"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family]. GQA kv=8,
no biases, parallel attn+FFN residual block, untied head over 256k vocab."""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    activation="silu",
    gated_mlp=True,
    norm="layernorm",
    use_bias=False,
    parallel_residual=True,
    rope_theta=75_000_000.0,
    tie_embeddings=True,
    fed=FedConfig(mode="client_sequential"),
    source="hf:CohereForAI/c4ai-command-r-v01 (R+ dims)",
)
