"""Architecture / run configuration dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig``.  ``reduced()`` derives the CPU smoke-test variant
(2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned; see system brief)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated-learning configuration (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FedConfig:
    """Paper knobs: rounds of E local SGD steps, scheme-based aggregation."""

    scheme: str = "C"              # "A" | "B" | "C"  (Section 4.1)
    local_epochs: int = 2          # E
    clients_per_round: int = 8     # C simulated clients in one jit'd round
    # client_parallel: clients vmapped over the data axis (paper breadth).
    # client_sequential: lax.scan over clients, each client data-parallel.
    mode: str = "client_parallel"
    # fast-reboot (Cor 4.0.2): arriving device coefficient boost.
    reboot_boost: float = 3.0
    # staircase learning rate eta_tau = eta0 / tau (Sec 5.1).
    eta0: float = 0.01


# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # --- attention ---
    n_heads: int = 0               # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    head_dim: int = 128
    rope_theta: float = 10000.0
    pos_emb: str = "rope"          # rope | sinusoidal | none
    sliding_window: int = 0        # 0 => full attention
    attn_logit_softcap: float = 0.0
    # --- mlp ---
    d_ff: int = 0
    activation: str = "silu"       # silu | gelu | geglu | sq_relu
    gated_mlp: bool = True         # gated (SwiGLU/GeGLU) vs plain 2-matmul
    # --- norm / structure ---
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    use_bias: bool = False
    parallel_residual: bool = False  # cohere-style parallel attn+ffn
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = True
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0           # 0 => direct q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0             # routed experts; 0 => dense FFN
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0         # leading layers with dense FFN
    router_score: str = "softmax"  # softmax | sigmoid (v3)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # --- SSM (mamba2 SSD) ---
    ssm_d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_d_conv: int = 4
    ssm_chunk: int = 256
    # --- multimodal stub frontends ---
    n_patches: int = 0             # vlm: patch embeddings prepended
    n_codebooks: int = 0           # audio: EnCodec codebooks (summed embed)
    # --- extras ---
    mtp_depth: int = 0             # deepseek-v3 multi-token prediction
    dtype: str = "bfloat16"
    # --- federated / distribution ---
    fed: FedConfig = field(default_factory=FedConfig)
    remat: bool = True
    # beyond-paper §Perf optimizations (flags so the paper-faithful
    # baseline stays reproducible; see EXPERIMENTS.md §Perf):
    seq_parallel: bool = False     # Megatron-style sequence sharding of the
    #                                residual stream over the model axis.
    #                                REFUTED on this GSPMD version: the
    #                                partitioner reshards the remat carries
    #                                with full-rematerialization copies and
    #                                collectives blow up 7x (EXPERIMENTS.md
    #                                §Perf iteration 2) — off by default.
    remat_attention: bool = True   # nested remat of the q-chunk scan (do
    #                                not save per-chunk softmax probs)
    expand_gqa: bool = True        # train/prefill: repeat kv heads to H so
    #                                every attention tensor shards on one
    #                                head axis — kills the per-chunk score
    #                                all-gathers GSPMD inserts when
    #                                n_kv_heads < the model axis (§Perf it.4)
    attn_impl: str = "chunked"     # "chunked" (jnp, differentiable) or
    #                                "flash" (Pallas kernel, forward-only:
    #                                serving prefill on real TPUs; runs in
    #                                interpret mode on CPU)
    source: str = ""               # citation

    # -- derived ----------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.n_heads == 0

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so embedding/head shard over
        the 16-way model axis; padded logits are masked in the loss."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:      # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_d_state else 0

    @property
    def moe_layers(self) -> int:
        return (self.n_layers - self.first_k_dense) if self.n_experts else 0

    @property
    def dense_layers(self) -> int:
        return self.n_layers - self.moe_layers

    def supports_shape(self, shape_name: str) -> bool:
        """long_500k only for sub-quadratic archs (see DESIGN.md)."""
        if shape_name != "long_500k":
            return True
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 32 if self.n_heads else self.head_dim
        n_h = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_h // 2)) if self.n_kv_heads else 0
        changes = dict(
            n_layers=2,
            d_model=d,
            vocab=min(self.vocab, 512),
            n_heads=n_h,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
            remat=False,
            fed=replace(self.fed, clients_per_round=4, local_epochs=2),
        )
        if self.use_mla:
            changes.update(
                q_lora_rank=64 if self.q_lora_rank else 0,
                kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
                head_dim=48,  # qk_nope + qk_rope
            )
        if self.n_experts:
            changes.update(
                n_experts=4,
                top_k=min(self.top_k, 2),
                n_shared_experts=min(self.n_shared_experts, 1),
                moe_d_ff=2 * d,
                first_k_dense=min(self.first_k_dense, 1),
            )
        if self.ssm_d_state:
            changes.update(ssm_d_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.n_patches:
            changes.update(n_patches=8)
        if self.mtp_depth:
            changes.update(mtp_depth=1)
        return replace(self, **changes)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "llava-next-34b",
    "gemma-7b",
    "hymba-1.5b",
    "starcoder2-3b",
    "mamba2-130m",
    "command-r-plus-104b",
    "musicgen-medium",
    "deepseek-v2-lite-16b",
    "nemotron-4-15b",
    "deepseek-v3-671b",
]

PAPER_IDS = ["mnist_mlp", "emnist_cnn", "synthetic_lr"]


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}"
    )
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
