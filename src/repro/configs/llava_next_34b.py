"""LLaVA-NeXT 34B language backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf,
scaled per llava-v1.6-34b / Yi-34B dims].

VLM: anyres-tiled vision frontend is a stub — ``input_specs`` supplies
(B, n_patches, d_model) projected patch embeddings which the backbone
prepends to the token embeddings (loss masked to text positions).
"""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    n_patches=576,  # one 24x24 anyres tile of projected CLIP patches
    fed=FedConfig(mode="client_sequential"),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34b variant dims)",
)
