"""Hymba 1.5B [arXiv:2411.13676]. Hybrid: every layer runs attention heads
and Mamba(2)-style SSM heads **in parallel**, outputs normalized per branch
then mean-combined. Attention uses SWA 2048 (Hymba uses SWA in most layers +
meta tokens; the few-global-layers detail is simplified — noted in
DESIGN.md). ssm_state=16.
"""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    sliding_window=2048,
    ssm_d_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    fed=FedConfig(mode="client_parallel"),
    source="arXiv:2411.13676",
)
