"""The paper's own experiment models (Section 5.1).

MNIST 2-layer MLP and EMNIST 2-conv CNN (both per McMahan et al. 2016),
and logistic regression for SYNTHETIC(alpha, beta) (Li et al. 2018).
Real MNIST/EMNIST are not available offline; the data pipeline substitutes
seeded pseudo-image class clusters with the same shapes (see repro.data).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import FedConfig


@dataclass(frozen=True)
class PaperModelConfig:
    name: str
    kind: str                 # mlp | cnn | logreg
    input_shape: tuple
    n_classes: int
    hidden: int = 200
    eta0: float = 2e-3
    batch_size: int = 10
    n_devices: int = 100      # federated clients in the paper's experiments
    local_epochs: int = 5
    fed: FedConfig = field(default_factory=FedConfig)


MNIST_MLP = PaperModelConfig(
    name="mnist_mlp",
    kind="mlp",
    input_shape=(28, 28),
    n_classes=10,
    hidden=200,
    eta0=2e-3,
    batch_size=10,
    n_devices=100,
)

EMNIST_CNN = PaperModelConfig(
    name="emnist_cnn",
    kind="cnn",
    input_shape=(28, 28, 1),
    n_classes=62,
    eta0=5e-4,
    batch_size=10,
    n_devices=62,
)

SYNTHETIC_LR = PaperModelConfig(
    name="synthetic_lr",
    kind="logreg",
    input_shape=(60,),
    n_classes=10,
    eta0=1.0,
    batch_size=20,
    n_devices=50,
)

PAPER_CONFIGS = {
    "mnist_mlp": MNIST_MLP,
    "emnist_cnn": EMNIST_CNN,
    "synthetic_lr": SYNTHETIC_LR,
}
