from repro.configs.base import (
    ARCH_IDS,
    PAPER_IDS,
    INPUT_SHAPES,
    ArchConfig,
    FedConfig,
    InputShape,
    all_configs,
    get_config,
)

__all__ = [
    "ARCH_IDS",
    "PAPER_IDS",
    "INPUT_SHAPES",
    "ArchConfig",
    "FedConfig",
    "InputShape",
    "all_configs",
    "get_config",
]
