"""MusicGen medium [arXiv:2306.05284]. Decoder-only over EnCodec tokens:
4 codebooks (delay pattern), summed codebook embeddings, 4 parallel LM heads
over vocab=2048. Sinusoidal positions, LayerNorm, GELU. The text-conditioning
cross-attention (T5 frontend) is omitted per the modality-frontend carve-out.
"""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    use_bias=True,
    pos_emb="sinusoidal",
    n_codebooks=4,
    tie_embeddings=False,
    fed=FedConfig(mode="client_parallel"),
    source="arXiv:2306.05284",
)
