"""StarCoder2 3B [arXiv:2402.19173]. GQA kv=2, RoPE, sliding window 4096,
LayerNorm with bias, plain GELU MLP (non-gated)."""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    use_bias=True,
    sliding_window=4096,
    rope_theta=999999.4,
    fed=FedConfig(mode="client_parallel"),
    source="arXiv:2402.19173",
)
