"""DeepSeek-V3 671B [arXiv:2412.19437]. MLA (q_lora=1536, kv_lora=512,
qk_rope=64), MoE: 256 routed top-8 (sigmoid router w/ aux-free bias —
implemented as sigmoid scoring + aux loss) + 1 shared expert, moe_d_ff=2048;
first 3 layers dense (d_ff=18432); MTP depth 1."""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,  # MLA: full heads over the shared compressed cache
    head_dim=192,    # qk_nope(128) + qk_rope(64)
    d_ff=18432,      # dense (first 3) layers
    vocab=129280,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_k_dense=3,
    router_score="sigmoid",
    mtp_depth=1,
    fed=FedConfig(mode="client_sequential", clients_per_round=4),
    source="arXiv:2412.19437",
)
