"""Mamba2 130M [arXiv:2405.21060]. Attention-free; SSD (state-space duality)
chunked algorithm; d_state=128, expand=2 (d_inner=1536), head_dim=64
(24 SSD heads), 1 group, conv width 4."""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=0,             # no separate MLP block (mamba block is the mixer)
    vocab=50280,
    norm="rmsnorm",
    pos_emb="none",
    ssm_d_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_d_conv=4,
    fed=FedConfig(mode="client_parallel"),
    source="arXiv:2405.21060",
)
