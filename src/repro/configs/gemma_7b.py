"""Gemma 7B [arXiv:2403.08295]. GeGLU, head_dim=256, kv=16 (MQA on 2b),
embeddings scaled by sqrt(d_model), tied embeddings."""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    activation="geglu",
    gated_mlp=True,
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    fed=FedConfig(mode="client_parallel"),
    source="arXiv:2403.08295",
)
