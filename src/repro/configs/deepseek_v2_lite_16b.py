"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]. MLA with kv_lora=512,
qk_rope=64, no q compression; MoE: 64 routed experts top-6 + 2 shared,
moe_d_ff=1408; first layer dense (d_ff=10944).

Note: the assignment line reads "MoE 64e top-6 ... 2 shared+160 routed";
160 routed belongs to full DeepSeek-V2 — we follow the primary spec
(64 routed) per the V2-Lite model card. Recorded in DESIGN.md.
"""
from repro.configs.base import ArchConfig, FedConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,   # MLA: heads share the compressed cache; kept for record
    head_dim=192,    # qk_nope(128) + qk_rope(64)
    d_ff=10944,      # dense (first) layer FFN
    vocab=102400,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    use_mla=True,
    q_lora_rank=0,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    router_score="softmax",
    # client_sequential so the shard_map expert-parallel MoE path applies
    # (client_parallel's vmap precludes it; §Perf iteration 6): the dense
    # dispatch left train_4k collective-bound at 49 s/step.
    fed=FedConfig(mode="client_sequential", clients_per_round=8),
    source="arXiv:2405.04434",
)
