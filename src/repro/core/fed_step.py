"""The jit'd federated round (Eq. 1–2) in the equivalent view (App. A.1.1).

Two execution layouts with identical arithmetic:

  client_parallel   — vmap over the client axis (sharded over the mesh
                      'data'/'pod' axes).  Paper-faithful breadth; per-client
                      parameter copies are live simultaneously.
  client_sequential — lax.scan over clients; each client's *batch* is
                      data-parallel over the mesh and params are fully
                      sharded (FSDP x TP).  Used for >=30B architectures.

Local updates are vanilla SGD (the paper's optimizer) with the staircase
learning rate supplied per round; each of the E steps is masked by
alpha[c, e] in {0,1}, so s_tau^k = sum_e alpha[c, e].
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import (accumulate_delta, aggregate_deltas,
                                    aggregate_deltas_compressed_ref,
                                    aggregate_deltas_flat, apply_accumulator,
                                    scheme_coefficients)
from repro.core.compression import resolve_compression, round_trip_tree


def local_sgd(loss_fn: Callable, params, client_batches, alpha_e, eta):
    """E masked SGD steps on one client.

    client_batches: pytree with leading (E, ...) dim (one batch per local
    epoch); alpha_e: (E,) masks; returns the client delta w_E - w_0.
    """

    def step(w, xs):
        batch, a = xs
        _, g = jax.value_and_grad(loss_fn)(w, batch)
        w = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - eta * a * gg.astype(jnp.float32)).astype(p.dtype),
            w, g)
        return w, None

    w_end, _ = jax.lax.scan(step, params, (client_batches, alpha_e))
    return jax.tree.map(
        lambda e, s: e.astype(jnp.float32) - s.astype(jnp.float32),
        w_end, params)


def _constrain_client_deltas(sharding, deltas, param_specs):
    """Constrain stacked client deltas (leaves (C, ...)): the client dim
    over the federation axes, the trailing dims per the model's param
    spec (or unsharded for replicated small-model params)."""
    if param_specs is None:
        return sharding.constrain_client_tree(deltas)
    entry = sharding._entry()
    return jax.tree.map(
        lambda d, s: jax.lax.with_sharding_constraint(
            d, sharding.param_sharding(
                jax.sharding.PartitionSpec(entry, *s))),
        deltas, param_specs)


def _constrain_batch(sharding, batches, axis_dim: int):
    """Shard the batch dim of a batch pytree over the federation axes
    (the client-sequential data-parallel layout).

    Ragged batch dims used to fall back silently to GSPMD's choice (in
    practice: replication of the whole batch, wasting every federation
    device but one).  Policy decided here: **pad to divisible** — the
    batch dim is extended to the next multiple of the shard count by
    wrapping around to the leading samples, then sharded.  The gradient
    becomes a weighted batch mean in which the first ``pad`` samples
    count twice — statistically benign for SGD, bit-identical whenever
    the batch already divides (pad == 0, the config every production run
    should use), and logged once per shape at trace time so a ragged
    deployment shows up in the logs rather than in the profile."""
    n = sharding.n_shards

    def con(l):
        if l.ndim <= axis_dim:
            return l
        b = l.shape[axis_dim]
        pad = -b % n
        if pad:
            _log_batch_padding(b, n, pad)
            wrap = jnp.arange(b + pad) % b
            l = jnp.take(l, wrap, axis=axis_dim)
        return sharding.constrain_client(l, axis_dim)

    return jax.tree.map(con, batches)


@functools.lru_cache(maxsize=None)
def _log_batch_padding(b: int, n_shards: int, pad: int) -> None:
    """Once per (batch, shards) shape — tracing re-runs this, real
    dispatch never does."""
    import logging
    logging.getLogger(__name__).warning(
        "fed_round_sequential: batch dim %d is ragged over %d federation "
        "shards; padding to %d by wrapping %d leading samples "
        "(padding fraction %.3f — the first %d samples weigh double in "
        "the batch mean)", b, n_shards, b + pad, pad, pad / (b + pad), pad)


def fed_round_parallel(loss_fn, params, batches, alpha, coeffs, eta, *,
                       agg: str = "tree", interpret=None,
                       with_metrics: bool = True, sharding=None,
                       param_specs=None, compression=None):
    """batches: pytree (C, E, ...); alpha: (C, E); coeffs: (C,).
    Returns (new_params, metrics).

    agg selects the aggregation layout: "tree" is the per-leaf jnp
    reference; "flat" flattens the delta pytree into one (C, D_total)
    buffer and reduces it with a single weighted_agg Pallas launch.
    with_metrics=False skips the delta-norm reduction (hot-loop mode).

    sharding: optional fed.sharding.FedSharding — the client axis of
    batches/alpha/deltas is constrained to the mesh's federation axis
    (or composite axes, e.g. ('pod', 'data')) so local epochs run
    device-parallel, and the delta reduction psums over exactly the
    federation axes.  param_specs (a PartitionSpec pytree matching
    params, see models.sharding.tree_param_specs) keeps params sharded
    over the mesh's model/FSDP axes through the round — without it the
    aggregated params come back replicated (small-model path).

    compression: optional CompressionSpec/str — client deltas are
    quantized right after the masked-SGD epochs, before aggregation.
    On the flat layout the fused dequant-and-reduce kernel consumes the
    compressed payload directly; on the tree layout the pure-jnp
    reference round-trips the same quantization lattice.  Both paths use
    the flattened-leaf chunk grid, so layouts (and the sequential mode)
    stay parity-comparable."""
    spec = resolve_compression(compression)
    deltas = jax.vmap(lambda b, a: local_sgd(loss_fn, params, b, a, eta))(
        batches, alpha)
    if sharding is not None:
        deltas = _constrain_client_deltas(sharding, deltas, param_specs)
    if agg == "flat":
        new_params = aggregate_deltas_flat(params, deltas, coeffs,
                                           interpret=interpret,
                                           sharding=sharding,
                                           compression=spec)
    elif spec.active:
        new_params = aggregate_deltas_compressed_ref(params, deltas,
                                                     coeffs, spec)
    else:
        new_params = aggregate_deltas(params, deltas, coeffs)
    if sharding is not None:
        new_params = sharding.constrain_params(new_params, param_specs)
    if not with_metrics:
        return new_params, {"delta_norm": jnp.float32(0)}
    dn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                      for x in jax.tree.leaves(deltas)))
    return new_params, {"delta_norm": dn}


def fed_round_sequential(loss_fn, params, batches, alpha, coeffs, eta, *,
                         with_metrics: bool = True, sharding=None,
                         param_specs=None, compression=None):
    """Same contract as fed_round_parallel; clients scanned to bound
    memory: only the global params, the streaming aggregation accumulator
    and ONE live client delta exist at a time — never a (C, D_total) or
    per-client parameter stack.  This is the >=30B path.

    Under ``sharding`` each client's *batch* dim is data-parallel over
    the federation axes (GSPMD psums the gradient over exactly those
    axes) while params and the accumulator stay sharded per
    ``param_specs`` (FSDP x TP over the mesh's model axes) — the
    federated round never materializes a replicated copy of the model.

    compression round-trips each client's delta through the wire format
    (core.compression.round_trip_tree) before it enters the accumulator
    — the flattened-leaf chunk grid matches the parallel layout, so the
    two modes quantize identically and differ only in f32 reduction
    order."""
    spec = resolve_compression(compression)
    if sharding is not None:
        params = sharding.constrain_params(params, param_specs)

    def con_acc(acc):
        if sharding is not None:
            return sharding.constrain_params(acc, param_specs)
        return acc

    acc0 = con_acc(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def one_client(carry, xs):
        acc, dn2 = carry
        b_c, a_c, c_c = xs
        if sharding is not None:
            # (E, B, ...): batch dim 1 shards over the federation axes
            b_c = _constrain_batch(sharding, b_c, axis_dim=1)
        delta = local_sgd(loss_fn, params, b_c, a_c, eta)
        if spec.active:
            delta = round_trip_tree(delta, spec)
        acc = con_acc(accumulate_delta(acc, delta, c_c))
        if with_metrics:
            dn2 = dn2 + sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(delta))
        return (acc, dn2), None

    (acc, dn2), _ = jax.lax.scan(one_client, (acc0, jnp.float32(0)),
                                 (batches, alpha, coeffs))
    new_params = apply_accumulator(params, acc)
    if sharding is not None:
        new_params = sharding.constrain_params(new_params, param_specs)
    dn = jnp.sqrt(dn2) if with_metrics else jnp.float32(0)
    return new_params, {"delta_norm": dn}


def make_fed_round(loss_fn, mode: str = "client_parallel",
                   agg: str = "tree", interpret=None, compression=None):
    """Returns fed_round(params, batches, alpha, coeffs, eta)."""
    if mode == "client_parallel":
        return functools.partial(fed_round_parallel, loss_fn, agg=agg,
                                 interpret=interpret,
                                 compression=compression)
    if mode != "client_sequential":
        raise ValueError(f"mode must be client_parallel|client_sequential, "
                         f"got {mode!r}")
    return functools.partial(fed_round_sequential, loss_fn,
                             compression=compression)


def fed_train_step(loss_fn, cfg, params, batches, alpha, p_weights, eta,
                   scheme: str = None, mode: str = None):
    """Convenience one-call round: compute scheme coefficients from the
    realized s = alpha.sum(-1), then run the round."""
    scheme = scheme or cfg.fed.scheme
    mode = mode or cfg.fed.mode
    s = jnp.sum(alpha, axis=-1)
    coeffs = scheme_coefficients(scheme, p_weights, s, cfg.fed.local_epochs)
    return make_fed_round(loss_fn, mode)(params, batches, alpha, coeffs, eta)
