"""Device-participation models (paper §5.1, Table 2).

The paper records eight real traces from Raspberry Pis: five CPU-contention
levels (no inactivity) and three bandwidth levels (with inactivity).  The
published table gives the stdevs (0, 14.8, 11.3, 11.7, 14.8, 23.3, 22.3,
18.3 in %); the means column did not survive extraction, so we reconstruct
them as decreasing availability levels — documented here as a
reconstruction, not paper data.  Each trace is a distribution over the
fraction of the E required local epochs a device completes in a round.

The *equivalent view* (paper Appendix A.1.1): rather than a ragged number
of steps, every client runs exactly E steps and step i carries a 0/1 mask
alpha_i with sum_i alpha_i = s.  A device that completes s epochs has its
first s masks set — this is what `sample_alpha` returns and what the jitted
federated round consumes (static shapes, dynamic participation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Trace:
    """Fraction-of-epochs-completed distribution for one device class."""

    name: str
    mean: float          # mean completed fraction, conditional on active
    stdev: float         # stdev of the completed fraction
    p_inactive: float    # probability of s == 0 in a round

    def _beta_params(self):
        m, s = self.mean, max(self.stdev, 1e-3)
        # method of moments for Beta(a,b); clamp to a valid variance
        var = min(s * s, m * (1 - m) * 0.95) if 0 < m < 1 else None
        if var is None or var <= 0:
            return None
        k = m * (1 - m) / var - 1
        return max(m * k, 1e-2), max((1 - m) * k, 1e-2)

    def sample_fraction(self, rng: np.random.Generator, size=()):
        frac = np.full(size, self.mean, dtype=np.float64)
        ab = self._beta_params()
        if ab is not None:
            frac = rng.beta(ab[0], ab[1], size=size)
        if self.p_inactive > 0:
            frac = np.where(rng.random(size) < self.p_inactive, 0.0, frac)
        return frac

    def sample_s(self, rng: np.random.Generator, E: int, size=()):
        """Number of completed local epochs s in {0..E}."""
        frac = self.sample_fraction(rng, size)
        s = np.round(frac * E).astype(np.int64)
        if self.p_inactive == 0:
            # CPU-contention traces never produce zero epochs (paper §5.1)
            s = np.maximum(s, 1)
        return np.clip(s, 0, E)


# Table-2 reconstruction (stdevs from the paper; means reconstructed).
TRACES: Sequence[Trace] = (
    Trace("cpu_0", 1.00, 0.000, 0.0),
    Trace("cpu_30", 0.90, 0.148, 0.0),
    Trace("cpu_50", 0.75, 0.113, 0.0),
    Trace("cpu_70", 0.55, 0.117, 0.0),
    Trace("cpu_90", 0.30, 0.148, 0.0),
    Trace("bw_low", 0.50, 0.233, 0.30),
    Trace("bw_med", 0.65, 0.223, 0.20),
    Trace("bw_high", 0.80, 0.183, 0.10),
)


def sample_alpha(rng: np.random.Generator, traces: Sequence[Trace],
                 E: int) -> np.ndarray:
    """Sample one round of participation masks.

    traces: per-client trace assignment (length C).
    Returns alpha: (C, E) float32 with alpha[c, :s_c] = 1.
    """
    C = len(traces)
    s = np.array([t.sample_s(rng, E) for t in traces])
    alpha = (np.arange(E)[None, :] < s[:, None]).astype(np.float32)
    return alpha


def assign_traces(rng: np.random.Generator, n_clients: int,
                  n_traces: int) -> list:
    """Paper §5.2: |T| = j uses the first j traces, randomly assigned."""
    idx = rng.integers(0, n_traces, size=n_clients)
    return [TRACES[i] for i in idx]


class BernoulliParticipation:
    """Analytic alternative: alpha_t ~ iid Bernoulli(q) => s ~ Bin(E, q)
    (paper Appendix A.1.1 example). Useful for property tests."""

    def __init__(self, q: float):
        self.q = q

    def sample_alpha(self, rng: np.random.Generator, C: int, E: int):
        return (rng.random((C, E)) < self.q).astype(np.float32)
