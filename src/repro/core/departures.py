"""Model applicability on departures (paper §4.3, Corollary 4.0.3).

When device l departs at tau0 the operator chooses:
  include — keep the old objective; the model stays applicable to l's data
            but the loss bound acquires a non-vanishing D/E bias term
            (M_tau grows linearly after tau0);
  exclude — shift the objective; one-time bound increase (Thm 3.2), then
            convergence to the new optimum.

Exclude wins iff  min_{tau>=tau0} f0(tau) >= f1(T)  with
  f0(tau) = ((tau - tau0) D + V) / (tau E + gamma)
  f1(tau) = V~ / ((tau - tau0) E + gamma~),
  V~ ≈ V / (tau0 E + gamma) + Gamma_l,
which reduces to the rule-of-thumb  T - tau0 >= O(sqrt(Gamma_l tau0)).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BoundTerms:
    D: float        # heterogeneity/non-IID drift term (Thm 3.1)
    V: float        # variance/initialization term
    gamma: float    # learning-rate offset
    E: int          # local epochs per round


def f0_include(tau, tau0, t: BoundTerms):
    return ((tau - tau0) * t.D + t.V) / (tau * t.E + t.gamma)


def f1_exclude(tau, tau0, t: BoundTerms, gamma_l: float):
    V_tilde = t.V / (tau0 * t.E + t.gamma) + gamma_l
    return V_tilde / ((tau - tau0) * t.E + t.gamma)


def should_exclude(T: int, tau0: int, terms: BoundTerms,
                   gamma_l: float) -> bool:
    """Corollary 4.0.3 decision at departure time tau0 with deadline T."""
    taus = np.arange(tau0, T + 1)
    min_f0 = float(np.min(f0_include(taus, tau0, terms)))
    return min_f0 >= float(f1_exclude(T, tau0, terms, gamma_l))


def crossing_round(T: int, tau0: int, terms: BoundTerms,
                   gamma_l: float):
    """First tau where excluding beats including (None if never by T) —
    the quantity tabulated in paper Table 5."""
    taus = np.arange(tau0 + 1, T + 1)
    f0 = f0_include(taus, tau0, terms)
    f1 = f1_exclude(taus, tau0, terms, gamma_l)
    hit = np.nonzero(f1 <= f0)[0]
    return int(taus[hit[0]]) if hit.size else None


def shift_weights_departure(n: np.ndarray, idx: int) -> np.ndarray:
    """Weights over remaining clients after excluding client idx."""
    m = np.delete(n, idx)
    return m / float(np.sum(m))
