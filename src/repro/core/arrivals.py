"""Fast-reboot on device arrival (paper §4.2, Corollary 4.0.2).

When device l arrives at round tau0:
  * the objective shifts (mandatory): data weights p^k are renormalised to
    include n_l;
  * the staircase learning rate restarts: eta_tau = eta0 / (tau - tau0)
    (Corollary 3.2.1);
  * the arriving device's aggregation coefficient is boosted to
    beta * p^l, decaying back to p^l as O((tau - tau0)^-2) (paper §5.3 uses
    beta = 3).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class RebootState:
    tau0: int
    client_idx: int
    boost: float = 3.0

    def coeff_multiplier(self, tau: int) -> float:
        """Multiplier on p^l at round tau >= tau0; ->1 as O((tau-tau0)^-2)."""
        dt = max(tau - self.tau0, 0)
        return 1.0 + (self.boost - 1.0) / float((1 + dt) ** 2)


def staircase_lr(eta0: float, tau: int, tau0: int = 0) -> float:
    """eta_tau = eta0 / (tau - tau0), restarted at the last objective
    shift (Cor. 3.2.1)."""
    return eta0 / max(tau - tau0, 1)


def shift_weights_arrival(n: np.ndarray, n_l: float) -> np.ndarray:
    """Data weights after admitting a device with n_l samples.
    n: (C,) sample counts of existing clients. Returns (C+1,) weights."""
    total = float(np.sum(n) + n_l)
    return np.concatenate([n, [n_l]]) / total


def reboot_radius(F_tilde_gap: float, p_l: float, gamma_l: float,
                  L: float, mu: float, W: float) -> float:
    """Corollary 4.0.2: the extra update helps iff
    ||w - w*|| < (F~(w*) - F~(w~*)) / ((2 sqrt(2L)/mu) p~l sqrt(Gamma_l) + 1) p~l W."""
    denom = ((2.0 * np.sqrt(2.0 * L) / mu) * p_l * np.sqrt(max(gamma_l, 0.0))
             + 1.0) * p_l * W
    return F_tilde_gap / max(denom, 1e-12)
