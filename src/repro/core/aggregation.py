"""Aggregation schemes (paper §4.1) and the generalized FedAvg update.

Eq. (2):  w <- w + sum_k p_tau^k (w_k - w),  with round-varying p_tau^k.

Scheme A: only complete devices (s=E), p_tau^k = N p^k / K_tau (round
          dropped if K_tau = 0).
Scheme B: accept partial work, fixed p_tau^k = p^k.
Scheme C: debiased, p_tau^k = (E / s_tau^k) p^k (0 when inactive) — the
          paper's contribution; the only scheme converging to the global
          optimum under heterogeneous participation (Thm 3.1 / Table 1).

Coefficients are plain device arrays, so one compiled round step serves
every scheme and every participation pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def scheme_coefficients(scheme: str, p: jnp.ndarray, s: jnp.ndarray,
                        E: int) -> jnp.ndarray:
    """p: (C,) static data weights p^k; s: (C,) completed epochs.
    Returns p_tau: (C,) aggregation coefficients."""
    p = jnp.asarray(p, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    if scheme == "A":
        complete = (s >= E).astype(jnp.float32)
        K = jnp.sum(complete)
        # N is the number of devices in the objective (p > 0), not the
        # buffer length: capacity-slotted engines carry empty columns
        # with p = 0 that must not inflate the coefficients
        N = jnp.sum((p > 0).astype(jnp.float32))
        return jnp.where(K > 0, N * p * complete / jnp.maximum(K, 1.0), 0.0)
    if scheme == "B":
        return p * (s > 0)
    if scheme == "C":
        return jnp.where(s > 0, E * p / jnp.maximum(s, 1.0), 0.0)
    raise ValueError(f"unknown scheme {scheme}")


def theta_bound(scheme: str, n_clients: int, E: int) -> float:
    """Assumption 3.5 upper bound p_tau^k / p^k <= theta."""
    return {"A": float(n_clients), "B": 1.0, "C": float(E)}[scheme]


def aggregate_deltas(params, deltas, coeffs):
    """w + sum_k c_k delta_k over a stacked client axis.

    deltas: pytree with leading client dim (C, ...); coeffs: (C,).
    This is the jnp reference path; aggregate_deltas_flat is the fused
    single-launch Pallas path used by the device-resident round engine.
    """
    def upd(p, d):
        c = coeffs.astype(jnp.float32).reshape((-1,) + (1,) * (d.ndim - 1))
        return (p.astype(jnp.float32)
                + jnp.sum(c * d.astype(jnp.float32), axis=0)).astype(p.dtype)

    return jax.tree.map(upd, params, deltas)


def flatten_client_deltas(deltas):
    """Stacked-client pytree (leaves (C, ...)) -> one (C, D_total) f32
    buffer, leaves concatenated in jax.tree.leaves order."""
    leaves = jax.tree.leaves(deltas)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(C, -1).astype(jnp.float32) for l in leaves], axis=1)


def aggregate_deltas_flat(params, deltas, coeffs, *, block: int = 2048,
                          interpret=None, sharding=None, compression=None):
    """Same contract as aggregate_deltas, but the whole model is flattened
    into a single (C, D_total) buffer and reduced with ONE weighted_agg
    Pallas launch (instead of one scaled-add tree per leaf).

    sharding: an optional fed.sharding.FedSharding whose mesh shards the
    client axis — each device then reduces its own (C/n, D_total) slab
    locally and a psum epilogue replicates the result (the cross-device
    path of the sharded round engine).

    compression: optional CompressionSpec/str (core.compression).  The
    int8 kinds quantize the flat buffer on-device and reduce it with the
    fused dequant-and-reduce kernel — under sharding the payload+scales
    are what shard over the federation axes, so only compressed bytes
    (plus one f32 psum) cross the client dim.  bf16 is a plain cast into
    the existing kernel (it reduces any float dtype in f32)."""
    from repro.core.compression import compress_flat, resolve_compression
    from repro.kernels import ops  # kernels never import core: no cycle

    spec = resolve_compression(compression)
    flat = flatten_client_deltas(deltas)
    # shrink the tile for models smaller than one default block (pad waste)
    D = flat.shape[1]
    block = min(block, max(128, -(-D // 128) * 128))
    if spec.quantized:
        payload, scales = compress_flat(flat, spec)
        if sharding is not None:
            payload, scales = sharding.constrain_compressed(payload, scales)
            agg = ops.weighted_agg_quant_sharded(
                coeffs.astype(jnp.float32), payload, scales,
                chunk=spec.chunk, mesh=sharding.mesh, axis=sharding.axis,
                block=block, interpret=interpret)
        else:
            agg = ops.weighted_agg_quant(
                coeffs.astype(jnp.float32), payload, scales,
                chunk=spec.chunk, block=block, interpret=interpret)
        agg = agg[:D]
    else:
        if spec.kind == "bf16":
            flat = flat.astype(jnp.bfloat16)
        if sharding is not None:
            flat = sharding.constrain_client(flat)
            agg = ops.weighted_agg_sharded(
                coeffs.astype(jnp.float32), flat, mesh=sharding.mesh,
                axis=sharding.axis, block=block, interpret=interpret)
        else:
            agg = ops.weighted_agg(coeffs.astype(jnp.float32), flat,
                                   block=block, interpret=interpret)
    p_leaves, treedef = jax.tree.flatten(params)
    outs, off = [], 0
    for p in p_leaves:
        seg = agg[off:off + p.size].reshape(p.shape)
        outs.append((p.astype(jnp.float32) + seg).astype(p.dtype))
        off += p.size
    return jax.tree.unflatten(treedef, outs)


def aggregate_deltas_compressed_ref(params, deltas, coeffs, compression):
    """Pure-jnp reference for the compressed flat reduction: quantize ->
    dequantize -> einsum on the same flat layout and chunk grid as the
    fused kernel.  This is the off-TPU path (interpret-mode Pallas is an
    emulator, far slower than XLA's einsum on CPU) — same lattice, only
    the f32 reduction order differs."""
    from repro.core.compression import resolve_compression, round_trip

    spec = resolve_compression(compression)
    flat = round_trip(flatten_client_deltas(deltas), spec)
    agg = jnp.einsum("k,kd->d", coeffs.astype(jnp.float32), flat)
    p_leaves, treedef = jax.tree.flatten(params)
    outs, off = [], 0
    for p in p_leaves:
        seg = agg[off:off + p.size].reshape(p.shape)
        outs.append((p.astype(jnp.float32) + seg).astype(p.dtype))
        off += p.size
    return jax.tree.unflatten(treedef, outs)


def accumulate_delta(acc, delta, coeff):
    """Streaming form for the client-sequential mode: acc += c * delta.
    coeff may be a plain python float or a jax scalar."""
    c = jnp.asarray(coeff, jnp.float32)
    return jax.tree.map(
        lambda a, d: a + c * d.astype(jnp.float32), acc, delta)


def apply_accumulator(params, acc):
    return jax.tree.map(
        lambda p, a: (p.astype(jnp.float32) + a).astype(p.dtype), params, acc)


def expected_coeff_stats(scheme: str, p: np.ndarray, trace_samples,
                         E: int, n_rounds: int = 2000, seed: int = 0):
    """Monte-Carlo estimates of E[p_tau^k s_tau^k] etc. used by the theory
    module (learning-rate scale, z_tau detection).  trace_samples(rng) must
    return s: (C,) for one round."""
    rng = np.random.default_rng(seed)
    C = len(p)
    ps_sum = np.zeros(C)
    for _ in range(n_rounds):
        s = trace_samples(rng)
        c = np.asarray(scheme_coefficients(scheme, jnp.asarray(p),
                                           jnp.asarray(s), E))
        ps_sum += c * s
    Eps = ps_sum / n_rounds
    ratio = Eps / np.maximum(p, 1e-12)
    z = float(np.std(ratio) > 1e-6 * max(1.0, np.mean(np.abs(ratio))))
    return {"E_ps": Eps, "ratio": ratio, "z": z,
            "E_sum_ps": float(np.sum(Eps))}
