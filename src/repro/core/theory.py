"""Convergence-bound calculators (Theorem 3.1, Theorem 3.2, Table 1).

These evaluate the paper's bounds numerically so that experiments can plot
measured loss against the predicted envelope, and so the departure rule
(core.departures) has concrete D / V / gamma values.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.departures import BoundTerms


@dataclass(frozen=True)
class ProblemConstants:
    """Assumption 3.1-3.4 constants for the learning problem."""

    L: float           # smoothness
    mu: float          # strong convexity
    G2: float          # E||g||^2 bound
    sigma2: np.ndarray  # per-client gradient variance (C,)
    gamma_k: np.ndarray  # per-client non-IID metric Gamma_k (C,)


def theorem31_terms(pc: ProblemConstants, p: np.ndarray, E: int,
                    theta: float, E_ps: np.ndarray) -> BoundTerms:
    """Assemble the Theorem 3.1 bound terms.

    E_ps[k] ~= E[p_tau^k s_tau^k] (estimated, see
    aggregation.expected_coeff_stats); theta from Assumption 3.5.
    """
    S = float(np.sum(E_ps))
    gamma = max(32 * E * (1 + theta) * pc.L / (pc.mu * S),
                4 * E * E * theta / S)
    D = 64 * E * float(np.sum(E_ps * pc.gamma_k)) / (pc.mu * S)
    # B term (expectation, leading order)
    B = (2 * (2 + theta) * pc.L * float(np.sum(E_ps * pc.gamma_k))
         + (2 + pc.mu / (2 * (1 + theta) * pc.L)) * E * (E - 1) * pc.G2 * S
         + 2 * E * pc.G2 * float(np.sum(E_ps))
         + float(np.sum((p ** 2) * pc.sigma2)) * E)
    V = max(gamma ** 2, (16 * E / (pc.mu * S)) ** 2 * B / E)
    return BoundTerms(D=D, V=V, gamma=gamma, E=E)


def convergence_bound(tau: int, terms: BoundTerms, M_tau: float) -> float:
    """Eq. (3): E||w - w*||^2 <= (M_tau D + V) / (tau E + gamma)."""
    return (M_tau * terms.D + terms.V) / (tau * terms.E + terms.gamma)


def observed_participation_stats(scheme: str, p_rounds, s_rounds, E: int,
                                 *, tol: float = 1e-6) -> dict:
    """Plug-in estimates of Theorem 3.1's participation quantities from an
    *executed* run's observed participation matrix, instead of the
    Monte-Carlo forecast (aggregation.expected_coeff_stats).

    p_rounds: (R, C) per-round data weights p^k (forward-filled span
    args); s_rounds: (R, C) realized completed-epoch counts.  The
    realized aggregation coefficients p_tau^k are recomputed per round
    with `scheme_coefficients`, giving

      E_ps[k] — empirical mean of p_tau^k s_tau^k over the run;
      z[t]    — Assumption 3.5's per-round bias indicator: 1 where the
                realized coefficient mass sum_k p_tau^k s_tau^k deviates
                from the unbiased E * sum_k p^k (inactive objective
                members, incomplete devices under scheme A/B, dropped
                rounds);
      M[t]    — the cumulative biased-round count; M[t] counts biased
                rounds in [0, t], so Eq. (3) at round tau takes
                M[tau - 1];
      S       — sum_k E_ps[k] (the bound's S).
    """
    from repro.core.aggregation import scheme_coefficients

    p = np.asarray(p_rounds, np.float64)
    s = np.asarray(s_rounds, np.float64)
    if p.shape != s.shape:
        raise ValueError(f"p_rounds {p.shape} vs s_rounds {s.shape}")
    ps = np.empty_like(p)
    for t in range(len(p)):
        c = np.asarray(scheme_coefficients(scheme, p[t], s[t], E),
                       np.float64)
        ps[t] = c * s[t]
    E_ps = ps.mean(axis=0) if len(ps) else np.zeros(p.shape[-1])
    z = (np.abs(ps.sum(axis=1) - E * p.sum(axis=1))
         > tol * max(float(E), 1.0)).astype(np.float64)
    return {"E_ps": E_ps, "z": z, "M": np.cumsum(z),
            "S": float(E_ps.sum())}


def objective_shift_offset(L: float, mu: float, n_l: float, n: float,
                           gamma_l: float, arrival: bool) -> float:
    """Theorem 3.2 bound on ||w* - w~*||."""
    frac = n_l / (n + n_l) if arrival else n_l / n
    return (2.0 * np.sqrt(2.0 * L) / mu) * frac * np.sqrt(max(gamma_l, 0.0))


def quadratic_problem_constants(A_list, c_list, p) -> ProblemConstants:
    """Closed-form constants for F_k(w) = 0.5 (w-c_k)^T A_k (w-c_k).

    Used by tests/benchmarks: with quadratics every paper quantity (w*,
    Gamma_k, L, mu) is exact, so Theorem 3.1 / Table 1 are directly
    checkable.
    """
    A_list = [np.asarray(A) for A in A_list]
    c_list = [np.asarray(c) for c in c_list]
    p = np.asarray(p, np.float64)
    A_bar = sum(pk * A for pk, A in zip(p, A_list))
    b_bar = sum(pk * A @ c for pk, A, c in zip(p, A_list, c_list))
    w_star = np.linalg.solve(A_bar, b_bar)
    gamma_k = np.array([0.5 * (w_star - c) @ A @ (w_star - c)
                        for A, c in zip(A_list, c_list)])
    eigs = [np.linalg.eigvalsh(A) for A in A_list]
    L = float(max(e.max() for e in eigs))
    mu = float(min(e.min() for e in eigs))
    return ProblemConstants(L=L, mu=mu, G2=0.0,
                            sigma2=np.zeros(len(p)), gamma_k=gamma_k), w_star
