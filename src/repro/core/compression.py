"""Client-delta compression: the wire format behind ``compression=``.

On a real mesh the federated bottleneck is bytes, not FLOPs: every round
ships one f32 delta per sampled client into the aggregator, and the
paper's schemes (Eq. 2) only reweight that traffic.  This module defines
what actually goes on the wire:

  none       f32 deltas, the uncompressed baseline (4 bytes/elem).
  bf16       plain bfloat16 cast (2 bytes/elem, no scales) — the existing
             weighted_agg kernel already reduces any float dtype in f32.
  int8       per-chunk symmetric quantization: the flat delta row is cut
             into ``chunk``-wide groups, each stored as int8 codes in
             [-levels, +levels] plus ONE f32 scale = absmax/levels
             (~1 byte/elem + 4/chunk, a 3.94x byte cut at chunk=256).
  int8-topk  magnitude top-k sparsification (per client row) before the
             int8 path: only ``topk_frac`` of entries survive, the rest
             quantize to 0; wire bytes count value+index pairs.

Quantization happens on the *flattened* ``(C, D_total)`` layout
(`core.aggregation.flatten_client_deltas` order), so the parallel vmap
path and the sequential per-client accumulator see identical chunk
boundaries — the two execution modes stay parity-comparable.  The fused
dequant-and-reduce Pallas kernel (`kernels/weighted_agg.py`) consumes
the (payload, scales) pair directly; `round_trip` is the pure-jnp
reference used off-TPU and by the sequential accumulator.

Error contract (pinned by the property tests): for every element of a
chunk with stored scale s, |x - dequant(quant(x))| <= s/2.  Zero chunks
store scale 0 and round-trip exactly; chunks whose absmax/levels would
underflow f32 get a floor scale of 2^-126 so the bound survives
subnormal inputs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Smallest normal f32: the scale floor that keeps round(x/scale) finite
# and the <= scale/2 error bound valid for subnormal chunk maxima.
_SCALE_FLOOR = 2.0 ** -126

KINDS = ("none", "bf16", "int8", "int8-topk")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Static description of the delta wire format (hashable: it is
    closed over by jitted round steps and keys benchmark sections)."""
    kind: str = "none"
    chunk: int = 256          # scale-group width along the flat D axis
    levels: int = 127         # int8 code range is [-levels, +levels]
    topk_frac: float = 0.1    # surviving fraction per row (int8-topk)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"compression kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if not 1 <= self.levels <= 127:
            raise ValueError(f"levels must be in [1, 127] (int8 codes), "
                             f"got {self.levels}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], "
                             f"got {self.topk_frac}")

    @property
    def quantized(self) -> bool:
        """True for the int8 code paths (payload + scales)."""
        return self.kind in ("int8", "int8-topk")

    @property
    def active(self) -> bool:
        return self.kind != "none"

    @property
    def name(self) -> str:
        """Canonical string form; `resolve_compression` round-trips it."""
        if self.kind == "none":
            return "none"
        opts = []
        if self.quantized:
            if self.chunk != 256:
                opts.append(f"chunk={self.chunk}")
            if self.levels != 127:
                opts.append(f"levels={self.levels}")
            if self.kind == "int8-topk" and self.topk_frac != 0.1:
                opts.append(f"topk={self.topk_frac:g}")
        return self.kind + (":" + ",".join(opts) if opts else "")


def resolve_compression(spec) -> CompressionSpec:
    """None | str | CompressionSpec -> CompressionSpec.

    Strings are ``kind`` or ``kind:opt=v,opt=v`` with opts ``chunk``,
    ``levels``, ``topk`` — e.g. ``"int8"``, ``"int8:chunk=128,levels=7"``,
    ``"int8-topk:topk=0.05"``.
    """
    if spec is None:
        return CompressionSpec("none")
    if isinstance(spec, CompressionSpec):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"compression must be None, str or CompressionSpec, "
                        f"got {type(spec).__name__}")
    kind, _, rest = spec.partition(":")
    kw = {}
    if rest:
        for item in rest.split(","):
            key, _, val = item.partition("=")
            key = key.strip()
            if key == "chunk":
                kw["chunk"] = int(val)
            elif key == "levels":
                kw["levels"] = int(val)
            elif key == "topk":
                kw["topk_frac"] = float(val)
            else:
                raise ValueError(f"unknown compression option {key!r} "
                                 f"in {spec!r}")
    return CompressionSpec(kind.strip(), **kw)


def quantize_chunked(flat, *, chunk: int, levels: int = 127):
    """(K, D) float -> (payload int8 (K, Dp), scales f32 (K, Dp/chunk))
    with Dp = D rounded up to a chunk multiple (zero-padded; zero codes
    contribute nothing downstream).

    Per (row, chunk) group: scale = absmax/levels (floored at 2^-126 so
    subnormal groups keep a representable scale; exactly-zero groups get
    scale 0 and all-zero codes), payload = round(x/scale) clipped to the
    symmetric code range.
    """
    flat = flat.astype(jnp.float32)
    K, D = flat.shape
    pad = (-D) % chunk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    Dp = D + pad
    g = flat.reshape(K, Dp // chunk, chunk)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scales = jnp.where(absmax > 0,
                       jnp.maximum(absmax / levels, _SCALE_FLOOR), 0.0)
    safe = jnp.where(scales > 0, scales, 1.0)
    codes = jnp.clip(jnp.round(g / safe[..., None]), -levels, levels)
    return (codes.astype(jnp.int8).reshape(K, Dp),
            scales.astype(jnp.float32))


def dequantize_chunked(payload, scales, *, chunk: int, d: int | None = None):
    """(K, Dp) int8 + (K, Dp/chunk) f32 -> (K, d or Dp) f32."""
    K, Dp = payload.shape
    g = (payload.astype(jnp.float32).reshape(K, Dp // chunk, chunk)
         * scales[..., None])
    out = g.reshape(K, Dp)
    return out if d is None else out[:, :d]


def topk_mask(flat, frac: float):
    """Per-row magnitude top-k keep mask for (K, D) deltas.  k is static
    (max(1, round(frac*D))); ties at the threshold all survive."""
    D = flat.shape[1]
    k = max(1, min(D, int(round(frac * D))))
    mag = jnp.abs(flat.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][:, -1]
    return mag >= thresh[:, None]


def compress_flat(flat, spec: CompressionSpec):
    """Quantize a flat (K, D) delta buffer per the spec.

    Returns (payload int8 (K, Dp), scales f32 (K, Dp/chunk)) — the pair
    the fused dequant-and-reduce kernel consumes.  Only valid for the
    int8 kinds; bf16 has no payload/scale split (it is a plain cast).
    """
    if not spec.quantized:
        raise ValueError(f"compress_flat needs an int8 kind, "
                         f"got {spec.kind!r}")
    if spec.kind == "int8-topk":
        flat = jnp.where(topk_mask(flat, spec.topk_frac),
                         flat.astype(jnp.float32), 0.0)
    return quantize_chunked(flat, chunk=spec.chunk, levels=spec.levels)


def round_trip(flat, spec: CompressionSpec):
    """Quantize-then-dequantize a (K, D) buffer: the pure-jnp reference
    for what the fused kernel dequantizes in VMEM.  Identity for
    kind='none'."""
    if not spec.active:
        return flat.astype(jnp.float32)
    if spec.kind == "bf16":
        return flat.astype(jnp.bfloat16).astype(jnp.float32)
    D = flat.shape[1]
    payload, scales = compress_flat(flat, spec)
    return dequantize_chunked(payload, scales, chunk=spec.chunk, d=D)


def round_trip_tree(delta, spec: CompressionSpec):
    """Round-trip one client's delta pytree through the wire format.

    Leaves are flattened to a (1, D_total) row in jax.tree.leaves order —
    the SAME order and chunk grid as the stacked parallel path — so the
    sequential accumulator quantizes identically to the vmap layout.
    """
    if not spec.active:
        return delta
    leaves, treedef = jax.tree.flatten(delta)
    flat = jnp.concatenate(
        [l.reshape(1, -1).astype(jnp.float32) for l in leaves], axis=1)
    rt = round_trip(flat, spec)[0]
    outs, off = [], 0
    for l in leaves:
        outs.append(rt[off:off + l.size].reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, outs)


def wire_bytes(D: int, spec, *, n_clients: int = 1) -> int:
    """Analytic bytes-on-the-wire for one round of client->aggregator
    delta traffic (the quantity `BENCH_engine.json["compression"]`
    reports).  f32 baseline: 4*D per client.  int8: 1 byte/code for the
    D live elements + one f32 scale per chunk — the zero-padding the
    kernel layout appends to reach a chunk multiple is reconstructed on
    receipt, so it never crosses the wire.  int8-topk: surviving
    (int8 value, int32 index) pairs + the scale slab."""
    spec = resolve_compression(spec)
    if spec.kind == "none":
        per = 4 * D
    elif spec.kind == "bf16":
        per = 2 * D
    else:
        n_chunks = -(-D // spec.chunk)
        if spec.kind == "int8":
            per = D + 4 * n_chunks
        else:
            kept = max(1, min(D, int(round(spec.topk_frac * D))))
            per = kept * (1 + 4) + 4 * n_chunks
    return per * n_clients
