import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialisation.  This module is the ONLY place the 512
# placeholder host devices are created; tests/benchmarks see 1 device.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes and extract the roofline inputs.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
#
# Artifacts: experiments/artifacts/dryrun_<arch>_<shape>_<mesh>.json

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.hlo_analysis import analyze as analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step
from repro.models.sharding import use_mesh

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str):
    """Sum result sizes of every collective op in the HLO, per op kind.

    We use result sizes (operand sizes are equal for all-reduce, and the
    result is the moved quantity for all-gather/all-to-all) — recorded as
    such in EXPERIMENTS.md."""
    per_op = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        for op in COLLECTIVE_OPS:
            if re.search(rf"\b{op}(-start|-done)?\(", rhs):
                lhs = ls.split("=", 1)[0]
                if f"{op}-done(" in rhs:
                    break  # counted at -start
                sizes = [_shape_bytes(d, s) for d, s in
                         _SHAPE_RE.findall(lhs)]
                per_op[op] += sum(sizes)
                counts[op] += 1
                break
    total = sum(per_op.values())
    return {"total_bytes": total, "per_op_bytes": per_op, "counts": counts}


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
            verbose: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not cfg.supports_shape(shape_name):
        result["status"] = "skipped"
        result["reason"] = ("full-attention arch; long_500k requires "
                            "sub-quadratic attention (DESIGN.md)")
        _save(result, out_dir)
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] SKIPPED "
                  f"({result['reason']})")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = int(mesh.size)
    t0 = time.time()
    try:
        with use_mesh(mesh):
            bundle = make_step(cfg, shape, mesh)
            jitted = jax.jit(bundle.fn,
                             in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            lowered = jitted.lower(*bundle.input_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = _mem_dict(compiled.memory_analysis(), n_dev)
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            # scan-aware static analysis (cost_analysis counts while
            # bodies once; analyze() scales by known_trip_count)
            ana = analyze_hlo(hlo)

        result.update({
            "status": "ok",
            "devices": n_dev,
            "meta": bundle.meta,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": mem,
            "hlo_analysis": ana,
            "xla_cost_analysis": {
                "flops_unscaled": float(cost.get("flops", -1.0))
                if cost else -1.0,
                "bytes_accessed_unscaled":
                    float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
            },
        })
    except Exception as e:  # noqa: BLE001 — sweep must continue
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-3000:]})
    _save(result, out_dir)
    if verbose:
        if result["status"] == "ok":
            m = result["memory"] or {}
            a = result["hlo_analysis"]
            print(f"[{arch} x {shape_name} x {mesh_kind}] OK "
                  f"compile={result['compile_s']}s "
                  f"flops/dev={a['flops']:.3e} "
                  f"traffic/dev={a['traffic_bytes']:.3e} "
                  f"coll/dev={a['collective_bytes']:.3e} "
                  f"mem/dev={m.get('bytes_per_device', -1):.3e}")
        else:
            print(f"[{arch} x {shape_name} x {mesh_kind}] "
                  f"{result['status'].upper()}: "
                  f"{result.get('error', result.get('reason'))}")
    return result


def _mem_dict(mem, n_dev: int):
    """memory_analysis() of an SPMD executable reports *per-device* program
    sizes (the partitioned module); we record them as such."""
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    total = (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0))
    out["total_bytes"] = total
    out["bytes_per_device"] = total
    out["n_devices"] = n_dev
    return out


def _save(result, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    name = (f"dryrun_{result['arch']}_{result['shape']}_"
            f"{result['mesh']}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/artifacts")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = ARCH_IDS
        shapes = list(INPUT_SHAPES)
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_one(arch, shape, mk, args.out)
                if r["status"] == "error":
                    n_err += 1
                else:
                    n_ok += 1
    print(f"done: {n_ok} ok/skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
