"""Run a named streaming-participation scenario end-to-end.

  PYTHONPATH=src python -m repro.launch.fed_stream --scenario flash-crowd
  PYTHONPATH=src python -m repro.launch.fed_stream --scenario churn \
      --rounds 60 --eval-every 10 --mode device --json out.json

Replays the scenario's event stream (arrivals admitted into capacity
slots mid-training, departures, trace shifts, inactivity bursts) through
the StreamScheduler on the paper's SYNTHETIC logreg workload and prints
an honest summary (non-eval rounds are NaN and are filtered, see
fed/scenarios.summarize_history) plus wall-clock rounds/sec.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> dict:
    from repro.fed.scenarios import SCENARIOS, make_scenario, run_scenario

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="flash-crowd",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the scenario's round count")
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--mode", default="device", choices=["device", "plan"])
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--json", default=None,
                    help="also write the summary to this path")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    sc = make_scenario(args.scenario, seed=args.seed)
    t0 = time.perf_counter()
    sch, summary = run_scenario(sc, mode=args.mode,
                                n_rounds=args.rounds,
                                eval_every=args.eval_every,
                                chunk_size=args.chunk_size)
    wall = time.perf_counter() - t0
    summary["wall_s"] = round(wall, 3)
    summary["rounds_per_sec"] = round(summary["rounds"] / wall, 2)

    if not args.quiet:
        print(f"# scenario {sc.name} ({sc.notes}), seed {sc.seed}, "
              f"mode {args.mode}")
        print("tau,loss,acc,eta,n_active,event")
        for h in sch.history:
            if h.event or not (h.loss != h.loss):   # event or evaluated
                print(f"{h.tau},{h.loss:.4f},{h.acc:.3f},{h.eta:.4f},"
                      f"{h.n_active},{h.event}")
        for k in ("rounds", "evals", "events_applied", "final_loss",
                  "final_acc", "mean_active", "clients_end", "capacity",
                  "wall_s", "rounds_per_sec"):
            print(f"{k},{summary[k]}")
    if args.json:
        payload = dict(summary)
        payload.pop("events", None)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        if not args.quiet:
            print(f"# wrote {args.json}")
    return summary


if __name__ == "__main__":
    main(sys.argv[1:])
