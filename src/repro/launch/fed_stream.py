"""Run a named streaming-participation scenario end-to-end.

  PYTHONPATH=src python -m repro.launch.fed_stream --scenario flash-crowd
  PYTHONPATH=src python -m repro.launch.fed_stream --scenario churn \
      --rounds 60 --eval-every 10 --mode device --json out.json

Replays the scenario's event stream (arrivals admitted into capacity
slots mid-training, departures, trace shifts, inactivity bursts) through
the StreamScheduler on the paper's SYNTHETIC logreg workload and prints
an honest summary (non-eval rounds are NaN and are filtered, see
fed/scenarios.summarize_history) plus wall-clock rounds/sec.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> dict:
    from repro.fed.scenarios import SCENARIOS, make_scenario, run_scenario

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="flash-crowd",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the scenario's round count")
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--mode", default=None, choices=["device", "plan"],
                    help="sampling mode (default: device; with --restore "
                         "the checkpoint's own mode unless given "
                         "explicitly — overriding it breaks exact resume)")
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--compress", default=None,
                    choices=["none", "bf16", "int8", "int8-topk"],
                    help="client-delta wire format (default: none; with "
                         "--restore the checkpoint's own format unless "
                         "given explicitly)")
    ap.add_argument("--bank", action="store_true",
                    help="keep the full fleet's payloads in a host-RAM "
                         "client bank (fed/bank.py); capacity slots "
                         "become a managed hot cache")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered cohort prefetch: stage the "
                         "next boundary's arrival cohort onto the "
                         "device while the current span runs "
                         "(implies --bank)")
    ap.add_argument("--json", default=None,
                    help="also write the summary to this path")
    ap.add_argument("--save-state", default=None, metavar="DIR",
                    help="write a resumable checkpoint (params + FedState "
                         "+ history) when the run ends")
    ap.add_argument("--restore", default=None, metavar="DIR",
                    help="resume a --save-state checkpoint and run "
                         "--rounds more rounds")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the telemetry JSONL dump (spans + "
                         "metrics) here when the run ends (enables "
                         "telemetry)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition here "
                         "when the run ends (enables telemetry)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    telemetry = None
    if args.metrics_out or args.prom_out:
        from repro.obs import Telemetry
        telemetry = Telemetry()

    sc = make_scenario(args.scenario, seed=args.seed)
    t0 = time.perf_counter()
    if args.restore:
        from repro.configs.paper import SYNTHETIC_LR
        from repro.fed.scenarios import _paper_eval_fn, summarize_history
        from repro.fed.stream import StreamScheduler
        from repro.models.small import make_loss_fn
        # the checkpoint's own mode unless --mode was given explicitly
        # (argparse's default must not silently flip a plan checkpoint
        # to device sampling — that would break exact resume)
        overrides = {} if args.mode is None else {"mode": args.mode}
        if args.compress is not None:
            overrides["compression"] = args.compress
        if args.bank:
            overrides["bank"] = True
        if args.prefetch:
            overrides["prefetch"] = True
        sch = StreamScheduler.restore(args.restore,
                                      loss_fn=make_loss_fn(SYNTHETIC_LR),
                                      eval_fn=_paper_eval_fn(),
                                      telemetry=telemetry,
                                      **overrides)
        resumed_from = sch._next_tau
        sch.run(args.rounds if args.rounds is not None else sc.n_rounds,
                eval_every=(args.eval_every if args.eval_every is not None
                            else sc.eval_every))
        summary = summarize_history(sch.history)
        summary.update(scenario=sc.name, events_applied=sch.events_applied,
                       capacity=sch.engine.capacity,
                       clients_end=len(sch.clients),
                       resumed_from=resumed_from)
        rounds_ran = sch._next_tau - resumed_from
    else:
        sch, summary = run_scenario(sc, mode=args.mode or "device",
                                    n_rounds=args.rounds,
                                    eval_every=args.eval_every,
                                    chunk_size=args.chunk_size,
                                    compression=args.compress,
                                    bank=args.bank or None,
                                    prefetch=args.prefetch,
                                    telemetry=telemetry)
        rounds_ran = summary["rounds"]
    wall = time.perf_counter() - t0
    if telemetry is not None:
        if args.metrics_out:
            telemetry.dump_jsonl(args.metrics_out)
            if not args.quiet:
                print(f"# telemetry JSONL written to {args.metrics_out}")
        if args.prom_out:
            telemetry.write_prom(args.prom_out)
            if not args.quiet:
                print(f"# prom exposition written to {args.prom_out}")
    if args.save_state:
        sch.save(args.save_state)
        if not args.quiet:
            print(f"# resumable checkpoint written to {args.save_state}")
    summary["compression"] = sch.engine.compression.name
    if sch.bank is not None:
        summary["bank"] = sch.prefetch_stats()
    summary["wall_s"] = round(wall, 3)
    # rounds run THIS invocation (a resumed history also holds the
    # pre-checkpoint rounds, which this wall clock never paid for)
    summary["rounds_per_sec"] = round(rounds_ran / wall, 2)

    if not args.quiet:
        print(f"# scenario {sc.name} ({sc.notes}), seed {sc.seed}, "
              f"mode {sch.mode}, wire {sch.engine.compression.name}")
        if sch.bank is not None:
            ps = sch.prefetch_stats()
            print(f"# bank: {ps['bank']['resident']} resident, "
                  f"prefetch hits {ps.get('hits', 0)} "
                  f"misses {ps.get('misses', 0)}")
        print("tau,loss,acc,eta,n_active,event")
        for h in sch.history:
            if h.event or not (h.loss != h.loss):   # event or evaluated
                print(f"{h.tau},{h.loss:.4f},{h.acc:.3f},{h.eta:.4f},"
                      f"{h.n_active},{h.event}")
        for k in ("rounds", "evals", "events_applied", "final_loss",
                  "final_acc", "mean_active", "clients_end", "capacity",
                  "wall_s", "rounds_per_sec"):
            print(f"{k},{summary[k]}")
    if args.json:
        payload = dict(summary)
        payload.pop("events", None)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        if not args.quiet:
            print(f"# wrote {args.json}")
    return summary


if __name__ == "__main__":
    main(sys.argv[1:])
