"""Multi-host / multi-pod cluster initialisation for real TPU deployments.

The dry-run (launch/dryrun.py) proves the SPMD programs lower and compile
for the production meshes using placeholder host devices; this module is
the piece that replaces the placeholders on real hardware: one process per
host, `jax.distributed.initialize`, then the same `make_production_mesh`
over the global device set.

Typical GKE/TPU-VM invocation (one line per host, via gcloud or your
scheduler):

    PYTHONPATH=src python -m repro.launch.cluster \
        --coordinator ${COORD_IP}:8476 \
        --num-processes ${N_HOSTS} --process-id ${HOST_ID} \
        -- python -m repro.launch.train --arch gemma-7b --full ...

On Cloud TPU the coordinator/process arguments are auto-detected and may
be omitted.  A 2-pod v5e-512 deployment runs 2x64 hosts; the
(pod, data, model) mesh built here is identical to the dry-run's, so the
compiled programs and shardings carry over unchanged.
"""
from __future__ import annotations

import argparse
import os
import runpy
import sys


def init_distributed(coordinator: str = None, num_processes: int = None,
                     process_id: int = None):
    import jax
    kwargs = {}
    if coordinator:
        kwargs["coordinator_address"] = coordinator
    if num_processes is not None:
        kwargs["num_processes"] = num_processes
    if process_id is not None:
        kwargs["process_id"] = process_id
    jax.distributed.initialize(**kwargs)
    return jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=os.environ.get(
        "REPRO_COORDINATOR"))
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- python -m repro.launch.train ...")
    args = ap.parse_args()

    jax = init_distributed(args.coordinator, args.num_processes,
                           args.process_id)
    print(f"[cluster] process {jax.process_index()}/{jax.process_count()} "
          f"local_devices={len(jax.local_devices())} "
          f"global_devices={len(jax.devices())}")

    cmd = [c for c in args.cmd if c != "--"]
    if not cmd:
        return
    if cmd[0] == "python":
        cmd = cmd[1:]
    if cmd and cmd[0] == "-m":
        sys.argv = cmd[1:]
        runpy.run_module(cmd[1], run_name="__main__")
    elif cmd:
        sys.argv = cmd
        runpy.run_path(cmd[0], run_name="__main__")


if __name__ == "__main__":
    main()
