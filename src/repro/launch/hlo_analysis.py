"""Static analysis of compiled (scheduled, SPMD-partitioned) HLO text.

Why: ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
lax.scan (layers, local epochs, clients, loss chunks) is dramatically
under-counted.  This analyzer parses the HLO module, builds the call tree
(while bodies scaled by their ``known_trip_count``), and produces
scan-corrected per-device totals:

  flops            — matmul (dot) FLOPs: 2 * prod(result) * prod(contracted)
  traffic_bytes    — HBM traffic proxy: operand+result bytes of every
                     surviving (post-fusion) instruction; fusion internals
                     excluded (they live in registers/VMEM)
  collective_bytes — result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     per kind

All shapes in the partitioned module are per-device, so these feed the
per-chip roofline terms directly.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)
    instrs: List[Instr] = field(default_factory=list)


# ops that produce no real HBM traffic
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call", "custom-call",
    "partition-id", "replica-id", "bitcast-convert",
}


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):  # potential computation header
            m = _HEADER_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                # parse parameter shapes "name: f32[...]"
                for pname, pshape in re.findall(
                        r"([\w.\-]+):\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\]"
                        r"(?:\{[^}]*\})?)", m.group(2)):
                    cur.params[pname] = pshape
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.traffic += other.traffic * scale
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * scale
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * scale)


def _local_shape(comp: Computation, name: str) -> Optional[str]:
    for ins in comp.instrs:
        if ins.name == name:
            return ins.shape
    return comp.params.get(name)


def _instr_traffic(comp: Computation, ins: Instr) -> float:
    """HBM traffic estimate for one surviving instruction.

    Slicing/in-place updates are aliasing-aware: a dynamic-update-slice
    writes only the update (the full buffer operand is aliased, not
    copied), and a dynamic-slice/gather reads ~the result size, not the
    whole operand.  Without this, scan-carried stacks (n_layers x
    residual) count as full reads/writes per layer — a ~10x overcount.
    """
    res = _shape_bytes(ins.shape)
    rest_head = ins.rest.split(", metadata")[0]
    opnds = []
    for opd in _OPERAND_RE.findall(rest_head)[:8]:
        s = _local_shape(comp, opd)
        if s:
            opnds.append(_shape_bytes(s))
    is_dus = (ins.op == "dynamic-update-slice"
              or "dynamic_update_slice" in ins.rest)
    is_slice = ins.op in ("dynamic-slice", "gather", "slice") \
        or "dynamic_slice" in ins.rest
    if is_dus:
        # write the update + read small operands; the aliased full buffer
        # (same size as the result) moves nothing
        small = [o for o in opnds if o < res]
        return 2.0 * sum(small) if small else 2.0 * res / max(len(opnds), 1)
    if is_slice:
        # read ~result, write result; ignore the big sliced operand
        small = [o for o in opnds if o <= 4 * res]
        return res + sum(small)
    return res + sum(opnds)


def analyze(text: str) -> Dict:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(cname: str, flops_only: bool) -> Cost:
        key = (cname, flops_only)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # break cycles defensively
        comp = comps.get(cname)
        if comp is None:
            return memo[key]
        total = Cost()
        for ins in comp.instrs:
            # --- flops ---
            if ins.op == "dot":
                dims = _shape_dims(ins.shape)
                ops = _OPERAND_RE.findall(ins.rest)
                cm = _CONTRACT_RE.search(ins.rest)
                if dims is not None and ops and cm:
                    lhs_shape = _local_shape(comp, ops[0])
                    lhs_dims = _shape_dims(lhs_shape) if lhs_shape else None
                    k = 1
                    if lhs_dims:
                        for ci in cm.group(1).split(","):
                            if ci:
                                k *= lhs_dims[int(ci)]
                    n_out = 1
                    for d in dims:
                        n_out *= d
                    total.flops += 2.0 * n_out * k
            elif ins.op == "convolution":
                # rough: 2 * out_elems * kernel_elems_per_output
                dims = _shape_dims(ins.shape)
                ops = _OPERAND_RE.findall(ins.rest)
                if dims and len(ops) >= 2:
                    ksh = _local_shape(comp, ops[1])
                    kd = _shape_dims(ksh) if ksh else None
                    if kd:
                        n_out = 1
                        for d in dims:
                            n_out *= d
                        kelems = 1
                        for d in kd[:-1]:  # all but output-feature dim
                            kelems *= d
                        total.flops += 2.0 * n_out * kelems
            # --- collectives ---
            if ins.op in COLLECTIVES or any(
                    ins.op == f"{c}-start" for c in COLLECTIVES):
                kind = ins.op.replace("-start", "")
                b = _shape_bytes(ins.shape)
                if not flops_only:
                    total.coll[kind] = total.coll.get(kind, 0.0) + b
                    total.coll_count[kind] = total.coll_count.get(kind, 0) + 1
            # --- traffic ---
            if not flops_only and ins.op not in _NO_TRAFFIC \
                    and not ins.op.endswith("-done"):
                total.traffic += _instr_traffic(comp, ins)
            # --- callees ---
            if ins.op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                for role, sub in re.findall(r"(body|condition)=%([\w.\-]+)",
                                            ins.rest):
                    total.add(comp_cost(sub, flops_only), scale=trip)
            elif ins.op == "fusion":
                cm2 = _CALL_RE.search(ins.rest)
                if cm2:
                    # fusion internals: count flops only (traffic is the
                    # fusion boundary, already counted above)
                    total.add(comp_cost(cm2.group(1), True))
            elif ins.op in ("call", "async-start"):
                cm2 = _CALL_RE.search(ins.rest)
                if cm2:
                    total.add(comp_cost(cm2.group(1), flops_only))
            elif ins.op == "conditional":
                bm = _BRANCH_RE.search(ins.rest)
                if bm:
                    subs = _OPERAND_RE.findall(bm.group(1))
                    costs = [comp_cost(s, flops_only) for s in subs]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.traffic)
                        total.add(best)
        memo[key] = total
        return total

    c = comp_cost(entry, False)
    return {
        "flops": c.flops,
        "traffic_bytes": c.traffic,
        "collective_bytes": sum(c.coll.values()),
        "collectives_per_op": c.coll,
        "collective_counts": c.coll_count,
        "n_computations": len(comps),
    }


def top_traffic(text: str, n: int = 25):
    """Per-instruction traffic attribution, scaled by while trip counts:
    the 'profile' used by the §Perf hypothesis loop."""
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break

    # compute the multiplier of each computation (product of trip counts
    # along the call chain)
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            scale = mult[cname]
            subs = []
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = int(tm.group(1)) if tm else 1
                subs = [(s, trip) for _r, s in
                        re.findall(r"(body|condition)=%([\w.\-]+)", ins.rest)]
            elif ins.op in ("call",):
                cm2 = _CALL_RE.search(ins.rest)
                if cm2:
                    subs = [(cm2.group(1), 1)]
            elif ins.op == "conditional":
                bm = _BRANCH_RE.search(ins.rest)
                if bm:
                    subs = [(s, 1) for s in _OPERAND_RE.findall(bm.group(1))]
            for sub, k in subs:
                mult[sub] = max(mult.get(sub, 0.0), scale * k)
                if sub not in seen:
                    seen.add(sub)
                    order.append(sub)

    rows = []
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.op in _NO_TRAFFIC or ins.op.endswith("-done"):
                continue
            t = _instr_traffic(comp, ins)
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', ins.rest)
            if mm:
                meta = mm.group(1)[-90:]
            rows.append((t * m, m, ins.op, ins.shape[:60], meta))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        text = f.read()
    print(json.dumps(analyze(text), indent=2))
    if len(sys.argv) > 2 and sys.argv[2] == "--top":
        for t, m, op, shape, meta in top_traffic(text):
            print(f"{t / 1e9:10.2f} GB x{int(m):5d} {op:18s} {shape:60s} {meta}")
