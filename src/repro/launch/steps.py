"""Step builders for the dry-run and real training/serving.

For each (arch x input shape) this module produces:
  * the step function (federated train round / prefill / decode),
  * ShapeDtypeStruct input specs (no allocation),
  * in/out shardings on a given mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.fed_step import make_fed_round
from repro.models import transformer
from repro.models.params import init_params
from repro.models.sharding import named_sharding, tree_param_specs

BATCH = ("pod", "data")


def _batch_axes(B: int, mesh):
    """Largest prefix of (pod, data) whose product divides B (long_500k has
    B=1 and must replicate)."""
    axes = [a for a in BATCH if a in mesh.shape]
    while axes:
        if B % int(np.prod([mesh.shape[a] for a in axes])) == 0:
            return tuple(axes)
        axes.pop(0)
    return None


# ---------------------------------------------------------------------------
# Parameter shapes without allocation
# ---------------------------------------------------------------------------


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def param_bytes(cfg: ArchConfig) -> int:
    ap = abstract_params(cfg)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(ap))


def serve_fsdp(cfg: ArchConfig) -> bool:
    """Shard serve-time params over the data axis too when a model-only
    (16-way) shard would not leave room for the KV cache."""
    return param_bytes(cfg) / 16 > 6e9


# ---------------------------------------------------------------------------
# Train (federated round) step
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    fn: Callable
    input_specs: Tuple          # ShapeDtypeStruct args (after params)
    in_shardings: Tuple         # matching shardings (params first)
    out_shardings: Any
    donate: Tuple = ()
    meta: Dict = None


def _token_struct(cfg, shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_train_step(cfg: ArchConfig, shape: InputShape, mesh) -> StepBundle:
    fed = cfg.fed
    parallel = fed.mode == "client_parallel"
    # client_parallel fills the client axis across pod*data; sequential uses
    # the configured clients_per_round and shards each client's batch.
    if parallel:
        C = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                         if a in mesh.shape]))
    else:
        C = fed.clients_per_round
    E = fed.local_epochs
    b = max(1, shape.global_batch // C)
    S = shape.seq_len
    S_text = S - cfg.n_patches if cfg.n_patches else S

    tok_shape = (C, E, b, S_text)
    if cfg.n_codebooks:
        tok_shape = tok_shape + (cfg.n_codebooks,)
    batch_specs = {
        "tokens": _token_struct(cfg, tok_shape),
        "labels": _token_struct(cfg, tok_shape),
    }
    client_axes = BATCH if parallel else None
    bdim_axes = None if parallel else BATCH
    tok_spec = P(client_axes, None, bdim_axes, *([None] * (len(tok_shape) - 3)))
    batch_shard = {"tokens": tok_spec, "labels": tok_spec}
    if cfg.n_patches:
        batch_specs["patch_emb"] = jax.ShapeDtypeStruct(
            (C, E, b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
        batch_shard["patch_emb"] = P(client_axes, None, bdim_axes, None, None)

    # the same ClientTask the federation engine uses (fed/task.py): the
    # dry-run train step and a live federated round share one loss path
    from repro.fed.task import LMTask
    task = LMTask(cfg, seq_len=S_text, fsdp=not parallel)
    round_fn = make_fed_round(task.loss_fn, fed.mode)

    def step(params, batches, alpha, coeffs, eta):
        return round_fn(params, batches, alpha, coeffs, eta)

    aparams = abstract_params(cfg)
    pspecs = tree_param_specs(aparams, fsdp=not parallel)
    ns = lambda spec: named_sharding(mesh, spec)
    in_shardings = (
        jax.tree.map(ns, pspecs),
        jax.tree.map(lambda s: ns(s), batch_shard),
        ns(P(client_axes, None)),
        ns(P(client_axes)),
        ns(P()),
    )
    input_specs = (
        aparams,
        batch_specs,
        jax.ShapeDtypeStruct((C, E), jnp.float32),
        jax.ShapeDtypeStruct((C,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    out_shardings = (jax.tree.map(ns, pspecs), None)
    return StepBundle(step, input_specs, in_shardings, out_shardings,
                      meta={"clients": C, "local_epochs": E,
                            "client_batch": b, "mode": fed.mode})


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode)
# ---------------------------------------------------------------------------


def _cache_sharding_tree(cfg, cache_struct, mesh, baxes):
    """Cache leaves: (L, B, slots, ...) — batch over `baxes`; kv dim over
    'model' for GQA; MLA compressed cache shards slots over 'model'."""
    ns = lambda spec: named_sharding(mesh, spec)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name in ("k", "v"):  # (L, B, slots, KV*hd) flattened kv dim
            return ns(P(None, baxes, None, "model"))
        if name == "ckv" or name == "krope":
            return ns(P(None, baxes, "model", None))
        if name == "pos_map":
            return ns(P(None, None))
        if name == "conv":
            return ns(P(None, baxes, None, "model"))
        if name == "state":  # (L, B, G, hg, P, N): head_dim over model
            return ns(P(None, baxes, None, None, "model", None))
        return ns(P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_struct)


def make_decode_step(cfg: ArchConfig, shape: InputShape, mesh) -> StepBundle:
    B, S = shape.global_batch, shape.seq_len
    baxes = _batch_axes(B, mesh)

    def step(params, cache, token, pos):
        return transformer.decode_step(params, cfg, cache, token, pos)

    aparams = abstract_params(cfg)
    pspecs = tree_param_specs(aparams, fsdp=serve_fsdp(cfg))
    cache_struct = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S))
    cache_shard = _cache_sharding_tree(cfg, cache_struct, mesh, baxes)
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    ns = lambda spec: named_sharding(mesh, spec)
    in_shardings = (
        jax.tree.map(ns, pspecs),
        cache_shard,
        ns(P(baxes, *([None] * (len(tok_shape) - 1)))),
        ns(P()),
    )
    input_specs = (
        aparams,
        cache_struct,
        jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    out_shardings = (None, cache_shard)
    return StepBundle(step, input_specs, in_shardings, out_shardings,
                      meta={"batch": B, "cache_len": S})


def make_prefill_step(cfg: ArchConfig, shape: InputShape, mesh) -> StepBundle:
    B, S = shape.global_batch, shape.seq_len
    baxes = _batch_axes(B, mesh)
    S_text = S - cfg.n_patches if cfg.n_patches else S

    def step(params, tokens, patch_emb=None):
        cache = transformer.init_cache(cfg, B, S)
        return transformer.prefill(params, cfg, tokens, cache,
                                   patch_emb=patch_emb)

    aparams = abstract_params(cfg)
    pspecs = tree_param_specs(aparams, fsdp=serve_fsdp(cfg))
    tok_shape = (B, S_text, cfg.n_codebooks) if cfg.n_codebooks \
        else (B, S_text)
    ns = lambda spec: named_sharding(mesh, spec)
    in_shardings = [jax.tree.map(ns, pspecs),
                    ns(P(baxes, None, *([None] * (len(tok_shape) - 2))))]
    input_specs = [aparams, jax.ShapeDtypeStruct(tok_shape, jnp.int32)]
    if cfg.n_patches:
        input_specs.append(jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)))
        in_shardings.append(ns(P(baxes, None, None)))
    return StepBundle(step, tuple(input_specs), tuple(in_shardings), None,
                      meta={"batch": B, "seq": S})


def make_step(cfg: ArchConfig, shape: InputShape, mesh) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh)
    return make_decode_step(cfg, shape, mesh)
