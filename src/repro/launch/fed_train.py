"""Federate an assigned architecture through the device-resident engine.

Unlike ``repro.launch.train`` (the seed host loop re-sampling batches in
numpy every round), this CLI drives the full production path: an LMTask
(fed/task.py) puts per-client token streams on device once, the
RoundEngine runs multi-round spans with on-device participation sampling,
and a StreamScheduler admits mid-training arrivals into capacity slots —
the same machinery the logreg workload uses, now over the model zoo.

  PYTHONPATH=src python -m repro.launch.fed_train --arch mamba2-130m \
      --rounds 8 --clients 4 --mode client_sequential

Composite (data x model) meshes shard the federation axis over 'data'
(add 'pod' via --pod for multi-pod federations) while each client's local
epochs run model-parallel over 'model' — params stay sharded per the
model's partition specs in client_sequential mode:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.fed_train --arch mamba2-130m \
      --data 2 --model 2 --mode client_sequential
"""
from __future__ import annotations

import argparse
import sys
import time


def build_fleet(task, *, n_clients: int, samples: int, seed: int,
                n_domains: int = 4):
    """Seeded non-IID client fleet: Zipf token streams per domain, Table-2
    availability traces round-robin."""
    import numpy as np

    from repro.core.participation import TRACES
    from repro.fed import Client

    rng = np.random.default_rng(seed)
    return [Client(x=task.token_stream(rng, n=samples, domain=i % n_domains),
                   trace=TRACES[i % len(TRACES)])
            for i in range(n_clients)]


def main(argv=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import ARCH_IDS, get_config
    from repro.fed import Arrival, FedSharding, LMTask, StreamScheduler
    from repro.models.params import param_count

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-130m", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=None,
                    help="engine capacity slots (default: clients + 2)")
    ap.add_argument("--samples", type=int, default=24,
                    help="sequences per client")
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--scheme", default="C", choices=list("ABC"))
    ap.add_argument("--eta0", type=float, default=0.05)
    ap.add_argument("--mode", default=None,
                    choices=["client_parallel", "client_sequential"],
                    help="engine execution mode (default: the arch "
                         "config's fed.mode)")
    ap.add_argument("--agg", default="auto", choices=["auto", "tree", "flat"])
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8", "int8-topk"],
                    help="client-delta wire format for aggregation "
                         "(docs/compression.md)")
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--eval-every", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator)")
    ap.add_argument("--data", type=int, default=0,
                    help="mesh 'data' (federation) axis size; 0 = no mesh")
    ap.add_argument("--model", type=int, default=1,
                    help="mesh 'model' (TP/FSDP) axis size")
    ap.add_argument("--pod", type=int, default=0,
                    help="leading 'pod' axis size for a composite "
                         "(pod x data) federation; 0 = no pod axis")
    ap.add_argument("--arrive", type=int, default=0,
                    help="admit this many brand-new clients mid-run "
                         "(streaming arrivals at round rounds//2)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mode = args.mode or cfg.fed.mode

    sharding = None
    if not args.data and (args.model > 1 or args.pod):
        ap.error("--model/--pod need --data (the mesh is built only for "
                 "a nonzero federation axis); e.g. --data 1 --model 2")
    if args.data:
        if args.pod:
            mesh = jax.make_mesh((args.pod, args.data, args.model),
                                 ("pod", "data", "model"))
            axis = ("pod", "data")
        else:
            mesh = jax.make_mesh((args.data, args.model),
                                 ("data", "model"))
            axis = "data"
        sharding = FedSharding(mesh=mesh, axis=axis)

    task = LMTask(cfg, seq_len=args.seq, fsdp=(mode == "client_sequential"))
    clients = build_fleet(task, n_clients=args.clients,
                          samples=args.samples, seed=args.seed)
    params = task.init_params(jax.random.PRNGKey(args.seed))
    n_params = param_count(params)

    # probe loss: one fixed held-out batch from every founding domain
    import numpy as np
    probe_rng = np.random.default_rng(args.seed + 1)
    probe = task.make_batch(
        {"tokens": task.token_stream(probe_rng, n=4, domain=0)})
    probe_loss = jax.jit(task.loss_fn)

    def evaluate(p):
        return float(probe_loss(p, probe)), float("nan")

    events = []
    if args.arrive:
        fresh = build_fleet(task, n_clients=args.arrive,
                            samples=args.samples, seed=args.seed + 999)
        events = [Arrival(max(1, args.rounds // 2), client=c)
                  for c in fresh]

    capacity = args.capacity
    if capacity is None:
        capacity = args.clients + max(2, args.arrive)
    sch = StreamScheduler(
        clients=clients, init_params=params, task=task,
        engine_mode=mode, capacity=capacity, max_samples=args.samples,
        local_epochs=args.local_epochs, batch_size=args.batch,
        scheme=args.scheme, eta0=args.eta0, chunk_size=args.chunk_size,
        agg=args.agg, compression=args.compress, sharding=sharding,
        seed=args.seed, mode="device", evaluate=evaluate, events=events)

    if not args.quiet:
        mesh_desc = (dict(sharding.mesh.shape) if sharding is not None
                     else "single-device")
        print(f"arch={cfg.name} params={n_params:,} mode={mode} "
              f"scheme={args.scheme} C={args.clients} "
              f"E={args.local_epochs} B={args.batch} S={args.seq} "
              f"capacity={sch.engine.capacity} mesh={mesh_desc} "
              f"wire={sch.engine.compression.name}")

    t0 = time.perf_counter()
    sch.run(args.rounds, eval_every=args.eval_every)
    wall = time.perf_counter() - t0

    evals = [(h.tau, h.loss, h.event) for h in sch.history
             if h.event or h.loss == h.loss]
    if not args.quiet:
        print("tau,probe_loss,event")
        for tau, loss, ev in evals:
            print(f"{tau},{loss:.4f},{ev}")
        print(f"rounds,{args.rounds}")
        print(f"wall_s,{wall:.2f}")
        print(f"rounds_per_sec,{args.rounds / wall:.3f}")

    losses = [l for _, l, _ in evals if l == l]
    return {"arch": cfg.name, "mode": mode, "params": n_params,
            "compression": sch.engine.compression.name,
            "rounds": args.rounds, "wall_s": round(wall, 3),
            "rounds_per_sec": round(args.rounds / wall, 3),
            "final_loss": losses[-1] if losses else float("nan"),
            "capacity": sch.engine.capacity,
            "events_applied": sch.events_applied}


if __name__ == "__main__":
    main(sys.argv[1:])
