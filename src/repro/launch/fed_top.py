"""``top`` for a live federation: plain-refresh terminal view of a
FederationService.

Renders one full frame per tick from the service's telemetry registry
and ``stats()``/``chaos_report()`` views — rounds/sec, inbox depth and
ingest lag, worker heartbeat age, busy/idle/overhead attribution, the
paper's participation gauges (active/inactive devices, scheme weight
mass and drift, per-client participation rates, live Theorem 3.1 bound
terms when attached), and the recovery history.  Rendering is stdlib
only and side-effect free: ``FedTop.frame()`` returns the frame as a
string, so tests (and ``--once``) can render headlessly.

Standalone (drives a scenario through the service, view attached):

  PYTHONPATH=src python -m repro.launch.fed_top --scenario flash-crowd \
      --rounds 40
  PYTHONPATH=src python -m repro.launch.fed_top --scenario churn \
      --chaos 7 --interval 0.5

This is exactly ``repro.launch.fed_serve`` with ``--top`` injected —
every fed_serve flag works here.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import Optional


def _val(snap: dict, name: str, labels: Optional[dict] = None,
         default: float = 0.0) -> float:
    """One counter/gauge sample out of a MetricsRegistry.snapshot()."""
    fam = snap.get(name)
    if not fam:
        return default
    want = labels or {}
    for s in fam["samples"]:
        if all(s["labels"].get(k) == str(v) for k, v in want.items()):
            return s.get("value", default)
    return default


def _hist(snap: dict, name: str, labels: Optional[dict] = None):
    """(count, sum, mean) of a histogram sample, or (0, 0.0, None)."""
    fam = snap.get(name)
    want = labels or {}
    if fam:
        for s in fam["samples"]:
            if all(s["labels"].get(k) == str(v)
                   for k, v in want.items()):
                n, tot = s.get("count", 0), s.get("sum", 0.0)
                return n, tot, (tot / n if n else None)
    return 0, 0.0, None


def _fmt_b(v: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if v < 1024 or unit == "GiB":
            return f"{v:.0f}{unit}" if unit == "B" else f"{v:.1f}{unit}"
        v /= 1024
    return f"{v:.1f}GiB"


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v < 1e-3:
        return f"{v * 1e6:.0f}us"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


class FedTop:
    """Frame renderer + refresh loop over one FederationService."""

    def __init__(self, svc, width: int = 78):
        self.svc = svc
        self.width = width
        self._prev: Optional[tuple] = None     # (monotonic, rounds)

    # -- one frame -------------------------------------------------------------
    def frame(self) -> str:
        svc = self.svc
        now = time.monotonic()
        st = svc.stats()
        tel = svc.telemetry
        snap = (tel.registry.snapshot() if tel.enabled
                else svc._registry.snapshot())
        rounds = int(st["rounds"])
        rate = None
        if self._prev is not None:
            t0, r0 = self._prev
            if now > t0:
                rate = (rounds - r0) / (now - t0)
        self._prev = (now, rounds)

        eng = getattr(getattr(svc, "scheduler", None), "engine", None)
        wire = (eng.compression.name if eng is not None
                and hasattr(eng, "compression") else "?")

        W = self.width
        bar = "-" * W
        lines = [
            f"fed_top  gen={st['generation']}  "
            f"{'supervised' if st['supervised'] else 'unsupervised'}  "
            f"{'PAUSED' if st['paused'] else 'running' if st['running'] else 'stopped'}"
            f"  wire={wire}"
            .ljust(W),
            bar,
            f"rounds     tau={rounds}"
            + (f"  {rate:.1f} r/s" if rate is not None else "")
            + f"  spans={st['spans_run']}"
            f"  heartbeat {_fmt_s(_val(snap, 'svc_heartbeat_age_s'))} ago",
            f"events     submitted={st['events_submitted']} "
            f"ingested={st['events_ingested']} "
            f"applied={st['events_applied']} "
            f"pending={st['events_pending']} inbox={st['inbox_depth']}",
            f"           merged={st['events_merged']} "
            f"dup={st['events_duplicated']} "
            f"delayed={st['events_delayed']} "
            f"flooded={st['events_flooded']}",
        ]

        busy = _val(snap, "svc_busy_seconds_total")
        idle = _val(snap, "svc_idle_seconds_total")
        over = _val(snap, "svc_overhead_seconds_total")
        total = busy + idle + over
        n_lag, _, lag_mean = _hist(snap, "svc_ingest_lag_seconds")
        lines.append(
            f"service    busy={busy:.2f}s idle={idle:.2f}s "
            f"overhead={over:.3f}s"
            + (f"  (overhead {over / total:.1%})" if total > 0 else "")
            + f"  ingest lag {_fmt_s(lag_mean)} (n={n_lag})")

        if tel.enabled:
            active = _val(snap, "fed_active_clients")
            n_obj = _val(snap, "fed_objective_clients")
            lines.append(
                f"paper      active={active:.0f}/{n_obj:.0f} devices  "
                f"mass={_val(snap, 'fed_scheme_weight_mass'):.4f} "
                f"drift={_val(snap, 'fed_scheme_weight_drift'):+.4f}  "
                f"eta={_val(snap, 'fed_eta'):.4g}")
            rate_min = _val(snap, "fed_participation_rate",
                            {"stat": "min"})
            rate_mean = _val(snap, "fed_participation_rate",
                             {"stat": "mean"})
            rate_max = _val(snap, "fed_participation_rate",
                            {"stat": "max"})
            n_st, _, st_mean = _hist(snap, "fed_event_staleness_rounds")
            lines.append(
                f"           participation min/mean/max = "
                f"{rate_min:.2f}/{rate_mean:.2f}/{rate_max:.2f}  "
                f"staleness mean="
                + (f"{st_mean:.1f} rounds" if st_mean is not None
                   else "-")
                + f" (n={n_st})")
            if snap.get("fed_bound", {}).get("samples"):
                lines.append(
                    f"bound      D={_val(snap, 'fed_bound', {'term': 'D'}):.4g} "
                    f"V={_val(snap, 'fed_bound', {'term': 'V'}):.4g} "
                    f"gamma={_val(snap, 'fed_bound', {'term': 'gamma'}):.4g} "
                    f"value={_val(snap, 'fed_bound', {'term': 'value'}):.4g}")

        fam = snap.get("fed_wire_bytes_total")
        if fam and fam["samples"]:
            per_wire = ", ".join(
                f"{s['labels'].get('wire', '?')}={_fmt_b(s['value'])}"
                for s in fam["samples"])
            lines.append(f"wire       uplink {per_wire}")
        hits = _val(snap, "sched_prefetch_hits_total")
        misses = _val(snap, "sched_prefetch_misses_total")
        if hits or misses:
            lines.append(
                f"prefetch   hits={hits:.0f} misses={misses:.0f}  "
                f"({hits / (hits + misses):.0%} staged ahead)")

        recs = list(svc.recoveries)
        if st["supervised"] or recs:
            n_rec, _, mttr_mean = _hist(snap, "svc_recovery_seconds")
            lines.append(
                f"recovery   {len(recs)} total  "
                f"mttr mean={_fmt_s(mttr_mean)}  "
                f"snapshot failures={st['snapshot_failures']}  "
                f"snapshots kept={st['snapshots_kept']}")
            for r in recs[-3:]:
                cause = r["cause"]
                if len(cause) > 40:
                    cause = cause[:37] + "..."
                lines.append(
                    f"  g{r['generation']} {cause}  "
                    f"mttr={_fmt_s(r['mttr_s'])} "
                    f"detect={_fmt_s(r.get('detect_latency_s', 0.0))} "
                    f"replayed={r['events_replayed']}")

        fam = snap.get("faults_fired_total")
        if fam and fam["samples"]:
            fired = ", ".join(
                f"{s['labels'].get('site', '?')}/"
                f"{s['labels'].get('kind', '?')}x{s['value']:.0f}"
                for s in fam["samples"])
            lines.append(f"faults     {fired}")
        lines.append(bar)
        return "\n".join(ln[:W] for ln in lines) + "\n"

    # -- refresh loop ----------------------------------------------------------
    def run(self, interval: float = 1.0,
            stop: Optional[threading.Event] = None,
            out=None, max_frames: Optional[int] = None) -> int:
        """Plain-refresh loop: clear + redraw each tick until ``stop`` is
        set (or ``max_frames`` frames).  Returns frames drawn."""
        out = out if out is not None else sys.stdout
        clear = "\x1b[2J\x1b[H" if getattr(out, "isatty",
                                           lambda: False)() else ""
        n = 0
        while max_frames is None or n < max_frames:
            out.write(clear + self.frame())
            out.flush()
            n += 1
            if stop is not None and stop.wait(interval):
                break
            if stop is None and max_frames is None:
                time.sleep(interval)
        return n


def attach(svc, interval: float = 1.0, out=None):
    """Start a daemon display thread over a running service; returns
    (thread, stop_event) — set the event to detach."""
    top = FedTop(svc)
    stop = threading.Event()
    t = threading.Thread(target=top.run,
                         kwargs=dict(interval=interval, stop=stop,
                                     out=out),
                         name="fed-top", daemon=True)
    t.start()
    return t, stop


def main(argv=None) -> dict:
    from repro.launch import fed_serve
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--top" not in argv:
        argv.append("--top")
    return fed_serve.main(argv)


if __name__ == "__main__":
    main()
