import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# Helper: compile one (arch x shape x mesh) and dump the scheduled HLO for
# offline profiling (used by the §Perf hypothesis loop).

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step
    from repro.models.sharding import use_mesh

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    with use_mesh(mesh):
        bundle = make_step(cfg, INPUT_SHAPES[args.shape], mesh)
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings)
        compiled = jitted.lower(*bundle.input_specs).compile()
        out = args.out or f"/tmp/hlo_{args.arch}_{args.shape}_{args.mesh}.txt"
        with open(out, "w") as f:
            f.write(compiled.as_text())
        mem = compiled.memory_analysis()
        print(f"wrote {out}")
        print(f"temp={mem.temp_size_in_bytes / 1e9:.2f}GB "
              f"arg={mem.argument_size_in_bytes / 1e9:.2f}GB")


if __name__ == "__main__":
    main()
