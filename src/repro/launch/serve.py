"""Batched serving driver: prefill a batch of prompts, then decode tokens
step by step with the per-family cache (GQA / ring-buffer / MLA / SSM).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.models.params import init_params, param_count


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"serving {cfg.name}: params={param_count(params):,} "
          f"batch={B} prompt={S} gen={args.gen}")

    key = jax.random.PRNGKey(args.seed)
    shp = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    prompts = jax.random.randint(key, shp, 0, cfg.vocab)

    decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(
        p, cfg, c, t, pos))

    t0 = time.time()
    cache = transformer.init_cache(cfg, B, max_len)
    logits, cache = transformer.prefill(params, cfg, prompts, cache)
    print(f"prefill {B}x{S}: {time.time() - t0:.2f}s")

    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    tokens = []
    t0 = time.time()
    for i in range(args.gen):
        key, sk = jax.random.split(key)
        nxt = jax.random.categorical(
            sk, logits / args.temperature, axis=-1)
        nxt = nxt.reshape(tok_shape).astype(jnp.int32)
        tokens.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, cache, nxt, jnp.int32(S + i))
    dt = time.time() - t0
    toks = B * args.gen
    print(f"decode: {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {dt / args.gen * 1e3:.1f} ms/step)")
    out = np.stack(tokens, axis=1)
    print("sample token ids (seq 0):", out[0].reshape(args.gen, -1)[:, 0][:16])


if __name__ == "__main__":
    main()
