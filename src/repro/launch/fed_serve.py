"""Serve a live federation: timed event traces against a FederationService.

Unlike ``repro.launch.fed_stream`` (which replays a scenario's events
through blocking ``run()`` calls), this CLI drives the *service* path:
a worker thread runs scheduler spans continuously while the main thread
submits ParticipationEvents on a wall-clock schedule — the closest thing
to production traffic this container can stage.

  PYTHONPATH=src python -m repro.launch.fed_serve --scenario flash-crowd \
      --rounds 40 --events-per-sec 20
  PYTHONPATH=src python -m repro.launch.fed_serve --scenario churn \
      --dump-trace /tmp/churn.jsonl              # write the timed trace
  PYTHONPATH=src python -m repro.launch.fed_serve --trace /tmp/churn.jsonl
  PYTHONPATH=src python -m repro.launch.fed_serve --scenario churn \
      --rounds 20 --snapshot /tmp/ckpt           # checkpoint at the end
  PYTHONPATH=src python -m repro.launch.fed_serve --resume /tmp/ckpt \
      --rounds 20                                # ...and pick it back up
  PYTHONPATH=src python -m repro.launch.fed_serve --scenario churn \
      --rounds 40 --chaos 7                      # supervised chaos soak

``--chaos SEED`` turns the run into a fault-injection soak: a seeded
FaultPlan (worker crashes/hangs, mid-span scheduler crashes, checkpoint
write failures and corruption, event floods, duplicated/delayed
ingestion) is wired into every boundary, and the service runs supervised
— periodic snapshots, a span watchdog, and crash-triggered restore +
replay.  The summary gains a ``"chaos"`` block (per-recovery records,
MTTR, fault log) from ``FederationService.chaos_report()``.

Trace format (JSONL): one event per line, the fed/events.py dict schema
with ndarray fields inlined as ``{"__ndarray__": {"data": [...],
"dtype": "float32"}}`` plus an optional ``"at"`` (seconds since serve
start) overriding the ``--events-per-sec`` pacing.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _to_jsonable(obj):
    if isinstance(obj, np.ndarray):
        return {"__ndarray__": {"data": obj.tolist(),
                                "dtype": str(obj.dtype)}}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_jsonable(v) for v in obj]
    return obj


def _from_jsonable(obj):
    if isinstance(obj, dict):
        if set(obj) == {"__ndarray__"}:
            spec = obj["__ndarray__"]
            return np.asarray(spec["data"], dtype=np.dtype(spec["dtype"]))
        return {k: _from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_from_jsonable(v) for v in obj]
    return obj


def dump_trace(events, path: str, *, events_per_sec: float) -> None:
    """Write a timed JSONL trace: events in (tau, push order), submit
    times paced at ``events_per_sec``."""
    from repro.fed.events import event_to_dict
    with open(path, "w") as f:
        for j, e in enumerate(sorted(events, key=lambda e: e.tau)):
            d = _to_jsonable(event_to_dict(e))
            d["at"] = round(j / events_per_sec, 4)
            f.write(json.dumps(d) + "\n")


def load_trace(path: str):
    """Read a JSONL trace: [(at_seconds, event), ...] in file order."""
    from repro.fed.events import event_from_dict
    out = []
    with open(path) as f:
        for j, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            d = _from_jsonable(json.loads(line))
            at = float(d.pop("at", j * 0.01))
            out.append((at, event_from_dict(d)))
    return out


def main(argv=None) -> dict:
    from repro.fed.scenarios import (_paper_eval_fn, build_scheduler,
                                     make_scenario, summarize_history)
    from repro.fed.service import FederationService
    from repro.fed.stream import StreamScheduler

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="flash-crowd",
                    help="scenario generator for the fleet + event trace")
    ap.add_argument("--trace", default=None,
                    help="JSONL event trace to replay (overrides the "
                         "scenario's own events)")
    ap.add_argument("--dump-trace", default=None, metavar="PATH",
                    help="write the scenario's timed trace as JSONL "
                         "and exit")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume a saved checkpoint instead of building "
                         "a fresh scheduler")
    ap.add_argument("--snapshot", default=None, metavar="DIR",
                    help="write a resumable checkpoint when serving ends")
    ap.add_argument("--rounds", type=int, default=None,
                    help="serve until this round (default: scenario's)")
    ap.add_argument("--span-rounds", type=int, default=4,
                    help="rounds per worker span between ingest polls")
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--events-per-sec", type=float, default=50.0,
                    help="submission pacing for scenario traces")
    ap.add_argument("--max-pending", type=int, default=256,
                    help="inbox bound (backpressure threshold)")
    ap.add_argument("--mode", default=None, choices=["device", "plan"],
                    help="sampling mode (default: device; with --resume "
                         "the checkpoint's own mode unless given "
                         "explicitly — overriding it breaks exact resume)")
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--compress", default=None,
                    choices=["none", "bf16", "int8", "int8-topk"],
                    help="client-delta wire format (default: none; with "
                         "--resume the checkpoint's own format unless "
                         "given explicitly)")
    ap.add_argument("--bank", action="store_true",
                    help="host-RAM client bank behind the slot registry "
                         "(fed/bank.py)")
    ap.add_argument("--prefetch", action="store_true",
                    help="stage the next arrival cohort on-device while "
                         "the current span runs (implies --bank)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run supervised with a seeded FaultPlan injected "
                         "at every boundary; adds a 'chaos' block to the "
                         "summary")
    ap.add_argument("--chaos-dir", default=None, metavar="DIR",
                    help="supervision snapshot directory for --chaos "
                         "(default: a fresh temp dir)")
    ap.add_argument("--snapshot-every", type=int, default=2,
                    help="spans between supervision auto-snapshots")
    ap.add_argument("--span-timeout", type=float, default=15.0,
                    help="watchdog: seconds of worker silence before the "
                         "supervisor declares a hang (--chaos only)")
    ap.add_argument("--max-restarts", type=int, default=8,
                    help="consecutive failed recoveries before giving up")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write the summary to this path")
    ap.add_argument("--top", action="store_true",
                    help="attach the fed_top live view while serving "
                         "(enables telemetry)")
    ap.add_argument("--top-interval", type=float, default=1.0,
                    help="fed_top refresh period in seconds")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the telemetry JSONL dump (spans + "
                         "metrics) here when serving ends (enables "
                         "telemetry)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition here "
                         "when serving ends (enables telemetry)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    telemetry = None
    if args.top or args.metrics_out or args.prom_out:
        from repro.obs import Telemetry
        telemetry = Telemetry()

    sc = make_scenario(args.scenario, seed=args.seed)
    if args.dump_trace:
        dump_trace(sc.events, args.dump_trace,
                   events_per_sec=args.events_per_sec)
        if not args.quiet:
            print(f"# wrote {len(sc.events)} events to {args.dump_trace}")
        return {"trace": args.dump_trace, "events": len(sc.events)}

    rounds = args.rounds if args.rounds is not None else sc.n_rounds
    eval_every = (args.eval_every if args.eval_every is not None
                  else sc.eval_every)

    if args.resume:
        # the checkpoint's own mode/wire unless given explicitly
        overrides = {} if args.mode is None else {"mode": args.mode}
        if args.compress is not None:
            overrides["compression"] = args.compress
        if args.bank:
            overrides["bank"] = True
        if args.prefetch:
            overrides["prefetch"] = True
        sch = StreamScheduler.restore(
            args.resume, loss_fn=_make_loss(), eval_fn=_paper_eval_fn(),
            telemetry=telemetry, **overrides)
        rounds = sch._next_tau + rounds   # serve this many MORE rounds
        timed = []
    elif args.trace:
        sch = build_scheduler(
            _strip_events(sc), mode=args.mode or "device",
            chunk_size=args.chunk_size, compression=args.compress,
            bank=args.bank or None, prefetch=args.prefetch,
            telemetry=telemetry)
        timed = load_trace(args.trace)
    else:
        sch = build_scheduler(
            _strip_events(sc), mode=args.mode or "device",
            chunk_size=args.chunk_size, compression=args.compress,
            bank=args.bank or None, prefetch=args.prefetch,
            telemetry=telemetry)
        timed = [(j / args.events_per_sec, e) for j, e in
                 enumerate(sorted(sc.events, key=lambda e: e.tau))]
    start_tau = sch._next_tau             # 0 fresh; checkpoint tau resumed

    svc_kwargs: dict = {}
    if args.chaos is not None:
        import tempfile

        from repro.fed.faults import FaultPlan
        n_spans = max(1, rounds // max(1, args.span_rounds))
        sch.injector = FaultPlan.generate(
            args.chaos, spans=n_spans,
            saves=max(1, n_spans // args.snapshot_every))
        snap_dir = args.chaos_dir or tempfile.mkdtemp(prefix="fed-chaos-")
        engine = sch.engine               # survives scheduler rebuilds
        svc_kwargs = dict(
            supervise=True, snapshot_dir=snap_dir,
            snapshot_every=args.snapshot_every,
            span_timeout=args.span_timeout,
            max_restarts=args.max_restarts,
            queue_policy="merge-stale",
            engine_factory=lambda: engine,
            restore_kwargs=dict(loss_fn=_make_loss(),
                                eval_fn=_paper_eval_fn()))

    svc = FederationService(sch, span_rounds=args.span_rounds,
                            eval_every=eval_every, max_rounds=rounds,
                            max_pending=args.max_pending, **svc_kwargs)
    top_stop = None
    t0 = time.perf_counter()
    with svc:
        if args.top:
            from repro.launch.fed_top import attach
            _, top_stop = attach(svc, interval=args.top_interval)
        for at, e in timed:               # the main thread is the client
            delay = at - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            svc.submit(e)
        svc.drain()
        svc.wait_rounds(rounds, timeout=600)
        if args.snapshot:
            svc.snapshot(args.snapshot)
        if top_stop is not None:
            top_stop.set()
    wall = time.perf_counter() - t0

    sch = svc.scheduler                   # recovery may have rebuilt it
    served = sch._next_tau - start_tau    # this invocation's rounds only
    summary = summarize_history(sch.history)
    summary.update(scenario=sc.name, wall_s=round(wall, 3),
                   compression=sch.engine.compression.name,
                   rounds_served=served,
                   rounds_per_sec=round(served / wall, 2),
                   **{k: v for k, v in svc.stats().items()
                      if k not in ("running", "paused")})
    if args.chaos is not None:
        summary["chaos"] = svc.chaos_report()
    if telemetry is not None:
        if args.metrics_out:
            telemetry.dump_jsonl(args.metrics_out)
        if args.prom_out:
            telemetry.write_prom(args.prom_out)
        summary["telemetry"] = {
            "spans_recorded": telemetry.tracer.recorded,
            "spans_dropped": telemetry.tracer.dropped,
            "metrics_out": args.metrics_out,
            "prom_out": args.prom_out}
    if not args.quiet:
        print(f"# served {served} rounds in {wall:.2f}s "
              f"({summary['rounds_per_sec']} rounds/s), "
              f"{svc.events_ingested} events ingested live")
        if args.chaos is not None:
            ch = summary["chaos"]
            print(f"# chaos: {ch['n_recoveries']} recoveries, "
                  f"mttr_mean={ch['mttr_mean_s']:.3f}s, "
                  f"{ch['recovered_rounds']} rounds recomputed, "
                  f"{len(ch.get('faults', []))} faults fired")
        for k in ("evals", "final_loss", "final_acc", "mean_active",
                  "events_submitted", "events_applied", "spans_run"):
            print(f"{k},{summary[k]}")
        if args.snapshot:
            print(f"# checkpoint written to {args.snapshot}")
    if args.json:
        payload = {k: v for k, v in summary.items() if k != "events"}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return summary


def _make_loss():
    from repro.configs.paper import SYNTHETIC_LR
    from repro.models.small import make_loss_fn
    return make_loss_fn(SYNTHETIC_LR)


def _strip_events(sc):
    """The service submits the trace live — the scheduler must not also
    preload the scenario's events."""
    import dataclasses
    return dataclasses.replace(sc, events=[])


if __name__ == "__main__":
    main(sys.argv[1:])
