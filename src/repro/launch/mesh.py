"""Production mesh builders.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  Target: TPU v5e, 256 chips/pod,
(data=16, model=16); multi-pod adds a leading pod axis (2 pods = 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_data_mesh(n_devices=None):
    """1-D client/data-parallel mesh over local devices — the federation
    axis used by ``fed.sharding.FedSharding`` (on CPU CI, virtualize with
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants used by the roofline analysis (benchmarks/roofline)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
