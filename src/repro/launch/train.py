"""Federated training driver for the assigned architectures.

CPU-scale entry point: trains a (reduced by default) architecture with the
paper's flexible-participation protocol on synthetic non-IID token streams.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --rounds 20 --scheme C [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.aggregation import scheme_coefficients
from repro.core.arrivals import staircase_lr
from repro.core.fed_step import make_fed_round
from repro.core.participation import TRACES, sample_alpha
from repro.data import fed_lm_batches
from repro.models import transformer
from repro.models.params import init_params, param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scheme", default="C", choices=list("ABC"))
    ap.add_argument("--eta0", type=float, default=0.05)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator)")
    ap.add_argument("--traces", type=int, default=5,
                    help="|T|: number of participation traces in play")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    C, E = args.clients, args.local_epochs
    rng = np.random.default_rng(args.seed)
    traces = [TRACES[i % args.traces] for i in range(C)]
    p_weights = jnp.full((C,), 1.0 / C)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"C={C} E={E} scheme={args.scheme}")

    def loss_fn(p, b):
        return transformer.train_loss(p, cfg, b)

    round_fn = jax.jit(make_fed_round(loss_fn, "client_parallel"))

    for tau in range(args.rounds):
        t0 = time.time()
        alpha = sample_alpha(rng, traces, E)
        s = alpha.sum(axis=1)
        coeffs = scheme_coefficients(args.scheme, p_weights,
                                     jnp.asarray(s), E)
        batch = fed_lm_batches(rng, vocab=cfg.vocab, n_clients=C,
                               local_epochs=E, batch=args.batch,
                               seq=args.seq,
                               codebooks=cfg.n_codebooks)
        if cfg.n_patches:
            batch["patch_emb"] = 0.02 * np.random.default_rng(tau).normal(
                size=(C, E, args.batch, cfg.n_patches, cfg.d_model)
            ).astype(np.float32)
        eta = staircase_lr(args.eta0, tau + 1)
        params, m = round_fn(params,
                             {k: jnp.asarray(v) for k, v in batch.items()},
                             jnp.asarray(alpha), coeffs, jnp.float32(eta))
        # probe loss on client 0's first batch
        probe = {k: jnp.asarray(v[0, 0]) for k, v in batch.items()}
        loss = float(loss_fn(params, probe))
        print(f"round {tau:3d} s={s.astype(int).tolist()} eta={eta:.4f} "
              f"loss={loss:.4f} |delta|={float(m['delta_norm']):.3e} "
              f"({time.time() - t0:.1f}s)")

    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.rounds,
                        extra={"arch": cfg.name, "scheme": args.scheme})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
