"""Reproduction of "Towards Flexible Device Participation in Federated
Learning" grown into a device-resident, streaming, mesh-sharded federated
training system on jax + Pallas.  See the root README.md for the map."""
