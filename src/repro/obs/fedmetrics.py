"""Paper-level federation signals derived from FedState + span metrics.

The paper's argument is about *participation dynamics*: how many devices
are inactive each round, how much aggregate weight mass the scheme
assigns (and how it drifts as devices depart/arrive), each device's
effective participation rate, and how those statistics enter the
Theorem 3.1 convergence bound.  ``FedObserver`` turns the raw per-span
outputs the scheduler already produces — the completed-epoch matrix
``s`` (R, capacity), the learning rates, the event log — into live
gauges/histograms on the shared telemetry registry:

  ``fed_active_clients`` / ``fed_inactive_clients``
      devices with s>0 vs objective members that contributed nothing
      this round (the paper's "inactive" x_k = 0 case).
  ``fed_scheme_weight_mass`` / ``fed_scheme_weight_drift``
      sum of the round's aggregation coefficients p_tau^k under the
      configured scheme (A/B/C re-derived in numpy from p and s — host
      arithmetic, no device round-trip), and its change vs the previous
      round.  Scheme B's mass deficit under inactivity is exactly the
      bias the paper's §3.2 discussion attributes it.
  ``fed_participation_rate{stat=min|mean|max}``
      per-client effective participation (fraction of member rounds
      with s>0), the quantity MIFA-style analyses bound regret by.
  ``fed_event_staleness_rounds``
      histogram of (apply_tau - event.tau) — how late news lands.
  ``fed_bound_D`` / ``fed_bound_V`` / ``fed_bound_gamma`` / ``fed_bound_value``
      live Theorem 3.1 terms, when a tractable problem is attached via
      :meth:`FedObserver.set_problem` — E[p s] is estimated online from
      the observed rounds, so the gauge tracks the *measured*
      participation process rather than an a-priori trace model.

With a null telemetry object every method is a cheap no-op (one
``enabled`` check), so schedulers can construct a FedObserver
unconditionally.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .telemetry import resolve

# staleness is measured in rounds, not seconds
STALENESS_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                     256.0)


def scheme_mass(scheme: str, p: np.ndarray, s: np.ndarray,
                E: int) -> float:
    """Sum of aggregation coefficients p_tau^k for one round — the numpy
    twin of core.aggregation.scheme_coefficients (which is jnp and would
    cost a device round-trip per observed round)."""
    p = np.asarray(p, np.float64)
    s = np.asarray(s, np.float64)
    if scheme == "A":
        complete = (s >= E).astype(np.float64)
        K = complete.sum()
        N = float((p > 0).sum())
        return float((N * p * complete / max(K, 1.0)).sum()) if K > 0 \
            else 0.0
    if scheme == "B":
        return float((p * (s > 0)).sum())
    if scheme == "C":
        return float(np.where(s > 0, E * p / np.maximum(s, 1.0),
                              0.0).sum())
    raise ValueError(f"unknown scheme {scheme}")


def _coeffs(scheme: str, p: np.ndarray, s: np.ndarray,
            E: int) -> np.ndarray:
    """Per-slot aggregation coefficients (numpy)."""
    p = np.asarray(p, np.float64)
    s = np.asarray(s, np.float64)
    if scheme == "A":
        complete = (s >= E).astype(np.float64)
        K = complete.sum()
        N = float((p > 0).sum())
        return (N * p * complete / max(K, 1.0)) if K > 0 \
            else np.zeros_like(p)
    if scheme == "B":
        return p * (s > 0)
    if scheme == "C":
        return np.where(s > 0, E * p / np.maximum(s, 1.0), 0.0)
    raise ValueError(f"unknown scheme {scheme}")


class FedObserver:
    """Per-round paper-signal instrumentation over a shared telemetry."""

    def __init__(self, telemetry=None):
        tel = resolve(telemetry)
        self.tel = tel
        self.enabled = tel.enabled
        self._g_active = tel.gauge(
            "fed_active_clients", "devices with s>0 in the last round")
        self._g_inactive = tel.gauge(
            "fed_inactive_clients",
            "objective members that contributed no epochs last round")
        self._g_objective = tel.gauge(
            "fed_objective_clients", "devices in the current objective")
        self._g_pending = tel.gauge(
            "fed_pending_events", "participation events queued, not yet "
            "applied")
        self._g_mass = tel.gauge(
            "fed_scheme_weight_mass",
            "sum of aggregation coefficients p_tau^k last round")
        self._g_drift = tel.gauge(
            "fed_scheme_weight_drift",
            "change in scheme weight mass vs the previous round")
        self._g_eta = tel.gauge("fed_eta", "learning rate of the last "
                                "round")
        self._g_rate = tel.gauge(
            "fed_participation_rate",
            "per-client effective participation rate (rounds with s>0 / "
            "member rounds)", labelnames=("stat",))
        self._c_rounds = tel.counter(
            "fed_rounds_total", "federated rounds completed")
        self._c_events = tel.counter(
            "sched_events_applied_total",
            "participation events applied, by kind", labelnames=("kind",))
        self._h_stale = tel.histogram(
            "fed_event_staleness_rounds",
            "rounds between an event's tau and the boundary it applied at",
            buckets=STALENESS_BUCKETS)
        self._g_bound = tel.gauge(
            "fed_bound", "live Theorem 3.1 bound terms (tractable configs "
            "only)", labelnames=("term",))
        # running state
        self._prev_mass: Optional[float] = None
        self._part = {}          # client id -> rounds with s>0
        self._member = {}        # client id -> member rounds observed
        # optional tractable problem for live bound evaluation
        self._pc = None
        self._theta = None
        self._m_tau = 1.0
        self._ps_sum = None      # per-client running sum of p_tau^k s^k
        self._ps_rounds = 0

    # -- tractable-config bound evaluation ------------------------------------
    def set_problem(self, pc, theta: float, m_tau: float = 1.0) -> None:
        """Attach Assumption 3.1-3.4 constants (core.theory
        ProblemConstants, e.g. from quadratic_problem_constants) so each
        span also refreshes the fed_bound{term=...} gauges."""
        self._pc = pc
        self._theta = float(theta)
        self._m_tau = float(m_tau)
        self._ps_sum = np.zeros(len(pc.gamma_k))
        self._ps_rounds = 0

    # -- per-event ------------------------------------------------------------
    def observe_event(self, e, tau: int) -> None:
        """Record one applied participation event (at boundary tau)."""
        if not self.enabled:
            return
        self._c_events.labels(type(e).__name__).inc()
        self._h_stale.observe(float(max(0, tau - e.tau)))

    # -- per-span -------------------------------------------------------------
    def observe_span(self, state, tau0: int, m: dict, scheme: str,
                     E: int) -> None:
        """Fold one span's metrics (m["s"]: (R, capacity), m["eta"]: (R,))
        into the gauges.  ``state`` is the scheduler's FedState *after*
        the span's events applied — membership is the span's membership."""
        if not self.enabled:
            return
        s_mat = np.asarray(m["s"], np.float64)
        etas = np.asarray(m["eta"], np.float64)
        R = s_mat.shape[0]
        if R == 0:
            return
        p = state.data_weights()
        n_obj = len(state.objective)
        slot_of = state.slot_of

        mass = None
        for j in range(R):
            s_row = s_mat[j]
            active = int((s_row > 0).sum())
            prev = mass if mass is not None else self._prev_mass
            mass = scheme_mass(scheme, p, s_row, E)
            if prev is not None:
                self._g_drift.set(mass - prev)
            self._g_active.set(active)
            self._g_inactive.set(max(0, n_obj - active))
            # per-client effective participation over observed rounds
            for i in state.objective:
                slot = slot_of.get(i)
                if slot is None:
                    continue
                self._member[i] = self._member.get(i, 0) + 1
                if s_row[slot] > 0:
                    self._part[i] = self._part.get(i, 0) + 1
            if self._pc is not None:
                self._accumulate_bound_round(state, p, s_row, scheme, E)
        self._prev_mass = mass
        self._g_mass.set(mass)
        self._g_eta.set(float(etas[-1]))
        self._g_objective.set(n_obj)
        self._g_pending.set(state.pending)
        self._c_rounds.inc(R)

        rates = [self._part.get(i, 0) / n for i, n in self._member.items()
                 if n > 0]
        if rates:
            self._g_rate.labels("min").set(min(rates))
            self._g_rate.labels("mean").set(sum(rates) / len(rates))
            self._g_rate.labels("max").set(max(rates))
        if self._pc is not None:
            self._refresh_bound(state, tau0 + R)

    def _accumulate_bound_round(self, state, p, s_row, scheme: str,
                                E: int) -> None:
        """Update the online E[p_tau^k s^k] estimate (client-indexed)."""
        c = _coeffs(scheme, p, s_row, E)
        for i, slot in state.slot_of.items():
            if i < len(self._ps_sum):
                self._ps_sum[i] += c[slot] * s_row[slot]
        self._ps_rounds += 1

    def _refresh_bound(self, state, tau: int) -> None:
        """Evaluate Theorem 3.1 terms against the measured participation
        process and publish them as gauges."""
        from repro.core.theory import convergence_bound, theorem31_terms
        if self._ps_rounds == 0:
            return
        E_ps = self._ps_sum / self._ps_rounds
        if E_ps.sum() <= 0:
            return                      # all-inactive so far: bound moot
        C = len(E_ps)
        p_slot = state.data_weights()
        p_client = np.zeros(C)
        for i in state.objective:
            slot = state.slot_of.get(i)
            if slot is not None and i < C:
                p_client[i] = p_slot[slot]
        terms = theorem31_terms(self._pc, p_client,
                                state.bound_terms.E, self._theta, E_ps)
        self._g_bound.labels("D").set(terms.D)
        self._g_bound.labels("V").set(terms.V)
        self._g_bound.labels("gamma").set(terms.gamma)
        self._g_bound.labels("value").set(
            convergence_bound(tau, terms, self._m_tau))

    # -- participation snapshot (fed_top reads this) --------------------------
    def participation(self) -> dict:
        """{client id: (participated, member_rounds)} observed so far."""
        return {i: (self._part.get(i, 0), n)
                for i, n in sorted(self._member.items())}
