"""repro.obs — the federation telemetry plane.

Zero-dependency (numpy + stdlib) observability for the whole stack:

  * :mod:`repro.obs.metrics` — thread-safe counters / gauges /
    fixed-bucket histograms in a :class:`MetricsRegistry`, with a
    Prometheus text exposition and a plain-dict snapshot;
  * :mod:`repro.obs.tracing` — monotonic-clock spans on a bounded ring
    buffer with parent/child nesting and a JSONL exporter;
  * :mod:`repro.obs.telemetry` — the :class:`Telemetry` facade every
    constructor accepts (``telemetry=None`` → the shared
    :data:`NULL` no-op);
  * :mod:`repro.obs.fedmetrics` — :class:`FedObserver`, per-round
    paper-level signals (participation, scheme weight mass, live
    Theorem 3.1 bound terms).

See docs/observability.md for the metric catalog and span inventory.
"""
from .metrics import (DEFAULT_BUCKETS, Counter, Family, Gauge, Histogram,
                      MetricsRegistry)
from .tracing import Span, Tracer
from .telemetry import NULL, NullTelemetry, Telemetry, resolve
from .fedmetrics import FedObserver, scheme_mass

__all__ = [
    "DEFAULT_BUCKETS", "Counter", "Family", "Gauge", "Histogram",
    "MetricsRegistry", "Span", "Tracer", "NULL", "NullTelemetry",
    "Telemetry", "resolve", "FedObserver", "scheme_mass",
]
