"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

The quantities the paper's theory cares about (participation rates,
scheme-weight mass, bound terms) and the quantities operations cares
about (span latency, ingest lag, MTTR) are all either monotone counts,
point-in-time values, or latency distributions — the three Prometheus
metric kinds.  This module implements them with zero dependencies beyond
numpy:

  * every metric family lives in a ``MetricsRegistry``; families are
    created idempotently (``registry.counter(name)`` twice returns the
    same object) and re-registration under a different kind or label set
    is an error;
  * locks are striped: metric instances draw their lock from a fixed
    pool instead of allocating one apiece, so a registry with hundreds
    of labeled children costs a handful of lock objects, and no two hot
    counters on different stripes ever contend;
  * histograms are numpy-backed with *fixed* bucket bounds chosen at
    registration: ``observe`` is one ``searchsorted`` + two adds, and
    ``observe_many`` ingests a whole span's worth of per-round samples
    in one vectorized ``bincount`` — the per-round instrumentation path
    (obs/fedmetrics.py) feeds (R, C) matrices through it;
  * ``render_prom()`` emits the Prometheus text exposition (counters as
    ``_total``-suffixed-by-caller names, histograms as cumulative
    ``_bucket{le=...}`` series plus ``_sum``/``_count``), and
    ``snapshot()`` returns the same data as plain dicts for JSONL sinks
    and the ``fed_top`` live view.

Usage::

    reg = MetricsRegistry()
    reg.counter("events_total", "events ingested").inc()
    lat = reg.histogram("span_seconds", "span wall time",
                        labelnames=("name",))
    lat.labels("engine.run_span").observe(0.004)
    print(reg.render_prom())
"""
from __future__ import annotations

import itertools
import math
import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# latency-oriented default bounds (seconds): 50us .. 30s
DEFAULT_BUCKETS = (50e-6, 100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3,
                   10e-3, 25e-3, 50e-3, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                   10.0, 30.0)

# -- lock striping -------------------------------------------------------------
_N_STRIPES = 16
_STRIPES = tuple(threading.Lock() for _ in range(_N_STRIPES))
_stripe_counter = itertools.count()


def _stripe() -> threading.Lock:
    """Hand out locks round-robin from a fixed pool: thread safety without
    one lock object per metric instance."""
    return _STRIPES[next(_stripe_counter) % _N_STRIPES]


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


# -- metric instances ----------------------------------------------------------

class Counter:
    """Monotone float counter."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = _stripe()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up, got inc({v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = _stripe()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram: counts[i] observations with
    v <= bounds[i] (exclusive of lower buckets), counts[-1] the +Inf
    overflow.  numpy-backed so batch observation is vectorized."""
    __slots__ = ("_lock", "bounds", "_counts", "_sum")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        b = tuple(float(x) for x in buckets)
        if not b or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError(f"bucket bounds must be strictly "
                             f"increasing and non-empty, got {buckets}")
        self._lock = _stripe()
        self.bounds = np.asarray(b, np.float64)
        self._counts = np.zeros(len(b) + 1, np.int64)
        self._sum = 0.0

    def observe(self, v: float) -> None:
        i = int(np.searchsorted(self.bounds, v, side="left"))
        with self._lock:
            self._counts[i] += 1
            self._sum += v

    def observe_many(self, vs) -> None:
        """Vectorized batch observe: one searchsorted + bincount for a
        whole array of samples (the per-span instrumentation path)."""
        vs = np.asarray(vs, np.float64).ravel()
        if vs.size == 0:
            return
        idx = np.searchsorted(self.bounds, vs, side="left")
        add = np.bincount(idx, minlength=len(self._counts))
        with self._lock:
            self._counts += add
            self._sum += float(vs.sum())

    @property
    def count(self) -> int:
        with self._lock:
            return int(self._counts.sum())

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def buckets(self):
        """[(upper_bound, cumulative_count), ...] ending with +Inf —
        the Prometheus cumulative form."""
        with self._lock:
            cum = np.cumsum(self._counts)
        bounds = list(self.bounds) + [math.inf]
        return list(zip(bounds, (int(c) for c in cum)))


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric family, optionally labeled.  ``labels(...)``
    returns (creating on first use) the child instance for one label
    combination; unlabeled families have a single anonymous child."""
    __slots__ = ("kind", "name", "help", "labelnames", "buckets",
                 "_lock", "_children")

    def __init__(self, kind: str, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.kind = kind
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets)
        self._lock = _stripe()
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make(self):
        return (Histogram(self.buckets) if self.kind == "histogram"
                else _KINDS[self.kind]())

    def labels(self, *values, **kv):
        if kv:
            values = values + tuple(kv[n] for n in
                                    self.labelnames[len(values):])
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {key}")
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make()
            return child

    def items(self):
        with self._lock:
            return sorted(self._children.items())


class MetricsRegistry:
    """Idempotent family registration + text/dict exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, Family] = {}

    def _register(self, kind: str, name: str, help: str,
                  labelnames: Sequence[str],
                  buckets: Sequence[float]) -> Family:
        labelnames = tuple(labelnames)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.labelnames}, cannot re-register "
                        f"as {kind}{labelnames}")
                return fam
            fam = Family(kind, name, help, labelnames, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()):
        fam = self._register("counter", name, help, labelnames, ())
        return fam if fam.labelnames else fam.labels()

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()):
        fam = self._register("gauge", name, help, labelnames, ())
        return fam if fam.labelnames else fam.labels()

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS):
        fam = self._register("histogram", name, help, labelnames, buckets)
        return fam if fam.labelnames else fam.labels()

    def get(self, name: str) -> Optional[Family]:
        with self._lock:
            return self._families.get(name)

    def families(self):
        with self._lock:
            return sorted(self._families.items())

    # -- exposition -----------------------------------------------------------
    def render_prom(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out = []
        for name, fam in self.families():
            if fam.help:
                out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.items():
                base = ",".join(
                    f'{n}="{_escape_label(v)}"'
                    for n, v in zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    for le, cum in child.buckets():
                        lbl = (base + "," if base else "") + \
                            f'le="{_fmt(le)}"'
                        out.append(f"{name}_bucket{{{lbl}}} {cum}")
                    sfx = f"{{{base}}}" if base else ""
                    out.append(f"{name}_sum{sfx} {child.sum}")
                    out.append(f"{name}_count{sfx} {child.count}")
                else:
                    sfx = f"{{{base}}}" if base else ""
                    out.append(f"{name}{sfx} {_fmt(child.value)}")
        return "\n".join(out) + ("\n" if out else "")

    def snapshot(self) -> dict:
        """Plain-data view of every family — the JSONL metrics sink and
        the ``fed_top`` renderer read this."""
        snap = {}
        for name, fam in self.families():
            samples = []
            for key, child in fam.items():
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    samples.append({
                        "labels": labels, "count": child.count,
                        "sum": child.sum,
                        "buckets": [[b if b != math.inf else "+Inf", c]
                                    for b, c in child.buckets()]})
                else:
                    samples.append({"labels": labels,
                                    "value": child.value})
            snap[name] = {"kind": fam.kind, "help": fam.help,
                          "samples": samples}
        return snap
