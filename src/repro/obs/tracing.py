"""Low-overhead monotonic-clock spans on a bounded ring buffer.

A ``Span`` is a context manager timing one operation::

    with tracer.span("sched.run_span", tau=12, rounds=4):
        ...

Finished spans land on a bounded ring buffer as plain dicts (oldest
evicted first — tracing never grows without bound under a long soak) and
are exported as JSONL.  Spans nest: a thread-local stack records the
active span per thread, so every record carries its parent's id and a
trace can be reassembled into the call tree.  All timestamps come from
``time.monotonic()`` — the same clock source the service supervisor's
heartbeat and the recovery MTTR records use, so span timings and
chaos-report latencies are directly comparable.

The per-span cost is two clock reads, a couple of attribute writes and
one deque append under a lock — cheap enough to leave on in production
spans (the enabled-overhead budget is pinned by
tests/test_telemetry.py).  The *disabled* path never reaches this
module: the null telemetry object returns a shared no-op context
manager instead (obs/telemetry.py).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Callable, List, Optional


class Span:
    """One timed operation; re-entrant use is not supported (make a new
    span per operation — ``Tracer.span`` always does)."""
    __slots__ = ("_tracer", "name", "attrs", "t0", "dur_s", "span_id",
                 "parent_id")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.dur_s = 0.0
        self.span_id = 0
        self.parent_id: Optional[int] = None

    def __enter__(self) -> "Span":
        tr = self._tracer
        stack = tr._stack()
        self.parent_id = stack[-1].span_id if stack else None
        with tr._lock:
            tr._next_id += 1
            self.span_id = tr._next_id
        stack.append(self)
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        self.dur_s = tr.clock() - self.t0
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tr._finish(self)
        return False


class Tracer:
    """Bounded span recorder with nesting and a JSONL exporter."""

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.monotonic,
                 on_finish: Optional[Callable[[str, float], None]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.on_finish = on_finish
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=capacity)
        self._tls = threading.local()
        self._next_id = 0
        self.recorded = 0           # finished spans, lifetime
        self.dropped = 0            # evicted from the ring unobserved

    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def current(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    def _finish(self, span: Span) -> None:
        rec = {"name": span.name, "t0": span.t0,
               "dur_s": span.dur_s, "id": span.span_id,
               "parent": span.parent_id,
               "thread": threading.current_thread().name}
        if span.attrs:
            rec["attrs"] = span.attrs
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(rec)
            self.recorded += 1
        if self.on_finish is not None:
            self.on_finish(span.name, span.dur_s)

    # -- export ---------------------------------------------------------------
    def drain(self) -> List[dict]:
        """Remove and return every buffered span record (oldest first)."""
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
        return out

    def peek(self, n: Optional[int] = None) -> List[dict]:
        """The newest ``n`` buffered records (all when n is None),
        without consuming them."""
        with self._lock:
            out = list(self._buf)
        return out if n is None else out[-n:]

    def export_jsonl(self, path: str, append: bool = True,
                     clear: bool = True) -> int:
        """Write buffered spans as JSONL (one record per line); returns
        the number written."""
        recs = self.drain() if clear else self.peek()
        with open(path, "a" if append else "w") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
        return len(recs)
