"""The `Telemetry` facade and its no-op null twin.

One ``Telemetry`` object threads through every constructor in the
federation stack (engine → scheduler → service → checkpoint → faults).
It owns a :class:`~repro.obs.metrics.MetricsRegistry` and a
:class:`~repro.obs.tracing.Tracer`, and wires them together: every
finished span's duration is also observed into the
``span_seconds{name=...}`` histogram family, so the prom exposition and
the JSONL trace describe the same events.

The default everywhere is :data:`NULL`, a ``NullTelemetry`` whose
metrics are shared no-op singletons and whose ``span()`` returns a
shared no-op context manager — no allocation, no clock reads, no locks.
Tier-1 tests pin that a null-telemetry run is bit-identical to an
uninstrumented one and triggers zero extra recompiles.

Constructors accept ``telemetry=None`` and call :func:`resolve` so the
null default never needs importing at call sites.
"""
from __future__ import annotations

import json
import time
from typing import Optional

from .metrics import DEFAULT_BUCKETS, MetricsRegistry
from .tracing import Tracer


class Telemetry:
    """Live telemetry: metrics registry + span tracer + sinks."""

    enabled = True

    def __init__(self, span_capacity: int = 4096,
                 jax_trace_dir: Optional[str] = None):
        self.registry = MetricsRegistry()
        self._span_seconds = self.registry.histogram(
            "span_seconds", "wall time of traced spans by name",
            labelnames=("name",), buckets=DEFAULT_BUCKETS)
        self.tracer = Tracer(
            capacity=span_capacity,
            on_finish=lambda name, dur:
                self._span_seconds.labels(name).observe(dur))
        # when set, RoundEngine.run_span wraps device dispatch in a
        # jax.profiler trace writing into this directory
        self.jax_trace_dir = jax_trace_dir

    # -- metric / span creation (delegates) -----------------------------------
    def counter(self, name: str, help: str = "", labelnames=()):
        return self.registry.counter(name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()):
        return self.registry.gauge(name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets=DEFAULT_BUCKETS):
        return self.registry.histogram(name, help, labelnames, buckets)

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    # -- sinks ----------------------------------------------------------------
    def render_prom(self) -> str:
        return self.registry.render_prom()

    def write_prom(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.render_prom())

    def export_spans(self, path: str, append: bool = True) -> int:
        """Drain the span ring buffer to a JSONL file."""
        return self.tracer.export_jsonl(path, append=append)

    def dump_jsonl(self, path: str, append: bool = True) -> int:
        """One-stop JSONL sink: buffered spans (``{"kind": "span", ...}``)
        followed by a metrics snapshot (``{"kind": "metric", ...}`` per
        family).  Returns the number of lines written."""
        n = 0
        with open(path, "a" if append else "w") as f:
            for rec in self.tracer.drain():
                f.write(json.dumps({"kind": "span", **rec}) + "\n")
                n += 1
            t = time.monotonic()
            for name, fam in self.registry.snapshot().items():
                f.write(json.dumps(
                    {"kind": "metric", "t": t, "name": name, **fam}) + "\n")
                n += 1
        return n


class _NullMetric:
    """Absorbs every metric call; ``labels()`` returns itself so labeled
    and unlabeled call shapes both no-op."""
    __slots__ = ()

    def inc(self, v: float = 1.0) -> None: pass
    def dec(self, v: float = 1.0) -> None: pass
    def set(self, v: float) -> None: pass
    def observe(self, v: float) -> None: pass
    def observe_many(self, vs) -> None: pass
    def labels(self, *a, **kw): return self
    value = 0.0
    count = 0
    sum = 0.0
    def buckets(self): return []


class _NullSpan:
    """Shared no-op context manager; also quacks like a Span."""
    __slots__ = ()
    name = ""
    dur_s = 0.0
    attrs: dict = {}

    def __enter__(self): return self
    def __exit__(self, *exc): return False


_NULL_METRIC = _NullMetric()
_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """Telemetry that does nothing — the default for every constructor.

    Shares the ``Telemetry`` call surface so instrumented code never
    branches on enablement; the few sites that must branch (e.g. to skip
    building an attrs dict) check ``telemetry.enabled``.
    """

    enabled = False
    registry = None
    tracer = None
    jax_trace_dir = None

    def counter(self, name: str, help: str = "", labelnames=()):
        return _NULL_METRIC

    def gauge(self, name: str, help: str = "", labelnames=()):
        return _NULL_METRIC

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets=DEFAULT_BUCKETS):
        return _NULL_METRIC

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def render_prom(self) -> str:
        return ""

    def write_prom(self, path: str) -> None:
        pass

    def export_spans(self, path: str, append: bool = True) -> int:
        return 0

    def dump_jsonl(self, path: str, append: bool = True) -> int:
        return 0


NULL = NullTelemetry()


def resolve(telemetry) -> "Telemetry | NullTelemetry":
    """``None`` → the shared null singleton; anything else passes through."""
    return NULL if telemetry is None else telemetry
