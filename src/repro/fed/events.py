"""Typed participation events + their wire/checkpoint codec.

The event model is the control plane's vocabulary (see docs/streaming.md):

  * Arrival       — a device joins at round tau (brand-new ``Client``
                    payload, or a ``client_id`` re-activation);
  * Departure     — a device leaves (paper §4.3 include/exclude/auto);
  * TraceShift    — a device's availability law changes;
  * InactivityBurst — a cohort goes dark for a window (correlated
                    unavailability) but keeps its weight mass.

Every event (and the Client payload an Arrival may carry) round-trips
through ``event_to_dict``/``event_from_dict``: plain dicts of scalars,
strings and numpy arrays — the representation FedState.to_dict embeds,
checkpoint/io persists, and the fed_serve JSONL trace format reuses.
Array fields stay numpy arrays in the dict; the checkpoint layer extracts
them into the npz (see checkpoint/io.jsonify_tree).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.participation import TRACES, Trace
from repro.fed.driver import Client


@dataclass(frozen=True)
class Arrival:
    """A device joins training at round tau.

    Either ``client`` is a brand-new Client (constructed after the engine
    was built; admitted into a free capacity slot), or ``client_id``
    references an already-registered client (activation only — the path
    the FederatedTrainer adapter uses for precomputed schedules).
    """
    tau: int
    client: Optional[Client] = None
    client_id: Optional[int] = None
    fast_reboot: Optional[bool] = None   # None => scheduler default


@dataclass(frozen=True)
class Departure:
    """A device leaves at round tau.  policy: include | exclude | auto
    (Corollary 4.0.3 remaining-time criterion); None uses the client's
    own departure_policy."""
    tau: int
    client_id: int
    policy: Optional[str] = None


@dataclass(frozen=True)
class TraceShift:
    """A client's availability law changes at round tau (e.g. a device
    moves from charger+wifi to battery+cellular)."""
    tau: int
    client_id: int
    trace: Trace


@dataclass(frozen=True)
class InactivityBurst:
    """A cohort goes dark for ``duration`` rounds starting at tau
    (correlated unavailability: a regional outage, a synchronized OS
    update).  Masked clients stay in the objective — their weight mass is
    unchanged — but contribute s = 0 until the burst expires."""
    tau: int
    duration: int
    client_ids: Tuple[int, ...]


ParticipationEvent = Union[Arrival, Departure, TraceShift, InactivityBurst]


# -- codec --------------------------------------------------------------------

_TRACE_BY_NAME = {t.name: t for t in TRACES}


def trace_to_dict(trace: Trace) -> dict:
    return {"name": trace.name, "mean": trace.mean,
            "stdev": trace.stdev, "p_inactive": trace.p_inactive}


def trace_from_dict(d: dict) -> Trace:
    # interned Table-2 traces come back as the canonical object (value-
    # equal anyway, but identity keeps repr/logs tidy); custom laws
    # reconstruct from their moments
    t = _TRACE_BY_NAME.get(d["name"])
    if t is not None and (t.mean, t.stdev, t.p_inactive) == \
            (d["mean"], d["stdev"], d["p_inactive"]):
        return t
    return Trace(d["name"], d["mean"], d["stdev"], d["p_inactive"])


def _opt_array(a):
    return None if a is None else np.asarray(a)


def client_to_dict(c: Client) -> dict:
    return {
        "x": np.asarray(c.x),
        "y": _opt_array(c.y),
        "trace": None if c.trace is None else trace_to_dict(c.trace),
        "x_test": _opt_array(c.x_test),
        "y_test": _opt_array(c.y_test),
        "active_from": c.active_from,
        "departs_at": c.departs_at,
        "departure_policy": c.departure_policy,
        "gamma_l": c.gamma_l,
    }


def client_from_dict(d: dict) -> Client:
    return Client(
        x=np.asarray(d["x"]), y=_opt_array(d.get("y")),
        trace=None if d.get("trace") is None
        else trace_from_dict(d["trace"]),
        x_test=_opt_array(d.get("x_test")),
        y_test=_opt_array(d.get("y_test")),
        active_from=int(d.get("active_from", 0)),
        departs_at=d.get("departs_at"),
        departure_policy=d.get("departure_policy", "exclude"),
        gamma_l=float(d.get("gamma_l", 1.0)))


def event_to_dict(e: ParticipationEvent) -> dict:
    if isinstance(e, Arrival):
        return {"kind": "arrival", "tau": e.tau,
                "client": None if e.client is None
                else client_to_dict(e.client),
                "client_id": e.client_id, "fast_reboot": e.fast_reboot}
    if isinstance(e, Departure):
        return {"kind": "departure", "tau": e.tau,
                "client_id": e.client_id, "policy": e.policy}
    if isinstance(e, TraceShift):
        return {"kind": "trace-shift", "tau": e.tau,
                "client_id": e.client_id, "trace": trace_to_dict(e.trace)}
    if isinstance(e, InactivityBurst):
        return {"kind": "burst", "tau": e.tau, "duration": e.duration,
                "client_ids": list(e.client_ids)}
    raise TypeError(f"unknown participation event {e!r}")


def event_from_dict(d: dict) -> ParticipationEvent:
    kind = d["kind"]
    tau = int(d["tau"])
    if kind == "arrival":
        return Arrival(tau,
                       client=None if d.get("client") is None
                       else client_from_dict(d["client"]),
                       client_id=d.get("client_id"),
                       fast_reboot=d.get("fast_reboot"))
    if kind == "departure":
        return Departure(tau, client_id=int(d["client_id"]),
                         policy=d.get("policy"))
    if kind == "trace-shift":
        return TraceShift(tau, client_id=int(d["client_id"]),
                          trace=trace_from_dict(d["trace"]))
    if kind == "burst":
        return InactivityBurst(tau, duration=int(d["duration"]),
                               client_ids=tuple(int(i)
                                                for i in d["client_ids"]))
    raise ValueError(f"unknown event kind {kind!r}")
