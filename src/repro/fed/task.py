"""ClientTask: the model/step layer behind the federation engine.

The paper's flexible-participation schemes (incomplete updates, arrivals,
departures) are model-agnostic, but the engine used to hard-wire the
logreg workload: ``(C, Nmax, d)`` feature / ``(C, Nmax)`` label buffers
and an ``{"x", "y"}`` batch dict.  This module factors everything
model-specific behind one small protocol, so the *same* RoundEngine /
StreamScheduler / FederatedTrainer machinery federates anything from the
paper's logistic regression to the >=30B architectures in ``models/``:

  * which per-sample arrays a client contributes (``buffers``),
  * how a gathered batch is presented to the loss (``make_batch``),
  * the loss itself (``loss_fn``),
  * parameter init (``init_params``) and — for sharded large models —
    per-leaf PartitionSpecs (``param_specs``: ``None`` replicates, the
    small-model path; a spec tree keeps params sharded FSDP x TP over the
    mesh's model axes while the federation axes carry clients/batches).

Two implementations ship:

  * :class:`ArrayTask` — feature/label pairs for the paper models
    (``models/small.py``); the engine builds one automatically from a
    bare ``loss_fn=`` for backward compatibility.
  * :class:`LMTask` — next-token prediction for any assigned
    ``ArchConfig`` (``models/transformer.py``): clients hold token
    streams ``(n, S+1)``, batches slice tokens/labels on the fly, and
    params carry ``models.sharding.tree_param_specs`` so a federated
    round composes with the model-parallel mesh axes.

Usage::

    task = LMTask(get_config("mamba2-130m").reduced(), seq_len=64)
    clients = [Client(x=task.token_stream(rng, n=40, domain=d),
                      trace=TRACES[d]) for d in range(4)]
    eng = RoundEngine(task=task, clients=clients, local_epochs=2,
                      batch_size=2, mode="client_sequential")
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["BufferSpec", "ClientTask", "ArrayTask", "LMTask"]


@dataclass(frozen=True)
class BufferSpec:
    """One per-sample device-resident buffer: the engine stores it as a
    ``(capacity, Nmax) + shape`` stack of the given dtype."""
    shape: Tuple[int, ...]
    dtype: Any = np.float32


class ClientTask:
    """Protocol (duck-typed base) between the federation engine and a
    model family.  Subclasses define:

    buffers          — dict name -> BufferSpec of per-sample arrays.
    loss_fn(p, b)    — scalar training loss on one batch.
    client_arrays(c) — dict name -> (n, *spec.shape) arrays for a Client.
    make_batch(g)    — map gathered buffers (each (..., B) + spec.shape,
                       any leading dims) to the loss_fn batch pytree.
    init_params(key) — fresh parameter pytree.
    param_specs(p)   — pytree of PartitionSpec matching params, or None
                       to replicate (small models).  Specs may name mesh
                       axes that don't exist on a given mesh; they are
                       filtered per-mesh at placement time.
    """

    buffers: Dict[str, BufferSpec] = {}

    def loss_fn(self, params, batch):
        raise NotImplementedError

    def client_arrays(self, client) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def make_batch(self, gathered: Dict[str, Any]):
        return gathered

    def init_params(self, key):
        raise NotImplementedError

    def param_specs(self, params):
        return None


class ArrayTask(ClientTask):
    """Feature/label clients for the paper's small models — the layout the
    engine used before the ClientTask refactor, now one implementation of
    it.  ``loss_fn(params, {"x": ..., "y": ...})``; params replicated."""

    def __init__(self, loss_fn, feature_shape: Tuple[int, ...], *,
                 init_fn=None, label_dtype=np.int32):
        self._loss_fn = loss_fn
        self._init_fn = init_fn
        self.buffers = {"x": BufferSpec(tuple(feature_shape), np.float32),
                        "y": BufferSpec((), label_dtype)}

    def loss_fn(self, params, batch):
        return self._loss_fn(params, batch)

    def client_arrays(self, client):
        return {"x": np.asarray(client.x, np.float32),
                "y": np.asarray(client.y,
                                self.buffers["y"].dtype)}

    def init_params(self, key):
        if self._init_fn is None:
            raise NotImplementedError("ArrayTask built without init_fn")
        return self._init_fn(key)


class LMTask(ClientTask):
    """Next-token prediction over an assigned architecture: the large-
    model federation path.

    Clients hold raw token streams shaped ``(n, seq_len + 1)`` (append
    ``(K,)`` codebooks for audio archs) in ``Client.x``; a training batch
    slices ``tokens = t[..., :-1]`` / ``labels = t[..., 1:]`` on device,
    so one int32 buffer per client serves both sides of the shift.
    ``param_specs`` comes from the model's partition-rule table
    (``tree_param_specs``), so under a composite mesh the federated round
    leaves params sharded FSDP x TP (never replicated) while the
    federation axes carry the client/batch dims — see docs/scaling.md.
    """

    def __init__(self, cfg, *, seq_len: int = 128, fsdp: bool = True):
        self.cfg = cfg
        self.seq_len = int(seq_len)
        self.fsdp = fsdp
        tail: Tuple[int, ...] = (self.seq_len + 1,)
        if cfg.n_codebooks:
            tail = tail + (cfg.n_codebooks,)
        self.buffers = {"tokens": BufferSpec(tail, np.int32)}

    # -- engine protocol ------------------------------------------------------
    def loss_fn(self, params, batch):
        from repro.models import transformer
        return transformer.train_loss(params, self.cfg, batch)

    def client_arrays(self, client):
        t = np.asarray(client.x, np.int32)
        want = self.buffers["tokens"].shape
        if t.shape[1:] != want:
            raise ValueError(f"client token stream shaped {t.shape[1:]}, "
                             f"task expects {want} (seq_len+1[, K])")
        return {"tokens": t}

    def make_batch(self, gathered):
        t = gathered["tokens"]
        # the seq axis sits before the codebook axis for audio archs
        ax = t.ndim - 2 if self.cfg.n_codebooks else t.ndim - 1
        sl = [slice(None)] * t.ndim
        sl[ax] = slice(None, -1)
        tokens = t[tuple(sl)]
        sl[ax] = slice(1, None)
        labels = t[tuple(sl)]
        return {"tokens": tokens, "labels": labels}

    def init_params(self, key):
        from repro.models.params import init_params
        return init_params(key, self.cfg)

    def param_specs(self, params):
        from repro.models.sharding import tree_param_specs
        return tree_param_specs(params, fsdp=self.fsdp)

    # -- client construction helpers ------------------------------------------
    def token_stream(self, rng: np.random.Generator, *, n: int,
                    domain: int = 0, zipf_a: float = 1.2) -> np.ndarray:
        """A client's dataset: ``n`` sequences of ``seq_len + 1`` tokens
        from the synthetic non-IID Zipf stream (``data/tokens.py``) —
        clients sharing a ``domain`` share token statistics."""
        from repro.data.tokens import client_token_stream
        K = max(1, self.cfg.n_codebooks)
        flat = client_token_stream(rng, self.cfg.vocab, domain,
                                   n * (self.seq_len + 1) * K,
                                   zipf_a=zipf_a)
        return flat.reshape((n,) + self.buffers["tokens"].shape)
