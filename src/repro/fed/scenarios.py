"""Scenario library: reproducible participation-event streams.

Each generator composes ParticipationEvents into a named workload and is a
pure function of its seed — the same (name, seed, size knobs) always
yields the identical client fleet and event stream, so scenarios are
usable both as benchmarks (benchmarks/stream_bench.py) and as regression
fixtures (tests/test_stream.py).

  diurnal      availability waves: the fleet splits into two "timezones"
               whose traces swing between high- and low-availability laws
               every half period (TraceShift waves).
  flash-crowd  a burst of brand-new devices arrives over a few rounds,
               trains for a while, then churns out (Arrivals + Departures
               through capacity slots).
  staggered    staggered-cohort rollout: cohort k of brand-new devices
               arrives at k * spacing (a product launch ramp).
  churn        correlated churn: recurring InactivityBursts over random
               cohorts plus occasional departures and replacement
               arrivals.

``run_scenario`` builds a StreamScheduler on the paper's SYNTHETIC logreg
workload, replays the stream end-to-end and returns an honest summary —
non-eval rounds record NaN loss/acc (see RoundRecord), and
``summarize_history`` filters them the same way benchmarks/paper_tables
does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.participation import TRACES, Trace
from repro.fed.driver import Client, RoundRecord
from repro.fed.stream import (Arrival, Departure, InactivityBurst,
                              ParticipationEvent, TraceShift)

# high-availability (charger+wifi) vs low-availability (contended) laws
# used by the diurnal wave; indices into the Table-2 reconstruction
_DAY_TRACE = TRACES[1]      # cpu_30: mean 0.90
_NIGHT_TRACE = TRACES[6]    # bw_med: mean 0.65, 20% inactive


@dataclass
class Scenario:
    """A named, fully reproducible streaming-participation workload."""
    name: str
    clients: List[Client]                    # founding fleet (slots 0..C-1)
    events: List[ParticipationEvent]
    capacity: int
    n_rounds: int
    eval_every: int = 5
    local_epochs: int = 5
    batch_size: int = 10
    scheme: str = "C"
    eta0: float = 1.0
    seed: int = 0
    max_samples: Optional[int] = None
    notes: str = ""

    def signature(self) -> list:
        """Structural fingerprint used by reproducibility tests: event
        types/taus/targets without array payloads."""
        sig = []
        for e in self.events:
            if isinstance(e, Arrival):
                sig.append(("arrival", e.tau,
                            e.client.n if e.client is not None
                            else e.client_id))
            elif isinstance(e, Departure):
                sig.append(("departure", e.tau, e.client_id, e.policy))
            elif isinstance(e, TraceShift):
                sig.append(("trace-shift", e.tau, e.client_id,
                            e.trace.name))
            elif isinstance(e, InactivityBurst):
                sig.append(("burst", e.tau, e.duration, e.client_ids))
        return sig


def _make_clients(n: int, seed: int, trace_pool=range(8),
                  alpha: float = 0.5, beta: float = 0.5) -> List[Client]:
    from repro.data import synthetic_federation
    train, test = synthetic_federation(alpha, beta, n, seed=seed)
    rng = np.random.default_rng(seed)
    pool = list(trace_pool)
    return [Client(x=tr[0], y=tr[1],
                   trace=TRACES[pool[rng.integers(0, len(pool))]],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


# -- generators ---------------------------------------------------------------

def diurnal(*, n_clients: int = 8, n_rounds: int = 32, period: int = 8,
            seed: int = 0) -> Scenario:
    """Two timezones in anti-phase: every half period, one half of the
    fleet shifts to the day law and the other to the night law."""
    clients = _make_clients(n_clients, seed, trace_pool=[1])
    half = max(1, period // 2)
    zone_a = list(range(0, n_clients, 2))
    zone_b = list(range(1, n_clients, 2))
    events: List[ParticipationEvent] = []
    for k, tau in enumerate(range(half, n_rounds, half)):
        day, night = (zone_a, zone_b) if k % 2 == 0 else (zone_b, zone_a)
        for i in night:
            events.append(TraceShift(tau, i, _NIGHT_TRACE))
        for i in day:
            events.append(TraceShift(tau, i, _DAY_TRACE))
    return Scenario("diurnal", clients, events, capacity=n_clients,
                    n_rounds=n_rounds, seed=seed,
                    notes=f"{n_clients} clients, period {period}")


def flash_crowd(*, n_founding: int = 6, n_crowd: int = 6,
                arrive_at: int = 6, stay: int = 10, n_rounds: int = 28,
                seed: int = 0) -> Scenario:
    """A crowd of brand-new devices floods in over three rounds, trains
    for ``stay`` rounds, then churns out (exclude policy)."""
    clients = _make_clients(n_founding, seed)
    crowd = _make_clients(n_crowd, seed + 1000)
    nmax = max(c.n for c in clients + crowd)
    events: List[ParticipationEvent] = []
    taus_in = [arrive_at + j % 3 for j in range(n_crowd)]  # 3-round stagger
    # ids are assigned when the Arrival is *applied*, i.e. in (tau, push
    # order) sequence — compute each crowd member's id accordingly
    order = sorted(range(n_crowd), key=lambda j: (taus_in[j], j))
    id_of = {j: n_founding + r for r, j in enumerate(order)}
    for j, cl in enumerate(crowd):
        events.append(Arrival(taus_in[j], client=cl))
        events.append(Departure(taus_in[j] + stay, client_id=id_of[j],
                                policy="exclude"))
    return Scenario("flash-crowd", clients, events,
                    capacity=n_founding + n_crowd, n_rounds=n_rounds,
                    seed=seed, max_samples=nmax,
                    notes=f"{n_founding}+{n_crowd} clients, "
                          f"crowd at tau={arrive_at}")


def staggered_rollout(*, n_cohorts: int = 3, cohort_size: int = 3,
                      spacing: int = 6, n_rounds: int = 26,
                      seed: int = 0) -> Scenario:
    """Cohort 0 is founding; cohort k of brand-new devices arrives at
    k * spacing (a staged product rollout)."""
    clients = _make_clients(cohort_size, seed)
    events: List[ParticipationEvent] = []
    nmax = max(c.n for c in clients)
    for k in range(1, n_cohorts):
        cohort = _make_clients(cohort_size, seed + 1000 * k)
        nmax = max(nmax, max(c.n for c in cohort))
        for cl in cohort:
            events.append(Arrival(k * spacing, client=cl))
    return Scenario("staggered", clients, events,
                    capacity=n_cohorts * cohort_size, n_rounds=n_rounds,
                    seed=seed, max_samples=nmax,
                    notes=f"{n_cohorts} cohorts x {cohort_size}, "
                          f"spacing {spacing}")


def correlated_churn(*, n_clients: int = 10, n_rounds: int = 30,
                     burst_every: int = 7, burst_frac: float = 0.4,
                     burst_len: int = 3, seed: int = 0) -> Scenario:
    """Recurring correlated outages (InactivityBursts over random cohorts)
    plus one auto-policy departure and one replacement arrival."""
    clients = _make_clients(n_clients, seed)
    rng = np.random.default_rng(seed + 7)
    events: List[ParticipationEvent] = []
    k = max(1, int(round(burst_frac * n_clients)))
    for tau in range(burst_every, n_rounds, burst_every):
        cohort = tuple(sorted(rng.choice(n_clients, size=k,
                                         replace=False).tolist()))
        events.append(InactivityBurst(tau, burst_len, cohort))
    # one device departs mid-run under the Corollary-4.0.3 auto policy...
    leaver = int(rng.integers(0, n_clients))
    events.append(Departure(n_rounds // 2, client_id=leaver,
                            policy="auto"))
    # ...and a replacement (brand-new data) arrives shortly after,
    # reusing the freed capacity slot when the departure excluded
    repl = _make_clients(1, seed + 2000)[0]
    events.append(Arrival(n_rounds // 2 + 2, client=repl))
    nmax = max(max(c.n for c in clients), repl.n)
    return Scenario("churn", clients, events, capacity=n_clients + 1,
                    n_rounds=n_rounds, seed=seed, max_samples=nmax,
                    notes=f"{n_clients} clients, burst every "
                          f"{burst_every} for {burst_len}")


def rotation(*, fleet: int = 40, hot: int = 12, dwell: int = 2,
             n_rounds: int = 60, seed: int = 0) -> Scenario:
    """A fleet far larger than the hot-slot capacity rotates through the
    engine: every ``dwell`` rounds the oldest resident departs (include
    policy — its data mass stays in the objective, MIFA-style) and the
    next fleet member arrives, first as a brand-new payload, then as a
    client_id rejoin once everyone has been seen.  At most ``hot``
    clients are resident at any time, so the scenario runs on ``hot``
    capacity slots backed by the client bank — and, because slot
    allocation is lowest-free-first, a run with capacity >= fleet
    assigns the *same* slots, making the two bit-comparable
    (tests/test_bank.py)."""
    from collections import deque

    all_clients = _make_clients(fleet, seed)
    clients = all_clients[:hot]
    events: List[ParticipationEvent] = []
    resident = deque(range(hot))
    departed_q: deque = deque()
    # first-time arrivals get ids in application order: hot, hot+1, ...
    next_new = hot
    for tau in range(dwell, n_rounds, dwell):
        old = resident.popleft()
        events.append(Departure(tau, client_id=old, policy="include"))
        departed_q.append(old)
        if next_new < fleet:
            events.append(Arrival(tau, client=all_clients[next_new]))
            resident.append(next_new)
            next_new += 1
        else:
            back = departed_q.popleft()
            events.append(Arrival(tau, client_id=back))
            resident.append(back)
    nmax = max(c.n for c in all_clients)
    return Scenario("rotation", clients, events, capacity=hot,
                    n_rounds=n_rounds, seed=seed, max_samples=nmax,
                    notes=f"fleet {fleet} through {hot} hot slots, "
                          f"dwell {dwell}")


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "diurnal": diurnal,
    "flash-crowd": flash_crowd,
    "staggered": staggered_rollout,
    "churn": correlated_churn,
    "rotation": rotation,
}


def make_scenario(name: str, *, seed: int = 0, **kwargs) -> Scenario:
    key = name.replace("_", "-")
    if key not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(SCENARIOS)}")
    return SCENARIOS[key](seed=seed, **kwargs)


# -- execution + honest summaries ---------------------------------------------

def build_scheduler(sc: Scenario, *, mode: str = "device",
                    chunk_size: int = 16, agg: str = "auto",
                    interpret=None, compression=None,
                    with_metrics: bool = False, telemetry=None,
                    engine_mode: str = "client_parallel",
                    capacity: Optional[int] = None,
                    bank=None, prefetch: bool = False):
    """StreamScheduler for a scenario on the paper's SYNTHETIC logreg.
    ``bank=``/``prefetch=`` enable the tiered client store and the
    double-buffered cohort prefetch (fed/bank.py); ``capacity=``
    overrides the scenario's hot-slot count (fleet-beyond-capacity
    runs keep the overflow in the bank)."""
    import jax

    from repro.configs.paper import SYNTHETIC_LR
    from repro.fed.stream import StreamScheduler
    from repro.models.small import init_small, make_loss_fn

    return StreamScheduler(
        clients=sc.clients, init_params=init_small(
            jax.random.PRNGKey(sc.seed), SYNTHETIC_LR),
        loss_fn=make_loss_fn(SYNTHETIC_LR), eval_fn=_paper_eval_fn(),
        capacity=capacity if capacity is not None else sc.capacity,
        max_samples=sc.max_samples,
        local_epochs=sc.local_epochs, batch_size=sc.batch_size,
        scheme=sc.scheme, eta0=sc.eta0, chunk_size=chunk_size, agg=agg,
        interpret=interpret, compression=compression,
        with_metrics=with_metrics, seed=sc.seed,
        mode=mode, events=sc.events, telemetry=telemetry,
        engine_mode=engine_mode, bank=bank, prefetch=prefetch)


def _paper_eval_fn():
    import jax
    import jax.numpy as jnp

    from repro.configs.paper import SYNTHETIC_LR
    from repro.models.small import logits_small

    def eval_fn(params, x, y):
        lg = logits_small(params, SYNTHETIC_LR, x)
        ll = jax.nn.log_softmax(lg)
        loss = -jnp.mean(jnp.take_along_axis(
            ll, y[:, None].astype(jnp.int32), axis=1))
        acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
        return float(loss), float(acc)

    return eval_fn


def summarize_history(history: Sequence[RoundRecord]) -> dict:
    """History consumers must filter NaN rounds (RoundRecord.loss/acc are
    NaN whenever no eval ran — the honest-records contract, same as
    benchmarks/paper_tables._run)."""
    evald = [h for h in history if np.isfinite(h.loss)]
    return {
        "rounds": len(history),
        "evals": len(evald),
        "final_loss": float(evald[-1].loss) if evald else None,
        "final_acc": float(evald[-1].acc) if evald else None,
        "best_acc": max((float(h.acc) for h in evald), default=None),
        "mean_active": (float(np.mean([h.n_active for h in history]))
                        if history else 0.0),
        "events": [(h.tau, h.event) for h in history if h.event],
    }


def run_scenario(sc: Scenario, *, mode: str = "device",
                 eval_every: Optional[int] = None,
                 n_rounds: Optional[int] = None, **kw):
    """Replay a scenario end-to-end; returns (scheduler, summary)."""
    sch = build_scheduler(sc, mode=mode, **kw)
    sch.run(n_rounds if n_rounds is not None else sc.n_rounds,
            eval_every if eval_every is not None else sc.eval_every)
    summary = summarize_history(sch.history)
    summary["scenario"] = sc.name
    summary["notes"] = sc.notes
    summary["events_applied"] = sch.events_applied
    summary["capacity"] = sc.capacity
    summary["clients_end"] = len(sch.clients)
    return sch, summary
