"""Host-level federated round driver.

Implements the full paper protocol around the jitted round step:
  * per-round participation sampling from device traces (alpha masks),
  * Scheme A/B/C aggregation coefficients,
  * arrivals with objective shift + fast-reboot (coefficient boost + LR
    restart, §4.2),
  * departures with include/exclude applicability decision (§4.3),
  * membership is handled by masking (alpha=0, coeff=0), so the compiled
    round step never recompiles as devices come and go.

Execution delegates to the device-resident RoundEngine (fed/engine.py):
client data lives on device once and R rounds run per host dispatch via a
chunked, donated lax.scan.  Three modes:

  engine="plan"   (default) participation/batch indices are sampled with
                  the host numpy RNG in the seed order (sample-for-sample
                  identical to the legacy loop) but every round runs on
                  device; spans break at events and eval rounds.
  engine="device" fully fused on-device jax.random sampling — the fast
                  path; statistically equivalent to "plan".
  engine="host"   the seed per-round host loop (reference for parity
                  tests and benchmarks).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import scheme_coefficients
from repro.core.arrivals import RebootState, staircase_lr
from repro.core.departures import BoundTerms, should_exclude
from repro.core.fed_step import make_fed_round
from repro.core.participation import Trace
from repro.fed.engine import RoundEngine


@dataclass
class Client:
    """One federated device: per-sample arrays + availability trace.

    ``x`` holds whatever the active ClientTask stores per sample — feature
    rows for the paper models, token sequences ``(n, S+1)`` for the LM
    path (``y``/test arrays stay None there)."""
    x: np.ndarray
    y: Optional[np.ndarray] = None
    trace: Trace = None
    x_test: Optional[np.ndarray] = None
    y_test: Optional[np.ndarray] = None
    # membership
    active_from: int = 0          # round the device joins (0 = founding)
    departs_at: Optional[int] = None
    departure_policy: str = "exclude"   # exclude | include | auto
    gamma_l: float = 1.0          # non-IID estimate used by policy "auto"

    @property
    def n(self) -> int:
        return len(self.y) if self.y is not None else len(self.x)


@dataclass
class RoundRecord:
    tau: int
    loss: float     # NaN on rounds where no eval ran (honest records)
    acc: float      # NaN on rounds where no eval ran
    eta: float
    n_active: int
    s: np.ndarray
    event: str = ""


class FederatedTrainer:
    def __init__(self, *, loss_fn: Optional[Callable] = None,
                 eval_fn: Optional[Callable] = None,
                 init_params, clients: List[Client], local_epochs: int = 5,
                 batch_size: int = 10, scheme: str = "C", eta0: float = 0.01,
                 reboot_boost: float = 3.0, fast_reboot: bool = True,
                 horizon: Optional[int] = None,
                 bound_terms: Optional[BoundTerms] = None,
                 seed: int = 0, engine: Optional[str] = "plan",
                 chunk_size: int = 16, agg: str = "auto",
                 interpret=None, donate: Optional[bool] = None,
                 compression=None, with_metrics: bool = False,
                 sharding=None, task=None,
                 mode: str = "client_parallel"):
        self.task = task
        self.mode = mode
        if loss_fn is None:
            if task is None:
                raise ValueError("pass loss_fn= (or a task= that carries "
                                 "one)")
            loss_fn = task.loss_fn
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn  # eval_fn(params, x, y) -> (loss, acc)
        self.params = init_params
        self.clients = clients
        self.E = local_epochs
        self.B = batch_size
        self.scheme = scheme
        self.eta0 = eta0
        self.reboot_boost = reboot_boost
        self.fast_reboot = fast_reboot
        # Corollary 4.0.3 inputs for departure_policy == "auto": the
        # training deadline T and the fitted Theorem-3.1 bound terms
        self.horizon = horizon
        self.bound_terms = bound_terms or BoundTerms(
            D=5.0, V=20.0, gamma=10.0, E=local_epochs)
        self.rng = np.random.default_rng(seed)
        from repro.core.compression import resolve_compression
        self.compression = resolve_compression(compression)
        self.round_fn = jax.jit(make_fed_round(
            loss_fn, "client_parallel", compression=self.compression))
        self.engine_mode = engine if engine not in (None, "off") else "host"
        if self.engine_mode not in ("host", "plan", "device"):
            raise ValueError(f"engine must be one of host|plan|device|off, "
                             f"got {engine!r}")
        self.chunk_size = chunk_size
        self.agg = agg
        self.interpret = interpret
        self.donate = donate
        self.with_metrics = with_metrics
        self.sharding = sharding
        self._engine: Optional[RoundEngine] = None
        self._scheduler = None
        self._key = jax.random.PRNGKey(seed)
        # membership bookkeeping
        self.objective: set = {i for i, c in enumerate(clients)
                               if c.active_from == 0}
        self.reboots: List[RebootState] = []
        self.lr_shift_tau = 0
        self.history: List[RoundRecord] = []
        self._next_tau = 0

    @property
    def engine(self) -> RoundEngine:
        if self._engine is None:
            self._engine = RoundEngine(
                loss_fn=None if self.task is not None else self.loss_fn,
                task=self.task, clients=self.clients,
                local_epochs=self.E, batch_size=self.B, scheme=self.scheme,
                eta0=self.eta0, chunk_size=self.chunk_size, agg=self.agg,
                interpret=self.interpret, donate=self.donate,
                compression=self.compression,
                with_metrics=self.with_metrics, sharding=self.sharding,
                mode=self.mode)
        return self._engine

    # -- weights over the current objective set -----------------------------
    def data_weights(self) -> np.ndarray:
        p = np.zeros(len(self.clients))
        total = sum(self.clients[i].n for i in self.objective)
        for i in self.objective:
            p[i] = self.clients[i].n / total
        return p

    def _participating(self, i: int, tau: int) -> bool:
        cl = self.clients[i]
        return (i in self.objective and tau >= cl.active_from
                and (cl.departs_at is None or tau < cl.departs_at))

    def _sample_plan(self, tau: int):
        """One round of host-RNG sampling: alpha (C, E) and batch indices
        idx (C, E, B).  Draw order matches the seed loop exactly, so a
        given numpy seed yields the identical sample stream."""
        C = len(self.clients)
        alpha = np.zeros((C, self.E), np.float32)
        idx = np.zeros((C, self.E, self.B), np.int64)
        for i, cl in enumerate(self.clients):
            if not self._participating(i, tau):
                continue
            alpha[i] = (np.arange(self.E)
                        < cl.trace.sample_s(self.rng, self.E)
                        ).astype(np.float32)
            idx[i] = self.rng.integers(0, cl.n, size=(self.E, self.B))
        return alpha, idx

    def _sample_round(self, tau: int):
        alpha, idx = self._sample_plan(tau)
        C = len(self.clients)
        xdim = self.clients[0].x.shape[1:]
        bx = np.zeros((C, self.E, self.B, *xdim), np.float32)
        by = np.zeros((C, self.E, self.B), np.int32)
        for i, cl in enumerate(self.clients):
            if self._participating(i, tau):
                bx[i] = cl.x[idx[i]]
                by[i] = cl.y[idx[i]]
        return alpha, {"x": bx, "y": by}

    # -- events --------------------------------------------------------------
    def _handle_events(self, tau: int) -> str:
        ev = ""
        for i, cl in enumerate(self.clients):
            if cl.active_from == tau and i not in self.objective:
                # arrival: mandatory objective shift (+ optional fast-reboot)
                self.objective.add(i)
                self.lr_shift_tau = tau
                if self.fast_reboot:
                    self.reboots.append(RebootState(tau, i,
                                                    self.reboot_boost))
                ev += f"arrival:{i};"
            if cl.departs_at == tau and i in self.objective:
                policy = cl.departure_policy
                if policy == "auto":
                    # Corollary 4.0.3: exclude iff enough training remains
                    T = self.horizon if self.horizon is not None \
                        else tau + 100
                    policy = "exclude" if should_exclude(
                        T, tau, self.bound_terms, cl.gamma_l) else "include"
                if policy == "exclude":
                    self.objective.discard(i)
                    self.lr_shift_tau = tau
                    ev += f"departure-exclude:{i};"
                else:
                    ev += f"departure-include:{i};"
        return ev

    # -- main loop ------------------------------------------------------------
    def run(self, n_rounds: int, eval_every: int = 1):
        if self.engine_mode == "host":
            return self._run_host(n_rounds, eval_every)
        return self._run_engine(n_rounds, eval_every)

    def _run_host(self, n_rounds: int, eval_every: int = 1):
        """The seed per-round host loop (reference path)."""
        start = self._next_tau
        for tau in range(start, start + n_rounds):
            ev = self._handle_events(tau)
            p = self.data_weights()
            alpha, batches = self._sample_round(tau)
            s = alpha.sum(axis=1)
            coeffs = np.array(scheme_coefficients(
                self.scheme, jnp.asarray(p), jnp.asarray(s), self.E))
            for rb in self.reboots:
                coeffs[rb.client_idx] *= rb.coeff_multiplier(tau)
            eta = staircase_lr(self.eta0, tau + 1, self.lr_shift_tau)
            self.params, _m = self.round_fn(
                self.params,
                {"x": jnp.asarray(batches["x"]),
                 "y": jnp.asarray(batches["y"])},
                jnp.asarray(alpha), jnp.asarray(coeffs),
                jnp.float32(eta))
            loss = acc = float("nan")
            if tau % eval_every == 0 or ev:
                loss, acc = self.evaluate()
            self.history.append(RoundRecord(tau, float(loss), float(acc),
                                            eta, int((s > 0).sum()), s, ev))
        self._next_tau = start + n_rounds
        return self.history

    def _stream_scheduler(self):
        """The engine path delegates to the streaming subsystem
        (fed/stream.py): the precomputed Client.active_from/departs_at
        schedule is translated into an event stream once, and the
        StreamScheduler owns span splitting, weights/reboot/LR
        recomputation and history.  The trainer is a thin adapter: it
        shares its clients/engine/RNG/history with the scheduler and
        mirrors membership state back after each run.  (Don't mix
        engine-mode and host-mode run() calls on one trainer — the
        scheduler tracks its own round clock.)"""
        if self._scheduler is None:
            from repro.fed.stream import (Arrival, Departure,
                                          StreamScheduler)
            events = []
            for i, cl in enumerate(self.clients):
                if cl.active_from > 0:
                    events.append(Arrival(cl.active_from, client_id=i))
                if cl.departs_at is not None:
                    events.append(Departure(cl.departs_at, client_id=i))

            def eval_cb(params):
                self.params = params
                return self.evaluate()

            self._scheduler = StreamScheduler(
                clients=self.clients, init_params=self.params,
                engine=self.engine, mode=self.engine_mode,
                reboot_boost=self.reboot_boost,
                fast_reboot=self.fast_reboot, horizon=self.horizon,
                bound_terms=self.bound_terms, rng=self.rng,
                key=self._key, evaluate=eval_cb, history=self.history,
                reboots=self.reboots, objective=self.objective,
                events=events)
        return self._scheduler

    def _run_engine(self, n_rounds: int, eval_every: int = 1):
        sch = self._stream_scheduler()
        sch.params = self.params
        sch.run(n_rounds, eval_every)
        # mirror scheduler state onto the legacy public attributes
        # (objective/reboots/history are shared objects already)
        self.params = sch.params
        self.lr_shift_tau = sch.lr_shift_tau
        self._next_tau = sch._next_tau
        return self.history

    def evaluate(self, include_idx: Optional[set] = None):
        idx = include_idx if include_idx is not None else self.objective
        xs = [self.clients[i].x_test for i in idx
              if self.clients[i].x_test is not None]
        ys = [self.clients[i].y_test for i in idx
              if self.clients[i].y_test is not None]
        if self.eval_fn is None or not xs:
            # task-only construction (e.g. LM clients without held-out
            # arrays): honest-NaN records, same as the scheduler's path
            return float("nan"), float("nan")
        return self.eval_fn(self.params, jnp.asarray(np.concatenate(xs)),
                            jnp.asarray(np.concatenate(ys)))
