"""Host-level federated round driver.

Implements the full paper protocol around the jitted round step:
  * per-round participation sampling from device traces (alpha masks),
  * Scheme A/B/C aggregation coefficients,
  * arrivals with objective shift + fast-reboot (coefficient boost + LR
    restart, §4.2),
  * departures with include/exclude applicability decision (§4.3),
  * membership is handled by masking (alpha=0, coeff=0), so the compiled
    round step never recompiles as devices come and go.

Execution delegates to the device-resident RoundEngine (fed/engine.py):
client data lives on device once and R rounds run per host dispatch via a
chunked, donated lax.scan.  Three modes:

  engine="plan"   (default) participation/batch indices are sampled with
                  the host numpy RNG in the seed order (sample-for-sample
                  identical to the legacy loop) but every round runs on
                  device; spans break at events and eval rounds.
  engine="device" fully fused on-device jax.random sampling — the fast
                  path; statistically equivalent to "plan".
  engine="host"   the seed per-round host loop (reference for parity
                  tests and benchmarks).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import scheme_coefficients
from repro.core.arrivals import RebootState, staircase_lr
from repro.core.departures import BoundTerms, should_exclude
from repro.core.fed_step import make_fed_round
from repro.core.participation import Trace
from repro.fed.engine import RoundEngine


@dataclass
class Client:
    x: np.ndarray
    y: np.ndarray
    trace: Trace
    x_test: Optional[np.ndarray] = None
    y_test: Optional[np.ndarray] = None
    # membership
    active_from: int = 0          # round the device joins (0 = founding)
    departs_at: Optional[int] = None
    departure_policy: str = "exclude"   # exclude | include | auto
    gamma_l: float = 1.0          # non-IID estimate used by policy "auto"

    @property
    def n(self) -> int:
        return len(self.y)


@dataclass
class RoundRecord:
    tau: int
    loss: float     # NaN on rounds where no eval ran (honest records)
    acc: float      # NaN on rounds where no eval ran
    eta: float
    n_active: int
    s: np.ndarray
    event: str = ""


class FederatedTrainer:
    def __init__(self, *, loss_fn: Callable, eval_fn: Callable,
                 init_params, clients: List[Client], local_epochs: int = 5,
                 batch_size: int = 10, scheme: str = "C", eta0: float = 0.01,
                 reboot_boost: float = 3.0, fast_reboot: bool = True,
                 horizon: Optional[int] = None,
                 bound_terms: Optional[BoundTerms] = None,
                 seed: int = 0, engine: Optional[str] = "plan",
                 chunk_size: int = 16, agg: str = "auto"):
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn  # eval_fn(params, x, y) -> (loss, acc)
        self.params = init_params
        self.clients = clients
        self.E = local_epochs
        self.B = batch_size
        self.scheme = scheme
        self.eta0 = eta0
        self.reboot_boost = reboot_boost
        self.fast_reboot = fast_reboot
        # Corollary 4.0.3 inputs for departure_policy == "auto": the
        # training deadline T and the fitted Theorem-3.1 bound terms
        self.horizon = horizon
        self.bound_terms = bound_terms or BoundTerms(
            D=5.0, V=20.0, gamma=10.0, E=local_epochs)
        self.rng = np.random.default_rng(seed)
        self.round_fn = jax.jit(make_fed_round(loss_fn, "client_parallel"))
        self.engine_mode = engine if engine not in (None, "off") else "host"
        if self.engine_mode not in ("host", "plan", "device"):
            raise ValueError(f"engine must be one of host|plan|device|off, "
                             f"got {engine!r}")
        self.chunk_size = chunk_size
        self.agg = agg
        self._engine: Optional[RoundEngine] = None
        self._key = jax.random.PRNGKey(seed)
        # membership bookkeeping
        self.objective: set = {i for i, c in enumerate(clients)
                               if c.active_from == 0}
        self.reboots: List[RebootState] = []
        self.lr_shift_tau = 0
        # per-client reboot state in array form for the engine: a client
        # that never rebooted has boost 1 (multiplier exactly 1)
        self._rb_tau0 = np.zeros(len(clients), np.int32)
        self._rb_boost = np.ones(len(clients), np.float32)
        self.history: List[RoundRecord] = []
        self._next_tau = 0

    @property
    def engine(self) -> RoundEngine:
        if self._engine is None:
            self._engine = RoundEngine(
                loss_fn=self.loss_fn, clients=self.clients,
                local_epochs=self.E, batch_size=self.B, scheme=self.scheme,
                eta0=self.eta0, chunk_size=self.chunk_size, agg=self.agg)
        return self._engine

    # -- weights over the current objective set -----------------------------
    def data_weights(self) -> np.ndarray:
        p = np.zeros(len(self.clients))
        total = sum(self.clients[i].n for i in self.objective)
        for i in self.objective:
            p[i] = self.clients[i].n / total
        return p

    def _participating(self, i: int, tau: int) -> bool:
        cl = self.clients[i]
        return (i in self.objective and tau >= cl.active_from
                and (cl.departs_at is None or tau < cl.departs_at))

    def _sample_plan(self, tau: int):
        """One round of host-RNG sampling: alpha (C, E) and batch indices
        idx (C, E, B).  Draw order matches the seed loop exactly, so a
        given numpy seed yields the identical sample stream."""
        C = len(self.clients)
        alpha = np.zeros((C, self.E), np.float32)
        idx = np.zeros((C, self.E, self.B), np.int64)
        for i, cl in enumerate(self.clients):
            if not self._participating(i, tau):
                continue
            alpha[i] = (np.arange(self.E)
                        < cl.trace.sample_s(self.rng, self.E)
                        ).astype(np.float32)
            idx[i] = self.rng.integers(0, cl.n, size=(self.E, self.B))
        return alpha, idx

    def _sample_round(self, tau: int):
        alpha, idx = self._sample_plan(tau)
        C = len(self.clients)
        xdim = self.clients[0].x.shape[1:]
        bx = np.zeros((C, self.E, self.B, *xdim), np.float32)
        by = np.zeros((C, self.E, self.B), np.int32)
        for i, cl in enumerate(self.clients):
            if self._participating(i, tau):
                bx[i] = cl.x[idx[i]]
                by[i] = cl.y[idx[i]]
        return alpha, {"x": bx, "y": by}

    # -- events --------------------------------------------------------------
    def _handle_events(self, tau: int) -> str:
        ev = ""
        for i, cl in enumerate(self.clients):
            if cl.active_from == tau and i not in self.objective:
                # arrival: mandatory objective shift (+ optional fast-reboot)
                self.objective.add(i)
                self.lr_shift_tau = tau
                if self.fast_reboot:
                    self.reboots.append(RebootState(tau, i,
                                                    self.reboot_boost))
                    self._rb_tau0[i] = tau
                    self._rb_boost[i] = self.reboot_boost
                ev += f"arrival:{i};"
            if cl.departs_at == tau and i in self.objective:
                policy = cl.departure_policy
                if policy == "auto":
                    # Corollary 4.0.3: exclude iff enough training remains
                    T = self.horizon if self.horizon is not None \
                        else tau + 100
                    policy = "exclude" if should_exclude(
                        T, tau, self.bound_terms, cl.gamma_l) else "include"
                if policy == "exclude":
                    self.objective.discard(i)
                    self.lr_shift_tau = tau
                    ev += f"departure-exclude:{i};"
                else:
                    ev += f"departure-include:{i};"
        return ev

    def _event_taus(self):
        taus = set()
        for cl in self.clients:
            if cl.active_from > 0:
                taus.add(cl.active_from)
            if cl.departs_at is not None:
                taus.add(cl.departs_at)
        return taus

    # -- main loop ------------------------------------------------------------
    def run(self, n_rounds: int, eval_every: int = 1):
        if self.engine_mode == "host":
            return self._run_host(n_rounds, eval_every)
        return self._run_engine(n_rounds, eval_every)

    def _run_host(self, n_rounds: int, eval_every: int = 1):
        """The seed per-round host loop (reference path)."""
        start = self._next_tau
        for tau in range(start, start + n_rounds):
            ev = self._handle_events(tau)
            p = self.data_weights()
            alpha, batches = self._sample_round(tau)
            s = alpha.sum(axis=1)
            coeffs = np.array(scheme_coefficients(
                self.scheme, jnp.asarray(p), jnp.asarray(s), self.E))
            for rb in self.reboots:
                coeffs[rb.client_idx] *= rb.coeff_multiplier(tau)
            eta = staircase_lr(self.eta0, tau + 1, self.lr_shift_tau)
            self.params, _m = self.round_fn(
                self.params,
                {"x": jnp.asarray(batches["x"]),
                 "y": jnp.asarray(batches["y"])},
                jnp.asarray(alpha), jnp.asarray(coeffs),
                jnp.float32(eta))
            loss = acc = float("nan")
            if tau % eval_every == 0 or ev:
                loss, acc = self.evaluate()
            self.history.append(RoundRecord(tau, float(loss), float(acc),
                                            eta, int((s > 0).sum()), s, ev))
        self._next_tau = start + n_rounds
        return self.history

    def _span_end(self, tau: int, stop: int, ev: str,
                  eval_every: int) -> int:
        """Largest t <= stop such that [tau, t) has fixed membership and at
        most one eval, which lands on the final round of the span."""
        end = stop
        for t in self._event_taus():
            if tau < t < end:
                end = t
        if ev:
            return tau + 1  # event round: evaluate right after it
        next_eval = tau + ((-tau) % eval_every)
        if next_eval < end:
            end = next_eval + 1
        return end

    def _run_engine(self, n_rounds: int, eval_every: int = 1):
        eng = self.engine
        start = self._next_tau
        stop = start + n_rounds
        tau = start
        span_args = None
        while tau < stop:
            ev = self._handle_events(tau)
            end = self._span_end(tau, stop, ev, eval_every)
            R = end - tau
            if span_args is None or ev:
                # membership/reboot/LR state only changes at events, so the
                # device-staged span arguments are reused across spans
                p = self.data_weights()
                active = np.array(
                    [1.0 if self._participating(i, tau) else 0.0
                     for i in range(len(self.clients))], np.float32)
                span_args = dict(p=jnp.asarray(p, jnp.float32),
                                 active=jnp.asarray(active),
                                 lr_shift_tau=self.lr_shift_tau,
                                 reboot_tau0=jnp.asarray(self._rb_tau0),
                                 reboot_boost=jnp.asarray(self._rb_boost))
            kwargs = span_args
            if self.engine_mode == "device":
                self._key, sub = jax.random.split(self._key)
                self.params, m = eng.run_span(self.params, tau, R,
                                              key=sub, **kwargs)
            else:
                plans = [self._sample_plan(t) for t in range(tau, end)]
                alphas = np.stack([pl[0] for pl in plans])
                idxs = np.stack([pl[1] for pl in plans])
                self.params, m = eng.run_span(self.params, tau, R,
                                              plan=(alphas, idxs), **kwargs)
            eval_last = (end - 1) % eval_every == 0 or (ev and R == 1)
            for j, t in enumerate(range(tau, end)):
                loss = acc = float("nan")
                if eval_last and t == end - 1:
                    loss, acc = self.evaluate()
                s = m["s"][j]
                self.history.append(RoundRecord(
                    t, float(loss), float(acc), float(m["eta"][j]),
                    int((s > 0).sum()), s, ev if t == tau else ""))
            tau = end
        self._next_tau = stop
        return self.history

    def evaluate(self, include_idx: Optional[set] = None):
        idx = include_idx if include_idx is not None else self.objective
        xs = np.concatenate([self.clients[i].x_test for i in idx
                             if self.clients[i].x_test is not None])
        ys = np.concatenate([self.clients[i].y_test for i in idx
                             if self.clients[i].y_test is not None])
        return self.eval_fn(self.params, jnp.asarray(xs), jnp.asarray(ys))
