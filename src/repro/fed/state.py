"""FedState: the event-sourced federation control plane.

Everything the streaming scheduler used to keep in ad-hoc attributes —
slot registry, objective/joined/departed/mask membership, reboot arrays,
the LR-shift round, the pending event queue, the RNG and PRNG-key state —
lives here as one plain-data object.  Event application is a pure state
transition: ``apply(event, tau)`` mutates only host bookkeeping and
returns the *engine actions* (slot admits/evicts/trace writes) the
transition implies, so the device side stays a thin executor
(StreamScheduler in fed/stream.py) and the whole control plane is
``to_dict()``/``from_dict()`` round-trippable.  That round trip is what
makes mid-stream checkpoint/resume exact: a killed run restored from disk
replays the remaining rounds bit-for-bit (checkpoint/io.py persists the
dict next to the params; tests/test_checkpoint_resume.py pins it).

Invariants:
  * client id == index into ``clients``; founding clients occupy slots
    0..C-1 in id order, later arrivals take the lowest free slot;
  * the queue is a heap keyed by (tau, push order) — ``seq`` is a plain
    int counter (not itertools.count) so it serializes;
  * ``objective_version`` bumps whenever objective *membership* changes —
    consumers (the scheduler's eval-set cache) key on it;
  * the jax key is a *base* key, never split: per-round randomness is
    derived by folding the round index on device (fed/engine.py), so the
    sample stream is invariant to how training is cut into run() calls,
    spans and chunks — the property resume parity rests on.
"""
from __future__ import annotations

import heapq
from dataclasses import replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.arrivals import RebootState
from repro.core.departures import BoundTerms, should_exclude
from repro.fed.driver import Client
from repro.fed.events import (Arrival, Departure, InactivityBurst,
                              ParticipationEvent, TraceShift,
                              client_from_dict, client_to_dict,
                              event_from_dict, event_to_dict)

# engine actions a transition emits: ("admit", slot, client_id),
# ("evict", slot), ("set_trace", slot, trace)
SlotAction = tuple


class FedState:
    """Serializable control-plane state for one federation run."""

    def __init__(self, *, clients: List[Client], capacity: int,
                 reboot_boost: float = 3.0, fast_reboot: bool = True,
                 horizon: Optional[int] = None,
                 bound_terms: Optional[BoundTerms] = None,
                 local_epochs: int = 5,
                 seed: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 key=None,
                 objective: Optional[set] = None,
                 reboots: Optional[List[RebootState]] = None):
        import jax

        self.clients: List[Client] = clients
        self.capacity = capacity
        self.reboot_boost = reboot_boost
        self.fast_reboot = fast_reboot
        self.horizon = horizon
        self.bound_terms = bound_terms or BoundTerms(
            D=5.0, V=20.0, gamma=10.0, E=local_epochs)
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.key = key if key is not None else jax.random.PRNGKey(seed)

        # slot registry: client id == index into self.clients; founding
        # clients occupy slots 0..C-1 in id order
        C = len(self.clients)
        self.slot_of: Dict[int, int] = {i: i for i in range(C)}
        self.client_at: Dict[int, int] = {i: i for i in range(C)}
        self.free_slots: List[int] = list(range(C, capacity))
        heapq.heapify(self.free_slots)

        # membership
        self.objective: set = (objective if objective is not None
                               else set(range(C)))
        self.joined: Dict[int, int] = {i: 0 for i in self.objective}
        self.departed: set = set()
        self.mask_until: Dict[int, int] = {}
        self.expiry_taus: set = set()
        self.lr_shift_tau = 0
        self.rb_tau0 = np.zeros(capacity, np.int32)
        self.rb_boost = np.ones(capacity, np.float32)
        self.reboots: List[RebootState] = (reboots if reboots is not None
                                           else [])
        self.objective_version = 0

        # the event queue (heap keyed by (tau, push order))
        self.queue: List[Tuple[int, int, ParticipationEvent]] = []
        self.seq = 0
        self.next_tau = 0
        self.events_applied = 0

    # -- queue ---------------------------------------------------------------
    def push(self, *events: ParticipationEvent) -> None:
        """Enqueue participation events (any order; any time — including
        between run() calls, which is the streaming use case)."""
        for e in events:
            heapq.heappush(self.queue, (e.tau, self.seq, e))
            self.seq += 1

    @property
    def pending(self) -> int:
        return len(self.queue)

    def due(self, tau: int) -> bool:
        return bool(self.queue) and self.queue[0][0] <= tau

    def pop_event(self) -> ParticipationEvent:
        return heapq.heappop(self.queue)[2]

    def compact_stale_traceshifts(self) -> int:
        """Bound event-heap growth under TraceShift floods (the ROADMAP
        soak question): among queued *stale* TraceShifts — tau already
        passed, so they all fire at the same next boundary — keep only
        the newest per client (last-write-wins, exactly what applying
        them in order would compute) and elide that one too when it
        restates the client's current trace (idempotent no-op).  Future-
        tau events and every other event kind are untouched.  Returns the
        number of events dropped."""
        now = self.next_tau
        keep, newest = [], {}
        for entry in self.queue:
            e = entry[2]
            if isinstance(e, TraceShift) and entry[0] <= now:
                cur = newest.get(e.client_id)
                if cur is None or entry[1] > cur[1]:
                    newest[e.client_id] = entry
            else:
                keep.append(entry)
        for entry in newest.values():
            e = entry[2]
            if not (0 <= e.client_id < len(self.clients)
                    and e.trace == self.clients[e.client_id].trace):
                keep.append(entry)
        dropped = len(self.queue) - len(keep)
        if dropped:
            heapq.heapify(keep)
            self.queue = keep
        return dropped

    # -- membership ----------------------------------------------------------
    def active(self, i: int, tau: int) -> bool:
        return (i in self.objective and i not in self.departed
                and self.joined.get(i, tau + 1) <= tau
                and self.mask_until.get(i, tau) <= tau)

    def register(self, client: Client) -> int:
        self.clients.append(client)
        return len(self.clients) - 1

    def _alloc_slot(self, i: int) -> int:
        if not self.free_slots:
            raise RuntimeError(
                f"engine capacity {self.capacity} exhausted: no "
                f"free slot for arriving client {i} (build the engine "
                f"with a larger capacity=)")
        slot = heapq.heappop(self.free_slots)
        self.slot_of[i] = slot
        self.client_at[slot] = i
        return slot

    def _free_slot(self, i: int, actions: List[SlotAction]) -> None:
        slot = self.slot_of.pop(i, None)
        if slot is None:
            return
        del self.client_at[slot]
        self.rb_tau0[slot] = 0
        self.rb_boost[slot] = 1.0
        heapq.heappush(self.free_slots, slot)
        actions.append(("evict", slot))

    # -- event application (pure transitions) --------------------------------
    def apply(self, e: ParticipationEvent,
              tau: int) -> Tuple[str, List[SlotAction]]:
        """Apply one event at round tau.  Mutates host bookkeeping only;
        returns (event-log string, engine actions) — the executor owns the
        device writes the actions describe."""
        actions: List[SlotAction] = []
        if isinstance(e, Arrival):
            if e.client is not None:
                i = self.register(e.client)
                slot = self._alloc_slot(i)
                actions.append(("admit", slot, i))
            else:
                i = e.client_id
                if i is None or not 0 <= i < len(self.clients):
                    raise ValueError(f"Arrival without client needs a "
                                     f"registered client_id, got {i!r}")
                if i not in self.slot_of:
                    slot = self._alloc_slot(i)
                    actions.append(("admit", slot, i))
            if i in self.objective:
                if i not in self.departed:
                    return "", actions          # duplicate arrival: no-op
                # rejoin of an include-departed device: the objective
                # never shifted, so no LR restart / reboot boost — the
                # device simply resumes participating
                self.departed.discard(i)
                self.joined[i] = tau
                return f"rejoin:{i};", actions
            self.objective.add(i)
            self.objective_version += 1
            self.joined[i] = tau
            self.departed.discard(i)
            self.lr_shift_tau = tau
            fast = self.fast_reboot if e.fast_reboot is None else \
                e.fast_reboot
            if fast:
                self.reboots.append(RebootState(tau, i, self.reboot_boost))
                slot = self.slot_of[i]
                self.rb_tau0[slot] = tau
                self.rb_boost[slot] = self.reboot_boost
            return f"arrival:{i};", actions

        if isinstance(e, Departure):
            i = e.client_id
            if i not in self.objective or i in self.departed:
                return "", actions              # duplicate/unknown: no-op
            cl = self.clients[i]
            policy = e.policy or cl.departure_policy
            if policy == "auto":
                # Corollary 4.0.3: exclude iff enough training remains
                T = self.horizon if self.horizon is not None else tau + 100
                policy = "exclude" if should_exclude(
                    T, tau, self.bound_terms, cl.gamma_l) else "include"
            self.departed.add(i)
            self._free_slot(i, actions)
            if policy == "exclude":
                self.objective.discard(i)
                self.objective_version += 1
                self.lr_shift_tau = tau
                return f"departure-exclude:{i};", actions
            return f"departure-include:{i};", actions

        if isinstance(e, TraceShift):
            i = e.client_id
            if not 0 <= i < len(self.clients):
                return "", actions              # unknown device: no-op
            # copy-on-shift, NOT in-place: the registered Client object
            # is aliased by the payload Arrival that delivered it (and
            # by any service journal holding that event for post-crash
            # replay) — mutating .trace through the alias would make the
            # replayed arrival re-register the *shifted* law and break
            # bit-exact recovery.  Arrays are shared by reference; only
            # the law changes.  Plan-mode draws follow the new object.
            self.clients[i] = replace(self.clients[i], trace=e.trace)
            slot = self.slot_of.get(i)
            if slot is not None:
                actions.append(("set_trace", slot, e.trace))
            return f"trace-shift:{i};", actions

        if isinstance(e, InactivityBurst):
            until = tau + e.duration
            for i in e.client_ids:
                self.mask_until[i] = max(self.mask_until.get(i, 0), until)
            self.expiry_taus.add(until)
            ids = ",".join(str(i) for i in e.client_ids)
            return f"burst:{ids}@{e.duration};", actions

        raise TypeError(f"unknown participation event {e!r}")

    def upcoming_arrivals(self, until_tau: int):
        """Prefetch planning (read-only): the (client_id, Client) pairs
        whose queued Arrivals with tau <= until_tau will stage data into
        a slot when applied — fresh payloads (client_id None until
        registration) and unslotted rejoins.  A currently-slotted client
        is included when a Departure for it is also queued in the window
        (evict + rejoin inside one boundary still re-admits).  The
        scheduler hands this set to the CohortStager (fed/bank.py) so
        the transfer overlaps the current span."""
        departing = {e.client_id for t, _, e in self.queue
                     if t <= until_tau and isinstance(e, Departure)}
        out, seen = [], set()
        for t, _, e in self.queue:
            if t > until_tau or not isinstance(e, Arrival):
                continue
            if e.client is not None:
                if id(e.client) not in seen:
                    seen.add(id(e.client))
                    out.append((None, e.client))
            else:
                i = e.client_id
                if (i is not None and 0 <= i < len(self.clients)
                        and i not in seen
                        and (i not in self.slot_of or i in departing)):
                    seen.add(i)
                    out.append((i, self.clients[i]))
        return out

    def expire(self, tau: int) -> bool:
        """Retire a burst expiry landing on tau; True when a masked
        cohort resumed (membership-derived span args are stale)."""
        if tau in self.expiry_taus:
            self.expiry_taus.discard(tau)
            return True
        return False

    # -- span arguments (host-side, numpy) ------------------------------------
    def data_weights(self) -> np.ndarray:
        """Slot-indexed data weights p over the current objective.  An
        include-departed client keeps its mass in the normalization (the
        paper's §4.3 'include' keeps the old objective) but holds no
        slot, so its column simply never appears — arithmetically
        identical to a zero-coefficient column."""
        p = np.zeros(self.capacity)
        total = sum(self.clients[i].n for i in self.objective)
        for i in self.objective:
            slot = self.slot_of.get(i)
            if slot is not None:
                p[slot] = self.clients[i].n / total
        return p

    def span_args(self, tau: int) -> dict:
        active = np.zeros(self.capacity, np.float32)
        for slot, i in self.client_at.items():
            if self.active(i, tau):
                active[slot] = 1.0
        return dict(p=self.data_weights().astype(np.float32),
                    active=active,
                    lr_shift_tau=self.lr_shift_tau,
                    reboot_tau0=self.rb_tau0.copy(),
                    reboot_boost=self.rb_boost.copy())

    def span_end(self, tau: int, stop: int, ev: str,
                 eval_every: int) -> int:
        """Largest t <= stop such that [tau, t) has fixed membership and
        at most one eval, which lands on the final round of the span."""
        end = stop
        if self.queue:
            end = min(end, max(self.queue[0][0], tau + 1))
        for t in self.expiry_taus:
            if tau < t < end:
                end = t
        if ev:
            return tau + 1      # event round: evaluate right after it
        next_eval = tau + ((-tau) % eval_every)
        if next_eval < end:
            end = next_eval + 1
        return end

    # -- plan-mode sampling (seed RNG draw order) -----------------------------
    def sample_plan(self, tau: int, E: int, B: int):
        """One round of host-RNG sampling in the seed draw order: alpha
        (capacity, E) and batch indices (capacity, E, B).  Consumes
        ``self.rng`` per occupied active slot in slot order — the legacy
        loop's stream, and (because draws advance per *round*, not per
        span) invariant to how training is cut into run() calls."""
        alpha = np.zeros((self.capacity, E), np.float32)
        idx = np.zeros((self.capacity, E, B), np.int64)
        for slot in range(self.capacity):
            i = self.client_at.get(slot)
            if i is None or not self.active(i, tau):
                continue
            cl = self.clients[i]
            alpha[slot] = (np.arange(E)
                           < cl.trace.sample_s(self.rng, E)
                           ).astype(np.float32)
            idx[slot] = self.rng.integers(0, cl.n, size=(E, B))
        return alpha, idx

    # -- serialization --------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-data snapshot: scalars, strings, lists and numpy arrays
        only (checkpoint/io.jsonify_tree extracts the arrays for disk).
        Round-trips exactly through from_dict."""
        return {
            "version": 1,
            "capacity": self.capacity,
            "reboot_boost": self.reboot_boost,
            "fast_reboot": self.fast_reboot,
            "horizon": self.horizon,
            "bound_terms": {"D": self.bound_terms.D,
                            "V": self.bound_terms.V,
                            "gamma": self.bound_terms.gamma,
                            "E": self.bound_terms.E},
            "slot_of": sorted(self.slot_of.items()),
            "free_slots": sorted(self.free_slots),
            "objective": sorted(self.objective),
            "joined": sorted(self.joined.items()),
            "departed": sorted(self.departed),
            "mask_until": sorted(self.mask_until.items()),
            "expiry_taus": sorted(self.expiry_taus),
            "lr_shift_tau": self.lr_shift_tau,
            "rb_tau0": self.rb_tau0.copy(),
            "rb_boost": self.rb_boost.copy(),
            "reboots": [[r.tau0, r.client_idx, r.boost]
                        for r in self.reboots],
            "objective_version": self.objective_version,
            "rng_state": self.rng.bit_generator.state,
            "key": np.asarray(self.key).copy(),
            "queue": [[tau, seq, event_to_dict(e)]
                      for tau, seq, e in sorted(self.queue)],
            "seq": self.seq,
            "next_tau": self.next_tau,
            "events_applied": self.events_applied,
            "clients": [client_to_dict(c) for c in self.clients],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FedState":
        import jax.numpy as jnp

        if d.get("version") != 1:
            raise ValueError(f"unknown FedState version {d.get('version')!r}")
        bt = d["bound_terms"]
        clients = [client_from_dict(c) for c in d["clients"]]
        st = cls(clients=clients, capacity=int(d["capacity"]),
                 reboot_boost=float(d["reboot_boost"]),
                 fast_reboot=bool(d["fast_reboot"]),
                 horizon=d["horizon"],
                 bound_terms=BoundTerms(D=bt["D"], V=bt["V"],
                                        gamma=bt["gamma"], E=int(bt["E"])),
                 key=jnp.asarray(np.asarray(d["key"])))
        st.rng.bit_generator.state = d["rng_state"]
        st.slot_of = {int(i): int(s) for i, s in d["slot_of"]}
        st.client_at = {s: i for i, s in st.slot_of.items()}
        st.free_slots = [int(s) for s in d["free_slots"]]
        heapq.heapify(st.free_slots)
        st.objective = {int(i) for i in d["objective"]}
        st.joined = {int(i): int(t) for i, t in d["joined"]}
        st.departed = {int(i) for i in d["departed"]}
        st.mask_until = {int(i): int(t) for i, t in d["mask_until"]}
        st.expiry_taus = {int(t) for t in d["expiry_taus"]}
        st.lr_shift_tau = int(d["lr_shift_tau"])
        st.rb_tau0 = np.asarray(d["rb_tau0"], np.int32).copy()
        st.rb_boost = np.asarray(d["rb_boost"], np.float32).copy()
        st.reboots = [RebootState(int(t), int(i), float(b))
                      for t, i, b in d["reboots"]]
        st.objective_version = int(d.get("objective_version", 0))
        st.queue = [(int(tau), int(seq), event_from_dict(ev))
                    for tau, seq, ev in d["queue"]]
        heapq.heapify(st.queue)
        st.seq = int(d["seq"])
        st.next_tau = int(d["next_tau"])
        st.events_applied = int(d["events_applied"])
        return st
