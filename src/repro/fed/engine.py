"""Device-resident multi-round federated engine.

The seed host loop (FederatedTrainer.run) rebuilt a (C, E, B, ...) numpy
batch tensor, shipped it host->device, and computed scheme coefficients in
numpy — every round.  This engine moves the whole round inside one jitted,
chunked ``lax.scan``:

  * client datasets are padded to a common length and live on device once
    as (C, Nmax, ...) stacks; per-round batch selection is an on-device
    gather (vmapped ``jnp.take``);
  * participation masks alpha can be sampled on device (inverse-CDF draw
    from an exact per-client table of the paper's Table-2 trace law, see
    trace_s_cdf) or supplied as a host-precomputed *plan* — the plan path
    consumes the trainer's numpy RNG in the seed order, so it is
    sample-for-sample identical to the legacy loop and is what the parity
    tests compare against; on-device draws fold the round index into the
    caller's base key per round (device_sample_round), so round tau's
    randomness never depends on span/chunk structure — the invariance
    mid-stream checkpoint/resume rests on (fed/state.py);
  * scheme A/B/C coefficients, fast-reboot boosts (per-client (tau0,
    boost) arrays evaluated at each in-chunk tau, so the O(dt^-2) decay is
    exact mid-chunk) and the staircase LR are computed inside the step;
  * R rounds run per host dispatch via ``lax.scan`` over power-of-two
    chunk sizes (bounded compile cache), with ``params`` donated to the
    chunk call on backends that support buffer donation;
  * aggregation uses the pytree-flat path: the delta pytree is flattened
    to one (C, D_total) buffer and reduced with a single weighted_agg
    Pallas launch per round (``agg="flat"``), or the per-leaf jnp tree
    path (``agg="tree"``);
  * with ``sharding=FedSharding(...)`` the client/slot axis of every
    buffer is sharded over the mesh's federation axis: local epochs run
    device-parallel and the delta reduction ends in a cross-device
    all-reduce that leaves params replicated (see fed/sharding.py and
    docs/scaling.md).

The host loop above the engine (StreamScheduler in fed/stream.py — with
FederatedTrainer as a thin adapter over it) handles participation events,
span splitting and evaluation at span boundaries.

Usage::

    eng = RoundEngine(loss_fn=loss_fn, clients=clients, local_epochs=5,
                      batch_size=10, capacity=16)
    params, metrics = eng.run_span(params, tau_start=0, n_rounds=32,
                                   p=p, active=active, lr_shift_tau=0,
                                   reboot_tau0=rb0, reboot_boost=rbb,
                                   key=jax.random.PRNGKey(0))
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import scheme_coefficients
from repro.core.compression import resolve_compression, wire_bytes
from repro.core.fed_step import fed_round_parallel, fed_round_sequential
from repro.fed.task import ArrayTask
from repro.obs.telemetry import resolve as resolve_telemetry


def _pow2_chunks(n: int, cap: int):
    """Split n rounds into power-of-two chunk lengths <= cap (largest
    first), so at most log2(cap)+1 distinct scan lengths ever compile."""
    out = []
    while n > 0:
        r = min(1 << (n.bit_length() - 1), 1 << (cap.bit_length() - 1))
        out.append(r)
        n -= r
    return out


@functools.lru_cache(maxsize=1024)
def trace_cdf_row(trace, E: int) -> np.ndarray:
    """CDF table of completed epochs s for one trace: (E+1,) with
    cdf[k] = P(s <= k).  Cached per (trace, E) — traces are frozen
    dataclasses and the betainc evaluation dominates admit() otherwise;
    callers must not mutate the returned array.

    s = round(frac * E) for frac ~ Beta(a, b) mixed with an inactivity
    atom at 0, so the s-law is a discrete distribution over {0..E} whose
    CDF is exact regularized-incomplete-beta evaluations at the rounding
    boundaries (k + 1/2)/E — computed once at engine build / admit time,
    which removes the gamma rejection sampler from the hot path entirely
    while sampling the *identical* distribution as Trace.sample_s.
    """
    from jax.scipy.special import betainc

    ks = np.arange(E + 1)
    ab = trace._beta_params()
    if ab is None:
        # degenerate trace: frac == mean deterministically
        s0 = int(np.clip(np.round(trace.mean * E), 0, E))
        base = (ks >= s0).astype(np.float64)
    else:
        x = np.clip((ks + 0.5) / E, 0.0, 1.0)
        base = np.asarray(betainc(ab[0], ab[1], x), np.float64)
        base[-1] = 1.0
    q = trace.p_inactive
    if q > 0:
        # inactive rounds put an atom at s = 0
        row = q + (1.0 - q) * base
    else:
        # CPU-contention traces never produce zero epochs: the s=0
        # mass moves to s=1 (Trace.sample_s's maximum(s, 1))
        row = base.copy()
        row[0] = 0.0
    row[-1] = 1.0
    return row.astype(np.float32)


# an empty slot's s-law: all mass at s = 0, so the slot never trains even
# before the scheduler's active mask is applied
def empty_slot_cdf(E: int) -> np.ndarray:
    return np.ones(E + 1, np.float32)


def trace_s_cdf(clients, E: int) -> np.ndarray:
    """Per-client CDF table of completed epochs s: (C, E+1) with
    cdf[c, k] = P(s_c <= k).  See trace_cdf_row."""
    return np.stack([trace_cdf_row(cl.trace, E) for cl in clients]) \
        if clients else np.zeros((0, E + 1), np.float32)


def device_sample_round(key, active, n, s_cdf, E: int, B: int):
    """On-device sampling of participation + batch indices for ONE round.

    active: (C,) 0/1 mask of clients participating this span; n: (C,)
    dataset sizes; s_cdf: (C, E+1) per-client CDF of completed epochs
    (trace_s_cdf).  Returns alpha (C, E) f32, idx (C, E, B) i32.

    The engine calls this inside the scan body with a per-round key
    ``fold_in(base_key, tau)`` — round tau's draw is a pure function of
    (base_key, tau), never of how training was cut into run() calls,
    spans or chunks.  That invariance is what makes mid-stream
    checkpoint/resume bit-exact in device mode (fed/state.py).
    """
    ks, kb = jax.random.split(key)
    # inverse-CDF draw of s: s = #{k : cdf[k] < u}
    u = jax.random.uniform(ks, (n.shape[0],))
    s = jnp.sum(u[:, None] > s_cdf, axis=-1)
    s = s.astype(jnp.float32) * active
    alpha = (jnp.arange(E, dtype=jnp.float32)[None, :]
             < s[:, None]).astype(jnp.float32)
    ub = jax.random.uniform(kb, (n.shape[0], E, B))
    nf = n.astype(jnp.float32)[:, None, None]
    idx = jnp.minimum((ub * nf).astype(jnp.int32),
                      n[:, None, None] - 1)
    return alpha, idx


def device_sample_span(key, R: int, active, n, s_cdf, E: int, B: int):
    """R rounds of device_sample_round under per-round folded keys:
    alphas (R, C, E) f32, idxs (R, C, E, B) i32.  Convenience/testing
    view of the sampling law the engine applies inside its scan."""
    keys = jax.vmap(lambda t: jax.random.fold_in(key, t))(jnp.arange(R))
    return jax.vmap(
        lambda k: device_sample_round(k, active, n, s_cdf, E, B))(keys)


def _slot_write(buf, row, slot):
    """dynamic-update-slice of one leading-axis row (jitted; one trace per
    buffer dtype/shape, reused for every admit/evict/set_trace)."""
    return jax.lax.dynamic_update_index_in_dim(buf, row, slot, axis=0)


_slot_write = jax.jit(_slot_write)


def _evict_write(n_buf, cdf_buf, cdf_row, slot):
    """Both evict writes (n -> 1, s-law -> empty-slot atom) in one
    dispatch — separate _slot_writes are a host dispatch each on the
    churn boundary path."""
    return (jax.lax.dynamic_update_index_in_dim(
                n_buf, jnp.int32(1), slot, axis=0),
            jax.lax.dynamic_update_index_in_dim(
                cdf_buf, cdf_row, slot, axis=0))


_evict_write = jax.jit(_evict_write)


@functools.lru_cache(maxsize=64)
def _slots_writer(sharding):
    """Jitted burst scatter (admit_many), pinned to the buffer's own
    sharding: without out_shardings the scatter result can come back
    replicated, silently changing the compiled span fns' input layout
    (one recompile per churn event — exactly what the slot machinery
    exists to avoid).  Cached per sharding object; rows: (k, ...)
    stacked, slots: (k,) int32, duplicate slots carry identical rows
    (pow2 padding repeats the last pair), so scatter order cannot
    matter."""
    return jax.jit(lambda buf, rows, slots: buf.at[slots].set(rows),
                   out_shardings=sharding)


def _slots_write(buf, rows, slots):
    return _slots_writer(buf.sharding)(buf, rows, slots)


def _pow2_pad(k: int) -> int:
    """Next power of two >= k: bursts of any size reuse at most
    log2(capacity)+1 compiled scatter shapes per buffer."""
    return 1 << (k - 1).bit_length() if k > 1 else 1


def _dev(x, dtype):
    """jnp.asarray(x, dtype) that short-circuits for device arrays
    already in dtype — the common span-args case."""
    if isinstance(x, jax.Array) and x.dtype == dtype:
        return x
    return jnp.asarray(x, dtype)


@functools.lru_cache(maxsize=64)
def _burst_writer(data_shardings, n_sharding, cdf_sharding):
    """ONE jitted dispatch updating every client buffer plus the n and
    s-CDF columns of an admit burst — the previous per-buffer scatters
    cost 3+ dispatches per burst and measured *slower* per row than
    single admits at small k.  Data rows go through a gather
    ``rows[idx]`` first, so a prefetched cohort stack can be committed
    partially / reordered (idx maps each written slot to its staged
    row); duplicate slots carry identical rows (pow2 padding repeats
    the last entry), so scatter order cannot matter.  Under mesh
    sharding, out_shardings pin each buffer's own sharding — without
    them the scatter result can come back replicated and silently
    re-layout the compiled span fns (one recompile per churn event).
    Single-device callers pass sharding None: out_shardings would mint
    *committed* outputs where the engine's buffers start uncommitted,
    and that committed-ness flip shows up as new C++ fastpath cache
    entries on every span fn (the churn contract pins those flat).
    Cached per sharding tuple; shape variants retrace under the same
    jit (bounded: pow2 burst x pow2 stack sizes)."""

    def write(data_bufs, data_rows, n_buf, n_rows, cdf_buf, cdf_rows,
              idx, slots):
        out = {name: buf.at[slots].set(data_rows[name][idx])
               for name, buf in data_bufs.items()}
        return out, n_buf.at[slots].set(n_rows), \
            cdf_buf.at[slots].set(cdf_rows)

    if n_sharding is None:
        return jax.jit(write)
    out_sh = (dict(data_shardings), n_sharding, cdf_sharding)
    return jax.jit(write, out_shardings=out_sh)


class RoundEngine:
    """Runs R federated rounds per host dispatch on device-resident data.

    The model/step layer is a ClientTask (fed/task.py): the task names
    the per-sample buffers, maps gathered samples to loss batches, and
    (for sharded large models) supplies per-leaf param PartitionSpecs.
    ``loss_fn=`` remains as the legacy constructor — it wraps into the
    equivalent ArrayTask.  Two execution modes share every other engine
    mechanism (sampling, slots, chunking, schemes):

      mode="client_parallel"   — vmap over the client axis (the small-
                                 model fast path; per-client param copies
                                 are live simultaneously);
      mode="client_sequential" — lax.scan over clients streaming each
                                 masked-SGD delta into one aggregation
                                 accumulator (global params + one live
                                 client delta; required >= 30B).

    Membership, data weights p, the LR-restart round and reboot state are
    constant within a span (the trainer splits spans at every event), so
    they enter the chunk as plain array arguments — values change between
    chunks without recompiling.

    Capacity slots: with ``capacity=C_max`` the engine preallocates C_max
    client slots (data/size/trace-CDF buffers have a C_max leading axis);
    slots beyond the founding clients start empty (n=1, s-law all mass at
    0).  ``admit(slot, client)`` / ``evict(slot)`` / ``set_trace(slot,
    trace)`` mutate one slot with a single host->device transfer plus a
    dynamic-update-slice each — buffer shapes never change, so the
    compiled span scans are reused across arbitrarily many membership
    events (no rebuild, no recompile).

    Sharding: with ``sharding=FedSharding(mesh)`` the slot axis of every
    client buffer is sharded over the mesh's federation ('data') axis
    (capacity is padded so each shard owns whole slots), local epochs run
    in parallel across devices and aggregation all-reduces to replicated
    params.  Slot writes stay one replicated-row device_put plus the same
    dynamic-update-slice, which XLA lowers to a masked shard-local write —
    so the zero-recompile membership-churn contract is preserved
    unchanged under sharding.
    """

    def __init__(self, *, clients, local_epochs: int,
                 batch_size: int, loss_fn=None, task=None,
                 scheme: str = "C", eta0: float = 0.01,
                 chunk_size: int = 16, agg: str = "auto",
                 interpret=None, donate: Optional[bool] = None,
                 with_metrics: bool = False,
                 capacity: Optional[int] = None,
                 max_samples: Optional[int] = None,
                 sharding=None, mode: str = "client_parallel",
                 telemetry=None, compression=None):
        if (task is None) == (loss_fn is None):
            raise ValueError("pass exactly one of task= or loss_fn=")
        if task is None:
            # legacy construction: a bare loss over {"x", "y"} batches —
            # wrap it in the equivalent ArrayTask (feature shape fixed by
            # the founding clients, exactly as before the refactor)
            if not clients:
                raise ValueError("RoundEngine needs at least one founding "
                                 "client (fixes the feature shape)")
            task = ArrayTask(loss_fn,
                             np.asarray(clients[0].x).shape[1:])
        self.task = task
        self.loss_fn = task.loss_fn
        if mode not in ("client_parallel", "client_sequential"):
            raise ValueError(f"mode must be client_parallel|"
                             f"client_sequential, got {mode!r}")
        self.mode = mode
        self.E = local_epochs
        self.B = batch_size
        self.scheme = scheme
        self.eta0 = eta0
        self.chunk_size = max(1, chunk_size)
        # delta wire format (core/compression): closed over by the jitted
        # chunk fns — a static spec, so changing it means a new engine
        self.compression = resolve_compression(compression)
        if agg == "auto":
            # the fused Pallas launch is the TPU path; its interpret-mode
            # emulation on CPU costs more than the per-leaf jnp tree —
            # EXCEPT for quantized wires, where the fused dequant-and-
            # reduce consumes the int8 payload directly and measures
            # faster than the quantize->dequantize->einsum reference
            # even under the interpreter
            agg = ("flat" if (jax.default_backend() == "tpu"
                              or self.compression.quantized) else "tree")
        self.agg = agg
        self.interpret = interpret
        self.with_metrics = with_metrics
        if donate is None:  # CPU jit cannot reuse donated buffers
            donate = jax.default_backend() != "cpu"
        self.donate = donate

        self.sharding = sharding
        C = len(clients)
        if C == 0 and (capacity is None or max_samples is None):
            # a task fixes feature shapes, but an empty engine still needs
            # explicit geometry (the founding fleet normally supplies it)
            raise ValueError("RoundEngine without founding clients needs "
                             "explicit capacity= and max_samples=")
        if capacity is None:
            capacity = C
        if capacity < max(C, 1):
            raise ValueError(f"capacity {capacity} < {C} founding clients")
        if sharding is not None:
            # every shard owns the same number of whole slots; the extra
            # columns are ordinary empty capacity slots (p=0, never train)
            capacity = sharding.pad_capacity(capacity)
        self.capacity = capacity
        nmax = max((c.n for c in clients), default=1)
        if max_samples is not None:
            nmax = max(nmax, max_samples)
        self.nmax = nmax
        # per-sample buffers are the task's business: one (capacity, Nmax,
        # *spec.shape) stack per named buffer (logreg: x/y; LM: tokens)
        stacks = {
            name: np.zeros((capacity, nmax) + spec.shape, spec.dtype)
            for name, spec in task.buffers.items()}
        # empty slots keep n=1 so the batch-index draw idx = min(u*n, n-1)
        # stays a valid gather (their alpha/coeff are 0 regardless)
        n_arr = np.ones(capacity, np.int32)
        cdf = np.tile(empty_slot_cdf(self.E), (capacity, 1))
        for i, c in enumerate(clients):
            for name, arr in self._client_rows(c).items():
                stacks[name][i, :c.n] = arr
            n_arr[i] = c.n
        cdf[:C] = trace_s_cdf(clients, self.E)
        # datasets move host->device exactly once, here; under sharding
        # each device receives only the slot rows it owns, and single
        # rows written later (admit/set_trace) go up replicated
        if sharding is not None:
            self._put_slots = sharding.put_client
            self._put_row = lambda a: jax.device_put(
                a, sharding.replicated())
        else:
            self._put_slots = self._put_row = jax.device_put
        self.data = {name: self._put_slots(buf)
                     for name, buf in stacks.items()}
        self.n = self._put_slots(n_arr)
        self.s_cdf = self._put_slots(cdf)
        self._fns = {}
        self._empty_cdf_row = None    # lazy device copy (see evict)
        self.trace_count = 0      # bumped at chunk trace time (see _get_fn)
        self._pspecs = None
        self._pspecs_built = False
        # telemetry (repro.obs): null by default — spans and counters on
        # this path are shared no-ops, so uninstrumented runs stay
        # bit-identical (pinned by tests/test_telemetry.py)
        self.telemetry = tel = resolve_telemetry(telemetry)
        self._m_traces = tel.counter(
            "engine_traces_total",
            "jitted chunk (re)traces — actual scan compiles")
        self._m_spans = tel.counter(
            "engine_spans_total", "run_span dispatches")
        self._m_rounds = tel.counter(
            "engine_rounds_total", "rounds executed by run_span")
        # analytic client->server traffic (core/compression.wire_bytes),
        # labeled by wire format — incremented per span from the realized
        # participation counts
        self._m_wire = tel.counter(
            "fed_wire_bytes_total",
            "client->server delta bytes (analytic, by wire format)",
            labelnames=("wire",))
        self._d_total: Optional[int] = None

    def _client_rows(self, client):
        """The task's per-sample arrays for one client, shape-checked
        against the engine's buffer specs."""
        arrays = self.task.client_arrays(client)
        for name, arr in arrays.items():
            spec = self.task.buffers[name]
            if arr.shape != (client.n,) + spec.shape:
                raise ValueError(
                    f"feature shape {arr.shape[1:]} != engine feature "
                    f"shape {spec.shape} (buffer {name!r})")
        return arrays

    def _param_specs(self, params):
        """The task's per-leaf PartitionSpecs (None => replicated),
        resolved once — only consulted under sharding.

        client_parallel vmaps a client axis over the federation axes, so
        a param spec may not also claim them (FSDP and client-parallelism
        would name the same mesh axis twice); the federation axes are
        stripped from every leaf spec, leaving pure TP ('model') sharding
        — the client_sequential mode keeps full FSDP x TP specs."""
        if not self._pspecs_built:
            specs = self.task.param_specs(params)
            if (specs is not None and self.sharding is not None
                    and self.mode == "client_parallel"):
                from jax.sharding import PartitionSpec as P
                fed = set(self.sharding.axes)

                def strip(entry):
                    if entry is None:
                        return None
                    if isinstance(entry, (tuple, list)):
                        kept = tuple(a for a in entry if a not in fed)
                        if not kept:
                            return None
                        # singleton tuples normalize to the bare name
                        # (tuple/bare spellings are cache-key-distinct)
                        return kept[0] if len(kept) == 1 else kept
                    return None if entry in fed else entry

                specs = jax.tree.map(
                    lambda s: P(*(strip(e) for e in s)), specs,
                    is_leaf=lambda x: isinstance(x, P))
            self._pspecs = specs
            self._pspecs_built = True
        return self._pspecs

    # legacy buffer aliases (pre-ClientTask layout)
    @property
    def data_x(self):
        return self.data["x"]

    @property
    def data_y(self):
        return self.data["y"]

    # -- capacity-slot lifecycle ----------------------------------------------
    def admit(self, slot: int, client) -> None:
        """Stage a client's data/size/trace-CDF into an engine slot.  The
        client may be brand new (constructed after engine build) — shapes
        are static, so no compiled span scan is invalidated.  Lands via
        the same fused multi-buffer write as admit_many (a k=1 burst):
        one transfer per buffer, one device dispatch total."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        with self.telemetry.span("engine.admit", slot=slot):
            self._admit_many([(slot, client)])

    def _staged_rows(self, client):
        """Zero-padded (Nmax, *spec.shape) rows for every task buffer."""
        if client.n > self.nmax:
            raise ValueError(
                f"client has {client.n} samples > slot capacity "
                f"{self.nmax}; build the engine with max_samples >= "
                f"{client.n}")
        rows = {}
        for name, arr in self._client_rows(client).items():
            spec = self.task.buffers[name]
            row = np.zeros((self.nmax,) + spec.shape, spec.dtype)
            row[:client.n] = arr
            rows[name] = row
        return rows

    def admit_many(self, assignments) -> None:
        """Admit an arrival burst in ONE fused device dispatch.

        assignments: sequence of (slot, client) pairs.  Per-client row
        staging happens host-side as in admit(), then the whole burst —
        every data buffer plus the n and s-CDF columns — goes up as one
        stacked device_put per buffer and lands in a single jitted
        multi-buffer scatter (_burst_writer) instead of 3+ transfers and
        scatters; under sharding every transfer replicates the rows to
        all devices, so coalescing cuts the dominant cost by ~k.  Bursts
        are padded to a power-of-two length by repeating the last
        (slot, row) pair, so at most log2(capacity)+1 scatter shapes
        ever compile (the zero-recompile churn contract)."""
        assignments = list(assignments)
        if not assignments:
            return
        with self.telemetry.span("engine.admit_many", k=len(assignments)):
            self._admit_many(assignments)

    def _admit_many(self, assignments, rows_of=None) -> None:
        for slot, _ in assignments:
            if not 0 <= slot < self.capacity:
                raise IndexError(
                    f"slot {slot} out of range [0, {self.capacity})")
        dup = [s for s, _ in assignments]
        if len(set(dup)) != len(dup):
            # duplicate-index scatter order is unspecified per buffer, so
            # one slot could mix two clients' rows across buffers
            raise ValueError(f"admit_many got duplicate slots: {dup}")
        rows_of = rows_of or self._staged_rows
        staged = [rows_of(c) for _, c in assignments]
        k = len(assignments)
        pad = _pow2_pad(k) - k
        stacks = {name: np.stack([st[name] for st in staged]
                                 + [staged[-1][name]] * pad)
                  for name in self.task.buffers}
        self.commit_burst(
            self.put_burst(stacks),
            slots=[s for s, _ in assignments],
            ns=[c.n for _, c in assignments],
            cdfs=[trace_cdf_row(c.trace, self.E) for _, c in assignments])

    # -- staged-cohort handoff (fed/bank.CohortStager) ------------------------
    def put_burst(self, stacks) -> dict:
        """Move pre-stacked (k, Nmax, *spec.shape) host buffers to device
        (replicated under sharding).  Pure transfer, no engine mutation —
        safe to call from a staging thread while a span runs.  All
        buffers go up in ONE batched device_put — per-buffer puts cost
        a host dispatch each."""
        host = {name: np.ascontiguousarray(a) for name, a in stacks.items()}
        if self.sharding is not None:
            return jax.device_put(host, self.sharding.replicated())
        return jax.device_put(host)

    def commit_burst(self, dev_rows, *, slots, ns, cdfs, idx=None) -> None:
        """Land a (possibly prefetched) burst: one fused jitted
        gather+scatter across every data buffer plus n and s_cdf.

        dev_rows: put_burst output — (K, Nmax, *spec.shape) device
        stacks; slots/ns/cdfs: per-written-slot values in slot order;
        idx: row index into dev_rows for each written slot (default
        identity), so a staged cohort can be committed as a subset or
        reordered.  n and the trace CDF always come from the *live*
        client at commit time (the caller's ns/cdfs), never from the
        staged stack — a TraceShift between staging and commit can't
        publish a stale law."""
        k = len(slots)
        if k == 0:
            return
        if idx is None:
            idx = list(range(k))
        pad = _pow2_pad(k) - k
        slots_h = np.asarray(list(slots) + [slots[-1]] * pad, np.int32)
        idx_h = np.asarray(list(idx) + [idx[-1]] * pad, np.int32)
        ns_h = np.asarray(list(ns) + [ns[-1]] * pad, np.int32)
        cdf_h = np.stack(list(cdfs) + [cdfs[-1]] * pad)
        if self.sharding is not None:
            slots_a, idx_a, ns_a = (jax.device_put(a)
                                    for a in (slots_h, idx_h, ns_h))
            cdf_rows = self._put_row(cdf_h)
        else:
            # one batched transfer — four small puts cost four host
            # dispatches on the boundary's critical path
            slots_a, idx_a, ns_a, cdf_rows = jax.device_put(
                (slots_h, idx_h, ns_h, cdf_h))
        if self.sharding is not None:
            writer = _burst_writer(
                tuple(sorted((name, buf.sharding)
                             for name, buf in self.data.items())),
                self.n.sharding, self.s_cdf.sharding)
        else:
            writer = _burst_writer((), None, None)
        self.data, self.n, self.s_cdf = writer(
            self.data, dev_rows, self.n, ns_a, self.s_cdf, cdf_rows,
            idx_a, slots_a)

    def evict(self, slot: int) -> None:
        """Free a slot: its s-law collapses to the empty-slot atom at 0
        and n drops to 1 (keeps gathers valid).  Stale data stays on
        device — it is unreachable (alpha=0, coeff=0) until the next
        admit overwrites it."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        with self.telemetry.span("engine.evict", slot=slot):
            if self._empty_cdf_row is None:
                # the empty-slot law is the same for every evict — put
                # it once
                self._empty_cdf_row = self._put_row(empty_slot_cdf(self.E))
            self.n, self.s_cdf = _evict_write(
                self.n, self.s_cdf, self._empty_cdf_row, np.int32(slot))

    def set_trace(self, slot: int, trace) -> None:
        """Swap the availability law of an occupied slot (TraceShift)."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        with self.telemetry.span("engine.set_trace", slot=slot):
            self.s_cdf = _slot_write(
                self.s_cdf, self._put_row(trace_cdf_row(trace, self.E)),
                np.int32(slot))

    # -- jitted chunk builders ------------------------------------------------
    def _round_core(self, params, data, alpha, idx, tau, p,
                    rb_tau0, rb_boost, lr_shift):
        gather = jax.vmap(lambda d, i: jnp.take(d, i, axis=0))
        batches = self.task.make_batch(
            {name: gather(buf, idx) for name, buf in data.items()})
        s = jnp.sum(alpha, axis=-1)
        coeffs = scheme_coefficients(self.scheme, p, s, self.E)
        # fast-reboot boost, exact O((tau-tau0)^-2) decay at every in-chunk
        # tau; rb_boost == 1 for never-rebooted clients => multiplier 1
        dt = jnp.maximum(tau - rb_tau0, 0).astype(jnp.float32)
        coeffs = coeffs * (1.0 + (rb_boost - 1.0) / jnp.square(1.0 + dt))
        eta = jnp.float32(self.eta0) / jnp.maximum(
            (tau + 1 - lr_shift).astype(jnp.float32), 1.0)
        pspecs = (self._param_specs(params) if self.sharding is not None
                  else None)
        if self.compression.active and pspecs is not None:
            # the quantizer works on the flattened-leaf layout, which the
            # model-sharded path cannot take (mixed-sharding leaf concat)
            raise ValueError(
                "compression is not supported with model-sharded params "
                "(task param_specs); use replicated params or "
                "compression='none'")
        if self.mode == "client_sequential":
            new_params, m = fed_round_sequential(
                self.loss_fn, params, batches, alpha, coeffs, eta,
                with_metrics=self.with_metrics, sharding=self.sharding,
                param_specs=pspecs, compression=self.compression)
        else:
            # model-spec'd params must take the tree path: the flat
            # layout concatenates mixed-sharding delta leaves (the GSPMD
            # pattern safe_concat exists for) and materializes the
            # reduced (D_total,) vector replicated over the model axes
            agg = "tree" if pspecs is not None else self.agg
            new_params, m = fed_round_parallel(
                self.loss_fn, params, batches, alpha, coeffs, eta,
                agg=agg, interpret=self.interpret,
                with_metrics=self.with_metrics, sharding=self.sharding,
                param_specs=pspecs, compression=self.compression)
        return new_params, {"s": s, "eta": eta,
                            "delta_norm": m["delta_norm"]}

    def _get_fn(self, R: int, sampled: bool):
        cache_key = (R, sampled)
        if cache_key in self._fns:
            return self._fns[cache_key]

        # round indices are derived INSIDE the jit from the scalar span
        # start (R is static per compiled chunk) — a host-side
        # jnp.arange per chunk costs a dispatch on the boundary path
        if sampled:
            def chunk(params, data, n, s_cdf, key, active, tau0,
                      p, rb_tau0, rb_boost, lr_shift):
                # trace-time side effect: the body runs only when jax
                # (re)traces, so this counts actual compiles — the
                # zero-recompile invariant's signal (the C++ fastpath
                # cache also keys on argument committed-ness, so its
                # _cache_size() over-reports)
                self.trace_count += 1
                self._m_traces.inc()
                taus = tau0 + jnp.arange(R, dtype=jnp.int32)

                def body(w, tau):
                    # per-round key: the draw for round tau is a pure
                    # function of (base key, tau), invariant to span and
                    # chunk structure — the checkpoint/resume contract
                    kt = jax.random.fold_in(key, tau)
                    alpha, idx = device_sample_round(
                        kt, active, n, s_cdf, self.E, self.B)
                    if self.sharding is not None:
                        # keep the per-round draws sharded on the client dim
                        alpha = self.sharding.constrain_client(alpha, 0)
                        idx = self.sharding.constrain_client(idx, 0)
                    return self._round_core(w, data, alpha, idx,
                                            tau, p, rb_tau0, rb_boost,
                                            lr_shift)
                return jax.lax.scan(body, params, taus)
        else:
            def chunk(params, data, alphas, idxs, tau0, p,
                      rb_tau0, rb_boost, lr_shift):
                self.trace_count += 1
                self._m_traces.inc()
                taus = tau0 + jnp.arange(R, dtype=jnp.int32)

                def body(w, xs):
                    alpha, idx, tau = xs
                    return self._round_core(w, data, alpha, idx,
                                            tau, p, rb_tau0, rb_boost,
                                            lr_shift)
                return jax.lax.scan(body, params, (alphas, idxs, taus))

        fn = jax.jit(chunk, donate_argnums=(0,) if self.donate else ())
        self._fns[cache_key] = fn
        return fn

    # -- host entry point -----------------------------------------------------
    def run_span(self, params, tau_start: int, n_rounds: int, *, p, active,
                 lr_shift_tau: int, reboot_tau0, reboot_boost,
                 plan=None, key=None, host_metrics: bool = True):
        """Run n_rounds starting at tau_start with fixed membership.

        plan: (alphas (R, C, E), idxs (R, C, E, B)) host-sampled arrays
        (numpy-RNG parity mode), or key: a jax PRNG key for fully
        on-device sampling.  Exactly one must be given.

        Returns (params, metrics) with metrics stacked over rounds:
        s (R, C), eta (R,), delta_norm (R,).  With
        ``host_metrics=False`` the metrics stay device-side as
        per-chunk lists ({key: [chunk arrays]}) and wire accounting is
        deferred — the caller converts later (``account_uploads``), so
        the host never blocks on the span and dispatch of the *next*
        span's boundary work overlaps this span's compute.
        """
        if (plan is None) == (key is None):
            raise ValueError("pass exactly one of plan= or key=")
        if n_rounds <= 0:
            # degenerate span: params unchanged, empty per-round metrics
            return params, {"s": np.zeros((0, self.capacity), np.float32),
                            "eta": np.zeros(0, np.float32),
                            "delta_norm": np.zeros(0, np.float32)}
        if self._d_total is None:
            # model size in floats, cached before params may be donated
            self._d_total = sum(
                int(np.prod(np.shape(leaf)))
                for leaf in jax.tree.leaves(params))
        # no-op for args already device-resident in the right dtype
        # (the StreamScheduler's cached span args) — an unconditional
        # jnp.asarray costs ~60us of python per arg per span
        p = _dev(p, jnp.float32)
        active = _dev(active, jnp.float32)
        rb_tau0 = _dev(reboot_tau0, jnp.int32)
        rb_boost = _dev(reboot_boost, jnp.float32)
        lr_shift = np.int32(lr_shift_tau)
        if plan is not None:
            alphas = jnp.asarray(plan[0], jnp.float32)
            idxs = jnp.asarray(plan[1], jnp.int32)
        if self.sharding is not None:
            # span args are per-slot columns -> shard with the buffers;
            # params enter replicated (small models) or stay sharded per
            # the task's model specs (the large-model FSDP x TP path)
            fs = self.sharding
            p, active, rb_tau0, rb_boost = (
                fs.put_client(a) for a in (p, active, rb_tau0, rb_boost))
            params = fs.put_params(params, self._param_specs(params))
            if plan is not None:
                alphas = fs.put_client(alphas, axis_dim=1)
                idxs = fs.put_client(idxs, axis_dim=1)

        tel = self.telemetry
        self._m_spans.inc()
        self._m_rounds.inc(n_rounds)
        # optional jax profiler hook: a Telemetry built with
        # jax_trace_dir= wraps every device dispatch (incl. the Pallas
        # agg path) in a profiler trace for offline TensorBoard analysis
        prof = (jax.profiler.trace(tel.jax_trace_dir)
                if tel.jax_trace_dir else contextlib.nullcontext())
        ms, off, tau = [], 0, tau_start
        with tel.span("engine.run_span", tau=tau_start,
                      rounds=n_rounds), prof:
            for r in _pow2_chunks(n_rounds, self.chunk_size):
                tau0 = np.int32(tau)     # round indices derive in-jit
                if plan is not None:
                    fn = self._get_fn(r, sampled=False)
                    params, m = fn(params, self.data,
                                   alphas[off:off + r], idxs[off:off + r],
                                   tau0, p, rb_tau0, rb_boost, lr_shift)
                else:
                    fn = self._get_fn(r, sampled=True)
                    # the base key passes through unchanged: per-round
                    # randomness folds tau inside the chunk body, so chunk
                    # splits never reuse (or re-shuffle) randomness
                    params, m = fn(params, self.data, self.n,
                                   self.s_cdf, key, active, tau0, p,
                                   rb_tau0, rb_boost, lr_shift)
                ms.append(jax.tree.map(np.asarray, m) if host_metrics
                          else m)
                off += r
                tau += r
        if not host_metrics:
            return params, {k: [m[k] for m in ms] for k in ms[0]}
        metrics = {k: np.concatenate([m[k] for m in ms]) for k in ms[0]}
        self.account_uploads(metrics["s"])
        return params, metrics

    def account_uploads(self, s: np.ndarray) -> None:
        """Charge fed_wire_bytes_total for a span's completed-epoch
        matrix — one delta upload per client-round with any epochs
        (run_span does this inline; deferred-metrics callers do it at
        conversion time)."""
        uploads = int((s > 0).sum())
        if uploads:
            self._m_wire.labels(self.compression.name).inc(
                wire_bytes(self._d_total, self.compression,
                           n_clients=uploads))
