"""Device-resident multi-round federated engine.

The seed host loop (FederatedTrainer.run) rebuilt a (C, E, B, ...) numpy
batch tensor, shipped it host->device, and computed scheme coefficients in
numpy — every round.  This engine moves the whole round inside one jitted,
chunked ``lax.scan``:

  * client datasets are padded to a common length and live on device once
    as (C, Nmax, ...) stacks; per-round batch selection is an on-device
    gather (vmapped ``jnp.take``);
  * participation masks alpha can be sampled on device (inverse-CDF draw
    from an exact per-client table of the paper's Table-2 trace law, see
    trace_s_cdf) or supplied as a host-precomputed *plan* — the plan path
    consumes the trainer's numpy RNG in the seed order, so it is
    sample-for-sample identical to the legacy loop and is what the parity
    tests compare against;
  * scheme A/B/C coefficients, fast-reboot boosts (per-client (tau0,
    boost) arrays evaluated at each in-chunk tau, so the O(dt^-2) decay is
    exact mid-chunk) and the staircase LR are computed inside the step;
  * R rounds run per host dispatch via ``lax.scan`` over power-of-two
    chunk sizes (bounded compile cache), with ``params`` donated to the
    chunk call on backends that support buffer donation;
  * aggregation uses the pytree-flat path: the delta pytree is flattened
    to one (C, D_total) buffer and reduced with a single weighted_agg
    Pallas launch per round (``agg="flat"``), or the per-leaf jnp tree
    path (``agg="tree"``);
  * with ``sharding=FedSharding(...)`` the client/slot axis of every
    buffer is sharded over the mesh's federation axis: local epochs run
    device-parallel and the delta reduction ends in a cross-device
    all-reduce that leaves params replicated (see fed/sharding.py and
    docs/scaling.md).

The host loop above the engine (StreamScheduler in fed/stream.py — with
FederatedTrainer as a thin adapter over it) handles participation events,
span splitting and evaluation at span boundaries.

Usage::

    eng = RoundEngine(loss_fn=loss_fn, clients=clients, local_epochs=5,
                      batch_size=10, capacity=16)
    params, metrics = eng.run_span(params, tau_start=0, n_rounds=32,
                                   p=p, active=active, lr_shift_tau=0,
                                   reboot_tau0=rb0, reboot_boost=rbb,
                                   key=jax.random.PRNGKey(0))
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import scheme_coefficients
from repro.core.fed_step import fed_round_parallel


def _pow2_chunks(n: int, cap: int):
    """Split n rounds into power-of-two chunk lengths <= cap (largest
    first), so at most log2(cap)+1 distinct scan lengths ever compile."""
    out = []
    while n > 0:
        r = min(1 << (n.bit_length() - 1), 1 << (cap.bit_length() - 1))
        out.append(r)
        n -= r
    return out


@functools.lru_cache(maxsize=1024)
def trace_cdf_row(trace, E: int) -> np.ndarray:
    """CDF table of completed epochs s for one trace: (E+1,) with
    cdf[k] = P(s <= k).  Cached per (trace, E) — traces are frozen
    dataclasses and the betainc evaluation dominates admit() otherwise;
    callers must not mutate the returned array.

    s = round(frac * E) for frac ~ Beta(a, b) mixed with an inactivity
    atom at 0, so the s-law is a discrete distribution over {0..E} whose
    CDF is exact regularized-incomplete-beta evaluations at the rounding
    boundaries (k + 1/2)/E — computed once at engine build / admit time,
    which removes the gamma rejection sampler from the hot path entirely
    while sampling the *identical* distribution as Trace.sample_s.
    """
    from jax.scipy.special import betainc

    ks = np.arange(E + 1)
    ab = trace._beta_params()
    if ab is None:
        # degenerate trace: frac == mean deterministically
        s0 = int(np.clip(np.round(trace.mean * E), 0, E))
        base = (ks >= s0).astype(np.float64)
    else:
        x = np.clip((ks + 0.5) / E, 0.0, 1.0)
        base = np.asarray(betainc(ab[0], ab[1], x), np.float64)
        base[-1] = 1.0
    q = trace.p_inactive
    if q > 0:
        # inactive rounds put an atom at s = 0
        row = q + (1.0 - q) * base
    else:
        # CPU-contention traces never produce zero epochs: the s=0
        # mass moves to s=1 (Trace.sample_s's maximum(s, 1))
        row = base.copy()
        row[0] = 0.0
    row[-1] = 1.0
    return row.astype(np.float32)


# an empty slot's s-law: all mass at s = 0, so the slot never trains even
# before the scheduler's active mask is applied
def empty_slot_cdf(E: int) -> np.ndarray:
    return np.ones(E + 1, np.float32)


def trace_s_cdf(clients, E: int) -> np.ndarray:
    """Per-client CDF table of completed epochs s: (C, E+1) with
    cdf[c, k] = P(s_c <= k).  See trace_cdf_row."""
    return np.stack([trace_cdf_row(cl.trace, E) for cl in clients]) \
        if clients else np.zeros((0, E + 1), np.float32)


def device_sample_span(key, R: int, active, n, s_cdf, E: int, B: int):
    """On-device sampling of participation + batch indices for a whole
    R-round span in one vectorized draw.

    active: (C,) 0/1 mask of clients participating this span; n: (C,)
    dataset sizes; s_cdf: (C, E+1) per-client CDF of completed epochs
    (trace_s_cdf).  Returns alphas (R, C, E) f32, idxs (R, C, E, B) i32.
    """
    ks, kb = jax.random.split(key)
    C = n.shape[0]
    # inverse-CDF draw of s: s = #{k : cdf[k] < u}
    u = jax.random.uniform(ks, (R, C))
    s = jnp.sum(u[:, :, None] > s_cdf[None, :, :], axis=-1)
    s = s.astype(jnp.float32) * active[None, :]
    alphas = (jnp.arange(E, dtype=jnp.float32)[None, None, :]
              < s[:, :, None]).astype(jnp.float32)
    ub = jax.random.uniform(kb, (R, C, E, B))
    nf = n.astype(jnp.float32)[None, :, None, None]
    idxs = jnp.minimum((ub * nf).astype(jnp.int32),
                       n[None, :, None, None] - 1)
    return alphas, idxs


def _slot_write(buf, row, slot):
    """dynamic-update-slice of one leading-axis row (jitted; one trace per
    buffer dtype/shape, reused for every admit/evict/set_trace)."""
    return jax.lax.dynamic_update_index_in_dim(buf, row, slot, axis=0)


_slot_write = jax.jit(_slot_write)


class RoundEngine:
    """Runs R federated rounds per host dispatch on device-resident data.

    Membership, data weights p, the LR-restart round and reboot state are
    constant within a span (the trainer splits spans at every event), so
    they enter the chunk as plain array arguments — values change between
    chunks without recompiling.

    Capacity slots: with ``capacity=C_max`` the engine preallocates C_max
    client slots (data/size/trace-CDF buffers have a C_max leading axis);
    slots beyond the founding clients start empty (n=1, s-law all mass at
    0).  ``admit(slot, client)`` / ``evict(slot)`` / ``set_trace(slot,
    trace)`` mutate one slot with a single host->device transfer plus a
    dynamic-update-slice each — buffer shapes never change, so the
    compiled span scans are reused across arbitrarily many membership
    events (no rebuild, no recompile).

    Sharding: with ``sharding=FedSharding(mesh)`` the slot axis of every
    client buffer is sharded over the mesh's federation ('data') axis
    (capacity is padded so each shard owns whole slots), local epochs run
    in parallel across devices and aggregation all-reduces to replicated
    params.  Slot writes stay one replicated-row device_put plus the same
    dynamic-update-slice, which XLA lowers to a masked shard-local write —
    so the zero-recompile membership-churn contract is preserved
    unchanged under sharding.
    """

    def __init__(self, *, loss_fn, clients, local_epochs: int,
                 batch_size: int, scheme: str = "C", eta0: float = 0.01,
                 chunk_size: int = 16, agg: str = "auto",
                 interpret=None, donate: Optional[bool] = None,
                 with_metrics: bool = False,
                 capacity: Optional[int] = None,
                 max_samples: Optional[int] = None,
                 sharding=None):
        self.loss_fn = loss_fn
        self.E = local_epochs
        self.B = batch_size
        self.scheme = scheme
        self.eta0 = eta0
        self.chunk_size = max(1, chunk_size)
        if agg == "auto":
            # the fused Pallas launch is the TPU path; its interpret-mode
            # emulation on CPU costs more than the per-leaf jnp tree
            agg = "flat" if jax.default_backend() == "tpu" else "tree"
        self.agg = agg
        self.interpret = interpret
        self.with_metrics = with_metrics
        if donate is None:  # CPU jit cannot reuse donated buffers
            donate = jax.default_backend() != "cpu"
        self.donate = donate

        self.sharding = sharding
        C = len(clients)
        if C == 0:
            raise ValueError("RoundEngine needs at least one founding "
                             "client (fixes the feature shape)")
        if capacity is None:
            capacity = C
        if capacity < C:
            raise ValueError(f"capacity {capacity} < {C} founding clients")
        if sharding is not None:
            # every shard owns the same number of whole slots; the extra
            # columns are ordinary empty capacity slots (p=0, never train)
            capacity = sharding.pad_capacity(capacity)
        self.capacity = capacity
        ns = [c.n for c in clients]
        nmax = max(ns)
        if max_samples is not None:
            nmax = max(nmax, max_samples)
        self.nmax = nmax
        x0 = np.asarray(clients[0].x)
        self._xdim = x0.shape[1:]
        X = np.zeros((capacity, nmax) + self._xdim, np.float32)
        Y = np.zeros((capacity, nmax), np.int32)
        # empty slots keep n=1 so the batch-index draw idx = min(u*n, n-1)
        # stays a valid gather (their alpha/coeff are 0 regardless)
        n_arr = np.ones(capacity, np.int32)
        cdf = np.tile(empty_slot_cdf(self.E), (capacity, 1))
        for i, c in enumerate(clients):
            X[i, :c.n] = c.x
            Y[i, :c.n] = c.y
            n_arr[i] = c.n
        cdf[:C] = trace_s_cdf(clients, self.E)
        # datasets move host->device exactly once, here; under sharding
        # each device receives only the slot rows it owns, and single
        # rows written later (admit/set_trace) go up replicated
        if sharding is not None:
            self._put_slots = sharding.put_client
            self._put_row = lambda a: jax.device_put(
                a, sharding.replicated())
        else:
            self._put_slots = self._put_row = jax.device_put
        self.data_x = self._put_slots(X)
        self.data_y = self._put_slots(Y)
        self.n = self._put_slots(n_arr)
        self.s_cdf = self._put_slots(cdf)
        self._fns = {}

    # -- capacity-slot lifecycle ----------------------------------------------
    def admit(self, slot: int, client) -> None:
        """Stage a client's data/size/trace-CDF into an engine slot: one
        host->device transfer + dynamic-update-slice per buffer.  The
        client may be brand new (constructed after engine build) — shapes
        are static, so no compiled span scan is invalidated."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        if client.n > self.nmax:
            raise ValueError(
                f"client has {client.n} samples > slot capacity "
                f"{self.nmax}; build the engine with max_samples >= "
                f"{client.n}")
        x = np.asarray(client.x, np.float32)
        if x.shape[1:] != self._xdim:
            raise ValueError(f"feature shape {x.shape[1:]} != engine "
                             f"feature shape {self._xdim}")
        xrow = np.zeros((self.nmax,) + self._xdim, np.float32)
        yrow = np.zeros(self.nmax, np.int32)
        xrow[:client.n] = x
        yrow[:client.n] = client.y
        s = jnp.int32(slot)
        self.data_x = _slot_write(self.data_x, self._put_row(xrow), s)
        self.data_y = _slot_write(self.data_y, self._put_row(yrow), s)
        self.n = _slot_write(self.n, jnp.int32(client.n), s)
        self.s_cdf = _slot_write(
            self.s_cdf, self._put_row(trace_cdf_row(client.trace, self.E)),
            s)

    def evict(self, slot: int) -> None:
        """Free a slot: its s-law collapses to the empty-slot atom at 0
        and n drops to 1 (keeps gathers valid).  Stale data stays on
        device — it is unreachable (alpha=0, coeff=0) until the next
        admit overwrites it."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        s = jnp.int32(slot)
        self.n = _slot_write(self.n, jnp.int32(1), s)
        self.s_cdf = _slot_write(
            self.s_cdf, self._put_row(empty_slot_cdf(self.E)), s)

    def set_trace(self, slot: int, trace) -> None:
        """Swap the availability law of an occupied slot (TraceShift)."""
        if not 0 <= slot < self.capacity:
            raise IndexError(f"slot {slot} out of range [0, {self.capacity})")
        self.s_cdf = _slot_write(
            self.s_cdf, self._put_row(trace_cdf_row(trace, self.E)),
            jnp.int32(slot))

    # -- jitted chunk builders ------------------------------------------------
    def _round_core(self, params, data_x, data_y, alpha, idx, tau, p,
                    rb_tau0, rb_boost, lr_shift):
        gather = jax.vmap(lambda d, i: jnp.take(d, i, axis=0))
        batches = {"x": gather(data_x, idx), "y": gather(data_y, idx)}
        s = jnp.sum(alpha, axis=-1)
        coeffs = scheme_coefficients(self.scheme, p, s, self.E)
        # fast-reboot boost, exact O((tau-tau0)^-2) decay at every in-chunk
        # tau; rb_boost == 1 for never-rebooted clients => multiplier 1
        dt = jnp.maximum(tau - rb_tau0, 0).astype(jnp.float32)
        coeffs = coeffs * (1.0 + (rb_boost - 1.0) / jnp.square(1.0 + dt))
        eta = jnp.float32(self.eta0) / jnp.maximum(
            (tau + 1 - lr_shift).astype(jnp.float32), 1.0)
        new_params, m = fed_round_parallel(
            self.loss_fn, params, batches, alpha, coeffs, eta,
            agg=self.agg, interpret=self.interpret,
            with_metrics=self.with_metrics, sharding=self.sharding)
        return new_params, {"s": s, "eta": eta,
                            "delta_norm": m["delta_norm"]}

    def _get_fn(self, R: int, sampled: bool):
        cache_key = (R, sampled)
        if cache_key in self._fns:
            return self._fns[cache_key]

        if sampled:
            def chunk(params, data_x, data_y, n, s_cdf, key, active, taus,
                      p, rb_tau0, rb_boost, lr_shift):
                alphas, idxs = device_sample_span(
                    key, R, active, n, s_cdf, self.E, self.B)
                if self.sharding is not None:
                    # keep the per-span draws sharded on the client dim
                    alphas = self.sharding.constrain_client(alphas, 1)
                    idxs = self.sharding.constrain_client(idxs, 1)

                def body(w, xs):
                    alpha, idx, tau = xs
                    return self._round_core(w, data_x, data_y, alpha, idx,
                                            tau, p, rb_tau0, rb_boost,
                                            lr_shift)
                return jax.lax.scan(body, params, (alphas, idxs, taus))
        else:
            def chunk(params, data_x, data_y, alphas, idxs, taus, p,
                      rb_tau0, rb_boost, lr_shift):
                def body(w, xs):
                    alpha, idx, tau = xs
                    return self._round_core(w, data_x, data_y, alpha, idx,
                                            tau, p, rb_tau0, rb_boost,
                                            lr_shift)
                return jax.lax.scan(body, params, (alphas, idxs, taus))

        fn = jax.jit(chunk, donate_argnums=(0,) if self.donate else ())
        self._fns[cache_key] = fn
        return fn

    # -- host entry point -----------------------------------------------------
    def run_span(self, params, tau_start: int, n_rounds: int, *, p, active,
                 lr_shift_tau: int, reboot_tau0, reboot_boost,
                 plan=None, key=None):
        """Run n_rounds starting at tau_start with fixed membership.

        plan: (alphas (R, C, E), idxs (R, C, E, B)) host-sampled arrays
        (numpy-RNG parity mode), or key: a jax PRNG key for fully
        on-device sampling.  Exactly one must be given.

        Returns (params, metrics) with metrics stacked over rounds:
        s (R, C), eta (R,), delta_norm (R,).
        """
        if (plan is None) == (key is None):
            raise ValueError("pass exactly one of plan= or key=")
        if n_rounds <= 0:
            # degenerate span: params unchanged, empty per-round metrics
            return params, {"s": np.zeros((0, self.capacity), np.float32),
                            "eta": np.zeros(0, np.float32),
                            "delta_norm": np.zeros(0, np.float32)}
        p = jnp.asarray(p, jnp.float32)
        active = jnp.asarray(active, jnp.float32)
        rb_tau0 = jnp.asarray(reboot_tau0, jnp.int32)
        rb_boost = jnp.asarray(reboot_boost, jnp.float32)
        lr_shift = jnp.int32(lr_shift_tau)
        if plan is not None:
            alphas = jnp.asarray(plan[0], jnp.float32)
            idxs = jnp.asarray(plan[1], jnp.int32)
        if self.sharding is not None:
            # span args are per-slot columns -> shard with the buffers;
            # params enter (and stay) replicated across the mesh
            fs = self.sharding
            p, active, rb_tau0, rb_boost = (
                fs.put_client(a) for a in (p, active, rb_tau0, rb_boost))
            params = fs.put_replicated(params)
            if plan is not None:
                alphas = fs.put_client(alphas, axis_dim=1)
                idxs = fs.put_client(idxs, axis_dim=1)

        ms, off, tau = [], 0, tau_start
        for r in _pow2_chunks(n_rounds, self.chunk_size):
            taus = jnp.arange(tau, tau + r, dtype=jnp.int32)
            if plan is not None:
                fn = self._get_fn(r, sampled=False)
                params, m = fn(params, self.data_x, self.data_y,
                               alphas[off:off + r], idxs[off:off + r],
                               taus, p, rb_tau0, rb_boost, lr_shift)
            else:
                fn = self._get_fn(r, sampled=True)
                # fold per chunk so split chunks never reuse randomness
                sub = jax.random.fold_in(key, tau)
                params, m = fn(params, self.data_x, self.data_y, self.n,
                               self.s_cdf, sub, active, taus, p,
                               rb_tau0, rb_boost, lr_shift)
            ms.append(jax.tree.map(np.asarray, m))
            off += r
            tau += r
        metrics = {k: np.concatenate([m[k] for m in ms]) for k in ms[0]}
        return params, metrics
