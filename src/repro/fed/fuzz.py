"""Property-based event-stream fuzzer for the federation control plane.

The streaming scheduler promises hard invariants under *any* interleaving
of participation events — arrivals, departures (include/exclude), rejoins,
trace shifts, inactivity bursts — with kills and resumes anywhere between
spans.  This module generates seeded random interleavings and checks the
promises on every one:

  exact-resume     killing the run at arbitrary span boundaries (in-memory
                   FedState.to_dict round-trip, the same serialization the
                   on-disk checkpoints use) and resuming yields a history
                   and final params **bit-identical** to the uninterrupted
                   run;
  zero-recompile   no fuzz case may grow the engine's jit cache after
                   warm-up: events cost slot writes, never a recompile
                   (the per-instance `_fns` key set and every function's
                   tracing-cache size are pinned against a baseline);
  weight-sanity    every span's membership-derived arguments are lawful —
                   p >= 0 with total mass in (0, 1] (include-departures
                   keep their mass in the normalization while holding no
                   slot), active slots carry positive weight, 0 <= s <= E
                   with s > 0 only on active slots, the scheme A/B/C
                   coefficients computed from (p, s) are finite and
                   non-negative, and eta(t) = eta0 / max(t+1-lr_shift, 1)
                   for the forward-filled LR-shift round;
  plan-parity      mode="plan" (host-RNG sampling) walks the identical
                   control-plane trajectory as mode="device": same event
                   application log, same eta sequence, same per-span
                   (p, active, lr_shift), same final membership.  (Epoch
                   counts s are sample-path quantities drawn from
                   different RNG streams, so they are *not* compared.)

One warm engine is pooled across all cases (a fresh engine costs seconds
of XLA compilation; re-staging slots costs milliseconds): each case evicts
every slot and re-admits its own client set, which is exactly the
restore-into-warm-engine path the supervised service uses for recovery.

Beyond the single-engine invariants, two cross-cutting checks pool
*multiple* executions of one case:

  backend-parity   the same seeded op schedule runs on a pool of
                   execution backends — client_parallel (the default
                   fused path), client_sequential (the streaming
                   accumulate path), and the sharded engine under a
                   multi-device mesh (tests/_fuzz_backends_check.py
                   re-execs with 4 virtual devices) — and every backend
                   must produce the identical control-plane trajectory
                   (tau/event/eta/n_active and the exact per-round epoch
                   counts s: device sampling folds the round index, so
                   the draw stream is backend-invariant) with final
                   params equal to numerical tolerance (aggregation
                   order differs across backends);
  chaos-bitexact   fuzz cases double as *supervised chaos* workloads:
                   the case's event schedule is submitted up-front to a
                   real ``FederationService(supervise=True)`` while a
                   seeded ``FaultPlan.generate`` schedule crashes the
                   worker, tears spans mid-run, breaks and corrupts
                   snapshots, and floods the queue — and the recovered
                   history and params must be bit-identical to the
                   fault-free service run (events are submitted before
                   ``start()`` so the merge-stale ingest policy sees
                   every event at the same ``next_tau`` in both runs —
                   and in journal replay after a rollback).

A violation raises InvariantViolation carrying the case seed — re-running
``run_fuzz_case(harness, seed)`` (or ``run_chaos_case`` /
``run_cross_backend_case``) replays the exact interleaving.

tests/test_fuzz_invariants.py runs a fast corpus in tier-1;
benchmarks/fuzz_bench.py (``run.py --fuzz``) runs the nightly-size one.
fed/validate.py layers the Theorem 3.1 scoring on top (run/validate
split — see docs/robustness.md).
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import scheme_coefficients
from repro.core.participation import TRACES
from repro.fed.engine import RoundEngine
from repro.fed.events import (Arrival, Departure, InactivityBurst,
                              TraceShift, event_from_dict, event_to_dict)
from repro.fed.state import FedState
from repro.fed.stream import StreamScheduler


class InvariantViolation(AssertionError):
    """A fuzz case broke a control-plane invariant.  The message leads
    with the case seed so the interleaving can be replayed exactly."""

    def __init__(self, seed: int, invariant: str, detail: str):
        self.seed = seed
        self.invariant = invariant
        super().__init__(f"[fuzz seed={seed}] {invariant}: {detail}")


# -- case generation -----------------------------------------------------------

@dataclass
class FuzzCase:
    """A seeded op program: ("push", event_dict), ("run", n), ("kill",).
    Events are stored in codec (dict) form so every execution
    materializes fresh payload objects — TraceShift mutates Client.trace
    in place, and two runs of one case must never share a Client."""
    seed: int
    ops: List[Tuple] = field(default_factory=list)
    total_rounds: int = 0

    @property
    def n_kills(self) -> int:
        return sum(1 for op in self.ops if op[0] == "kill")


def generate_case(seed: int, *, n_founding: int = 4, capacity: int = 8,
                  n_arrival_pool: int = 4, max_ops: int = 14,
                  max_kills: int = 2) -> FuzzCase:
    """A random-but-valid interleaving.  A conservative occupancy/
    membership simulation keeps programs inside the engine's contract:
    arrivals never exceed free slots (allocated at event *creation*, the
    pessimistic bound), exclude-departures never drive the objective
    below two members (data_weights normalizes by total mass), and
    rejoins pair 1:1 with prior include-departures (the freed slot is
    reserved for them).  Duplicate deliveries are injected for
    Departure/TraceShift (idempotent or deterministic on replay);
    payload-carrying Arrivals are never duplicated — registering the same
    payload twice is a *second* client by design (docs/robustness.md)."""
    rng = np.random.default_rng(seed)
    ops: List[Tuple] = []
    cursor = 0                       # rounds scheduled so far
    max_tau = 0                      # largest event tau pushed
    free = capacity - n_founding     # pessimistic free-slot budget
    members = set(range(n_founding))           # objective lower bound
    include_departed: List[int] = []           # rejoinable ids
    slotted = set(range(n_founding))           # ids that may trace-shift
    # a TraceShift dereferences clients[i], so it must not apply before
    # the arrival that registers i: clamp its tau to the arrival's
    min_tau = {i: 0 for i in range(n_founding)}
    next_arrival = 0                           # index into arrival pool
    next_id = n_founding                       # id a new payload will get
    last_fresh_tau = 0               # fresh-arrival taus NON-DECREASING:
    # fresh payloads are registered in *application* order, so a later
    # pool entry landing at an earlier tau would swap the ids this
    # simulation hands to shifts/departures (clients[i] IndexError when
    # a shift for the swapped id applies before its arrival)
    kills = 0
    excludes = 0

    def push(e) -> None:
        nonlocal max_tau
        max_tau = max(max_tau, e.tau)
        ops.append(("push", event_to_dict(e)))

    n_ops = int(rng.integers(8, max_ops + 1))
    for _ in range(n_ops):
        kind = rng.choice(["run", "arrival", "departure", "rejoin",
                           "shift", "burst", "kill"],
                          p=[0.30, 0.13, 0.13, 0.10, 0.14, 0.10, 0.10])
        tau = cursor + int(rng.integers(0, 4))   # near-future (or stale
        if rng.random() < 0.2:                   # news for past rounds)
            tau = max(0, cursor - 1)
        if kind == "run":
            n = int(rng.integers(1, 6))
            ops.append(("run", n))
            cursor += n
        elif kind == "arrival" and free > 0 \
                and next_arrival < n_arrival_pool:
            tau = max(tau, last_fresh_tau)
            last_fresh_tau = tau
            push(Arrival(tau, client_id=-(next_arrival + 1)))
            # negative ids are pool references resolved at execution
            free -= 1
            members.add(next_id)
            slotted.add(next_id)
            min_tau[next_id] = tau
            next_arrival += 1
            next_id += 1
        elif kind == "departure" and members:
            i = int(rng.choice(sorted(members)))
            # the objective only ever shrinks via exclude (include keeps
            # the mass), so capping total excludes below n_founding - 1
            # keeps it nonempty under ANY application order — arrivals
            # pending at the departure boundary must not be counted on
            if excludes < n_founding - 2 and rng.random() < 0.5:
                push(Departure(tau, client_id=i, policy="exclude"))
                members.discard(i)
                excludes += 1
            else:
                push(Departure(tau, client_id=i, policy="include"))
                members.discard(i)
                include_departed.append(i)
            slotted.discard(i)
            if rng.random() < 0.25:              # duplicate delivery:
                push(Departure(tau, client_id=i,  # second is a no-op
                               policy="include"))
        elif kind == "rejoin" and include_departed:
            i = include_departed.pop(int(rng.integers(
                0, len(include_departed))))
            # tau >= the departure's (same boundary is fine: the heap
            # pops the earlier-seq departure first, freeing the slot)
            push(Arrival(max(tau, cursor), client_id=i))
            members.add(i)
            slotted.add(i)
        elif kind == "shift" and slotted:
            i = int(rng.choice(sorted(slotted)))
            ev = TraceShift(max(tau, min_tau[i]), client_id=i,
                            trace=TRACES[int(rng.integers(0, len(TRACES)))])
            push(ev)
            if rng.random() < 0.25:              # duplicate delivery:
                push(ev)                         # deterministic replay
        elif kind == "burst" and members:
            ids = tuple(sorted(rng.choice(
                sorted(members),
                size=int(rng.integers(1, min(3, len(members)) + 1)),
                replace=False).tolist()))
            push(InactivityBurst(tau, duration=int(rng.integers(1, 4)),
                                 client_ids=ids))
        elif kind == "kill" and kills < max_kills and ops:
            ops.append(("kill",))
            kills += 1
    # tail run: pass every queued tau so all events actually apply
    tail = max(4, max_tau + 1 - cursor)
    ops.append(("run", int(tail)))
    cursor += tail
    return FuzzCase(seed=seed, ops=ops, total_rounds=cursor)


# -- harness -------------------------------------------------------------------

def _fn_signature(engine: RoundEngine) -> dict:
    """The recompile fingerprint: the jit key set plus the engine's
    trace counter (bumped only when jax actually retraces a chunk body).
    Any growth after warm-up means an event triggered a recompile.
    Deliberately NOT the jits' _cache_size(): jax's C++ fastpath cache
    also keys on argument committed-ness and grows without retracing."""
    return {"keys": sorted(engine._fns.keys()),
            "traces": engine.trace_count}


class FuzzHarness:
    """Shared fixtures for a fuzz corpus: data pools, one warm pooled
    engine (both sampled and plan jit variants compiled by the warm-up
    spans), and the recompile baseline every case is checked against."""

    def __init__(self, *, capacity: int = 8, n_founding: int = 4,
                 n_arrival_pool: int = 4, local_epochs: int = 3,
                 batch_size: int = 5, chunk_size: int = 4,
                 max_samples: int = 60, scheme: str = "C",
                 eta0: float = 1.0, data_seed: int = 0,
                 engine_mode: str = "client_parallel", sharding=None,
                 compression=None, bank: bool = False,
                 prefetch: bool = False):
        from repro.configs.paper import SYNTHETIC_LR
        from repro.data import synthetic_federation
        from repro.fed.driver import Client
        from repro.models.small import init_small, make_loss_fn

        self.capacity = capacity
        self.n_founding = n_founding
        self.n_arrival_pool = n_arrival_pool
        self.E = local_epochs
        self.scheme = scheme
        self.eta0 = eta0
        self.engine_mode = engine_mode
        self.bank = bank
        self.prefetch = prefetch
        cfg = SYNTHETIC_LR
        train, test = synthetic_federation(
            0.5, 0.5, n_founding + n_arrival_pool, seed=data_seed)
        clients = [Client(x=tr[0][:max_samples], y=tr[1][:max_samples],
                          trace=TRACES[j % len(TRACES)],
                          x_test=te[0], y_test=te[1])
                   for j, (tr, te) in enumerate(zip(train, test))]
        self.founding = clients[:n_founding]
        self.arrival_pool = clients[n_founding:]
        self.init_params = init_small(jax.random.PRNGKey(0), cfg)
        self.loss_fn = make_loss_fn(cfg)
        self.engine = RoundEngine(
            loss_fn=self.loss_fn, clients=list(self.founding),
            local_epochs=local_epochs, batch_size=batch_size,
            scheme=scheme, eta0=eta0, chunk_size=chunk_size,
            capacity=capacity, max_samples=max_samples,
            mode=engine_mode, sharding=sharding, compression=compression)
        # warm-up: a 7-round span chunks into 4+2+1, compiling every
        # pow2 chunk length the cases can produce — in both modes
        for mode in ("device", "plan"):
            sch = self.new_scheduler(mode)
            sch.run(7, eval_every=1 << 30)
        self.fn_baseline = _fn_signature(self.engine)

    def _clone(self, client):
        from repro.fed.events import client_from_dict, client_to_dict
        return client_from_dict(client_to_dict(client))

    def new_scheduler(self, mode: str, *, state: Optional[FedState] = None,
                      params=None, case_seed: int = 0,
                      injector=None) -> StreamScheduler:
        """A scheduler over the pooled warm engine: evict every slot,
        re-stage the case's (or restored state's) occupancy.  Clients are
        cloned per scheduler — TraceShift mutates Client.trace in place,
        and runs of one case must stay independent."""
        eng = self.engine
        for slot in range(eng.capacity):
            eng.evict(slot)
        if state is None:
            founders = [self._clone(c) for c in self.founding]
            eng.admit_many(list(enumerate(founders)))
            return StreamScheduler(
                clients=founders, init_params=self.init_params,
                engine=eng, mode=mode, seed=case_seed, log_spans=True,
                injector=injector, bank=self.bank,
                prefetch=self.prefetch)
        eng.admit_many(sorted(
            ((slot, state.clients[i])
             for i, slot in state.slot_of.items()),
            key=lambda sc: sc[0]))
        return StreamScheduler(
            init_params=jax.tree.map(jnp.asarray, params), engine=eng,
            state=state, mode=mode, log_spans=True, injector=injector,
            bank=self.bank, prefetch=self.prefetch)

    def materialize(self, case: FuzzCase) -> List[Tuple]:
        """Codec dicts -> fresh event objects; negative Arrival ids are
        resolved to cloned payloads from the arrival pool."""
        out = []
        for op in case.ops:
            if op[0] != "push":
                out.append(op)
                continue
            d = op[1]
            if d["kind"] == "arrival" and d.get("client_id") is not None \
                    and d["client_id"] < 0:
                payload = self._clone(
                    self.arrival_pool[-d["client_id"] - 1])
                out.append(("push", Arrival(int(d["tau"]),
                                            client=payload)))
            else:
                out.append(("push", event_from_dict(d)))
        return out


# -- execution -----------------------------------------------------------------

def _execute(harness: FuzzHarness, case: FuzzCase, *, mode: str,
             honor_kills: bool) -> dict:
    """Run one materialized op program.  ``honor_kills=True`` serializes
    the full control plane at every ("kill",) op — the in-memory twin of
    the on-disk checkpoint — and resumes into a freshly re-staged
    scheduler; ``False`` ignores kills (the uninterrupted reference)."""
    sch = harness.new_scheduler(mode, case_seed=case.seed)
    span_log = list(sch.span_log or [])
    n_resumes = 0
    for op in harness.materialize(case):
        if op[0] == "push":
            sch.push(op[1])
        elif op[0] == "run":
            sch.run(op[1], eval_every=1 << 30)
        elif op[0] == "kill" and honor_kills:
            span_log.extend(sch.span_log)
            blob = copy.deepcopy(sch.state.to_dict())
            params = jax.tree.map(lambda a: np.asarray(a).copy(),
                                  sch.params)
            history = list(sch.history)
            sch = harness.new_scheduler(
                mode, state=FedState.from_dict(blob), params=params)
            sch.history.extend(history)
            n_resumes += 1
    span_log.extend(sch.span_log)
    return {"history": sch.history,
            "params": jax.tree.map(np.asarray, sch.params),
            "span_log": span_log,
            "state": sch.state,
            "n_resumes": n_resumes}


# -- invariants ----------------------------------------------------------------

def _check_exact_resume(seed: int, ref: dict, killed: dict, *,
                        invariant: str = "exact-resume") -> None:
    h1, h2 = ref["history"], killed["history"]
    if len(h1) != len(h2):
        raise InvariantViolation(seed, invariant,
                                 f"history length {len(h2)} != {len(h1)}")
    for r1, r2 in zip(h1, h2):
        if (r1.tau != r2.tau or r1.event != r2.event
                or r1.eta != r2.eta or r1.n_active != r2.n_active
                or not np.array_equal(np.asarray(r1.s),
                                      np.asarray(r2.s))):
            raise InvariantViolation(
                seed, invariant,
                f"round {r1.tau}: {r1} != {r2}")
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(killed["params"])):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise InvariantViolation(
                seed, invariant,
                f"final params differ (max |d|="
                f"{np.max(np.abs(np.asarray(a) - np.asarray(b)))})")


def _check_zero_recompile(seed: int, harness: FuzzHarness) -> None:
    sig = _fn_signature(harness.engine)
    if sig != harness.fn_baseline:
        raise InvariantViolation(
            seed, "zero-recompile",
            f"jit cache grew: baseline {harness.fn_baseline} -> {sig}")


def _check_weight_sanity(seed: int, harness: FuzzHarness,
                         result: dict) -> None:
    E, eta0, scheme = harness.E, harness.eta0, harness.scheme
    log = sorted(result["span_log"], key=lambda t: t[0])
    if not log:
        raise InvariantViolation(seed, "weight-sanity", "empty span log")
    j = 0
    for rec in result["history"]:
        while j + 1 < len(log) and log[j + 1][0] <= rec.tau:
            j += 1
        tau0, p, active, lr_shift = log[j]
        # sum(p) <= 1 with the deficit owned by include-departed members
        # (mass in the normalization, no slot); sum(p) == 0 is the
        # everyone-include-departed state, lawful only with nobody active
        # (covered by the active&p<=0 check below)
        if np.any(p < 0) or p.sum() > 1.0 + 1e-5:
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: p={p} (sum={p.sum()})")
        if np.any((active > 0) & (p <= 0)):
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: active slot with zero weight "
                f"(p={p}, active={active})")
        s = np.asarray(rec.s)
        if np.any(s < 0) or np.any(s > E):
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: s={s} outside [0, {E}]")
        if np.any((s > 0) & (active == 0)):
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: inactive slot trained (s={s}, "
                f"active={active})")
        coeffs = np.asarray(scheme_coefficients(scheme, p, s, E))
        if np.any(~np.isfinite(coeffs)) or np.any(coeffs < 0):
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: scheme-{scheme} coefficients "
                f"{coeffs} not finite/non-negative")
        want_eta = eta0 / max(rec.tau + 1 - lr_shift, 1)
        if abs(rec.eta - want_eta) > 1e-6 * max(1.0, want_eta):
            raise InvariantViolation(
                seed, "weight-sanity",
                f"round {rec.tau}: eta={rec.eta} != "
                f"eta0/max(t+1-{lr_shift},1)={want_eta}")


def _check_plan_parity(seed: int, device: dict, plan: dict) -> None:
    h1, h2 = device["history"], plan["history"]
    if len(h1) != len(h2):
        raise InvariantViolation(seed, "plan-parity",
                                 f"history length {len(h2)} != {len(h1)}")
    for r1, r2 in zip(h1, h2):
        if r1.tau != r2.tau or r1.event != r2.event or r1.eta != r2.eta:
            raise InvariantViolation(
                seed, "plan-parity",
                f"round {r1.tau}: control plane diverged "
                f"({r1.event!r}/{r1.eta} vs {r2.event!r}/{r2.eta})")
    d1, d2 = device["span_log"], plan["span_log"]
    if len(d1) != len(d2):
        raise InvariantViolation(
            seed, "plan-parity",
            f"span-arg recompute count {len(d2)} != {len(d1)}")
    for (t1, p1, a1, l1), (t2, p2, a2, l2) in zip(d1, d2):
        if t1 != t2 or l1 != l2 or not np.array_equal(p1, p2) \
                or not np.array_equal(a1, a2):
            raise InvariantViolation(
                seed, "plan-parity",
                f"span args at tau {t1}/{t2} diverged")
    s1, s2 = device["state"], plan["state"]
    if (s1.objective != s2.objective or s1.departed != s2.departed
            or s1.slot_of != s2.slot_of):
        raise InvariantViolation(seed, "plan-parity",
                                 "final membership diverged")


# -- backend cross-checking ----------------------------------------------------

# Measured parity tolerance for the quantized-vs-f32 cross-check.  The
# int8 round-off (~absmax/254 per element per round) enters the same
# post-event chaotic amplification as the flat-vs-tree layout caveat in
# docs/engine.md, so at the harness's adversarial eta0 = 1 the final
# divergence is set by the dynamics, not the quantizer: measured over
# the 30-seed backend corpus (full-length cases, <= 27 rounds) max
# |param| divergence is 4.6e-1, mean 5.2e-2.  The gate is ~2x the
# measured max; it pins the scale (weight-sanity allows |w| <= 1e3)
# while the *sharp* invariant is the same-wire one: two quantized
# backends (parallel vmap vs sequential accumulate) share one
# quantization lattice and measured bit-exact over the same corpus,
# so they keep the ordinary exact-law tolerance below.
QUANT_VS_F32_ATOL = 1.0
QUANT_VS_F32_RTOL = 1.0

# Engine kwargs per backend name; "sharded" is special-cased (needs a
# mesh).  The quantized legs run the int8 wire format end-to-end.
_BACKEND_SPECS = {
    "client_parallel": {},
    "client_sequential": {"engine_mode": "client_sequential"},
    "quantized": {"compression": "int8"},
    "quantized_sequential": {"engine_mode": "client_sequential",
                             "compression": "int8"},
    "banked": {"bank": True, "prefetch": True},
}


def make_backend_pool(backends=("client_parallel", "client_sequential"),
                      *, sharding=None, **kw) -> dict:
    """One warm FuzzHarness per execution backend, identical geometry
    and data: "client_parallel" (fused vmap + flat Pallas agg),
    "client_sequential" (streaming accumulate), "quantized" /
    "quantized_sequential" (the int8 compressed-delta wire format on
    either layout), "banked" (the host-RAM client bank with
    double-buffered cohort prefetch — must be bit-exact against
    "client_parallel"), "sharded" (the client-axis sharded engine — pass
    sharding=, only meaningful under a multi-device mesh;
    tests/_fuzz_backends_check.py re-execs with 4 virtual devices)."""
    pool = {}
    for b in backends:
        if b == "sharded":
            if sharding is None:
                raise ValueError('backend "sharded" needs sharding=')
            pool[b] = FuzzHarness(sharding=sharding, **kw)
        elif b in _BACKEND_SPECS:
            pool[b] = FuzzHarness(**_BACKEND_SPECS[b], **kw)
        else:
            pool[b] = FuzzHarness(engine_mode=b, **kw)
    return pool


def _check_backend_parity(seed: int, backend: str, ref: dict,
                          other: dict, *, atol: float = 5e-4,
                          rtol: float = 5e-4) -> float:
    """The same op schedule on two execution backends must walk one
    trajectory: the control plane and the sampled epoch counts are
    *exact* (device sampling folds the round index, so the draw stream
    is invariant to how clients are executed or sharded); params agree
    to numerical tolerance (aggregation order differs)."""
    h1, h2 = ref["history"], other["history"]
    if len(h1) != len(h2):
        raise InvariantViolation(
            seed, "backend-parity",
            f"{backend}: history length {len(h2)} != {len(h1)}")
    for r1, r2 in zip(h1, h2):
        if (r1.tau != r2.tau or r1.event != r2.event
                or r1.eta != r2.eta or r1.n_active != r2.n_active
                or not np.array_equal(np.asarray(r1.s),
                                      np.asarray(r2.s))):
            raise InvariantViolation(
                seed, "backend-parity",
                f"{backend}: round {r1.tau}: {r1} != {r2}")
    s1, s2 = ref["state"], other["state"]
    if (s1.objective != s2.objective or s1.departed != s2.departed
            or s1.slot_of != s2.slot_of):
        raise InvariantViolation(
            seed, "backend-parity",
            f"{backend}: final membership diverged")
    max_err = 0.0
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(other["params"])):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        max_err = max(max_err, float(np.max(np.abs(a - b))))
        if not np.allclose(a, b, atol=atol, rtol=rtol):
            raise InvariantViolation(
                seed, "backend-parity",
                f"{backend}: final params diverged "
                f"(max |d|={np.max(np.abs(a - b)):.3g}, "
                f"atol={atol}, rtol={rtol})")
    return max_err


def run_cross_backend_case(pool: dict, seed: int, *,
                           reference: str = "client_parallel",
                           mode: str = "device",
                           case: Optional[FuzzCase] = None,
                           atol: float = 5e-4,
                           rtol: float = 5e-4) -> dict:
    """Execute one seeded op schedule on every backend in the pool and
    cross-check parity against the reference backend.  Kills are not
    honored here (resume is a per-backend invariant covered by
    run_fuzz_case); the schedule's event stream is."""
    ref_h = pool[reference]
    if case is None:
        case = generate_case(seed, n_founding=ref_h.n_founding,
                             capacity=ref_h.capacity,
                             n_arrival_pool=ref_h.n_arrival_pool)
    results = {}
    for name, h in pool.items():
        results[name] = _execute(h, case, mode=mode, honor_kills=False)
        _check_zero_recompile(seed, h)
    max_err = 0.0
    ref_wire = pool[reference].engine.compression.name
    for name in pool:
        if name == reference:
            continue
        # backends on the same wire format walk one quantization lattice
        # and keep the exact-law tolerance; a wire-format mismatch (int8
        # leg vs f32 reference) is held to the measured gate instead
        a, r = ((atol, rtol)
                if pool[name].engine.compression.name == ref_wire
                else (max(atol, QUANT_VS_F32_ATOL),
                      max(rtol, QUANT_VS_F32_RTOL)))
        max_err = max(max_err, _check_backend_parity(
            seed, name, results[reference], results[name],
            atol=a, rtol=r))
    return {"seed": seed, "rounds": case.total_rounds,
            "backends": sorted(pool), "max_param_err": max_err,
            "events_applied":
                results[reference]["state"].events_applied}


def run_backend_matrix(seeds, *, pool: Optional[dict] = None,
                       mode: str = "device", **pool_kw) -> dict:
    """Cross-backend parity over a seed corpus — shared by the tier-1
    subprocess check and benchmarks/fuzz_bench.py."""
    if pool is None:
        pool = make_backend_pool(**pool_kw)
    rows = [run_cross_backend_case(pool, int(s), mode=mode)
            for s in seeds]
    return {"cases": len(rows),
            "backends": sorted(pool),
            "rounds": int(sum(r["rounds"] for r in rows)),
            "max_param_err": max((r["max_param_err"] for r in rows),
                                 default=0.0),
            "per_case": rows}


# -- fuzzed supervised chaos ---------------------------------------------------

def run_chaos_case(harness: FuzzHarness, seed: int, *,
                   span_rounds: int = 4, hang: bool = False,
                   span_timeout: float = 2.0, snapshot_every: int = 1,
                   flood_size: int = 64, plan=None,
                   case: Optional[FuzzCase] = None,
                   timeout: float = 300.0) -> dict:
    """One fuzz case as a *supervised chaos* workload: the generator's
    event schedule is submitted to a real FederationService while a
    seeded FaultPlan (worker crashes, mid-span tears, snapshot
    write-failure + corruption, stale floods; optional hangs) fires
    against the supervision layer — and the recovered run must be
    bit-identical to the fault-free service run.

    Both runs submit every event *before* start() and use the
    merge-stale queue policy: ingest (and journal replay after a
    rollback) then sees each event at the same next_tau, so the
    policy's drop decisions — which annotate history — are identical
    by construction.  Kill ops in the case are ignored: the fault plan
    owns failure injection here (that's the point)."""
    import tempfile

    from repro.fed.faults import Fault, FaultPlan
    from repro.fed.service import FederationService

    if case is None:
        case = generate_case(seed, n_founding=harness.n_founding,
                             capacity=harness.capacity,
                             n_arrival_pool=harness.n_arrival_pool)
    events = [op[1] for op in harness.materialize(case)
              if op[0] == "push"]
    total = case.total_rounds
    spans = -(-total // span_rounds)
    if plan is None:
        plan = FaultPlan.generate(
            seed, spans=spans, saves=max(2, spans), hang=hang,
            flood_size=flood_size, hang_seconds=4 * span_timeout)
        # corrupting ckpt_written#0 poisons the generation-0 *base*
        # snapshot: a crash before the first periodic snapshot then has
        # no restorable candidate — unrecoverable by design (bitrot on
        # the only checkpoint), so retarget the bitrot to snapshot #1
        faults = []
        for f in plan.faults:
            if f.site == "ckpt_written" and f.at == 0:
                if any(g.site == "ckpt_written" and g.at == 1
                       for g in plan.faults):
                    continue
                f = Fault(f.site, 1, f.kind, size=f.size,
                          seconds=f.seconds)
            faults.append(f)
        plan = FaultPlan(faults=faults, seed=seed)

    def service(sch, **kw):
        return FederationService(
            sch, span_rounds=span_rounds, max_rounds=total,
            queue_policy="merge-stale", max_queue=256, **kw)

    # fault-free reference: same service machinery, no injector
    ref_sch = harness.new_scheduler("device", case_seed=seed)
    svc = service(ref_sch)
    svc.submit(*events)
    with svc:
        if not svc.wait_rounds(total, timeout=timeout):
            raise InvariantViolation(
                seed, "chaos-bitexact",
                f"fault-free reference stalled before {total} rounds")
    ref = {"history": ref_sch.history,
           "params": jax.tree.map(np.asarray, ref_sch.params)}

    # chaotic run: supervised auto-recovery under the fault plan
    chaos_sch = harness.new_scheduler("device", case_seed=seed,
                                      injector=plan)
    with tempfile.TemporaryDirectory(prefix="fuzz-chaos-") as snapdir:
        live = service(
            chaos_sch, supervise=True, snapshot_dir=snapdir,
            snapshot_every=snapshot_every, keep_snapshots=4,
            backoff0=0.01, span_timeout=span_timeout,
            join_timeout=10.0, injector=plan,
            engine_factory=lambda: harness.engine,
            restore_kwargs=dict(loss_fn=harness.loss_fn))
        live.submit(*events)
        with live:
            if not live.wait_rounds(total, timeout=timeout):
                raise InvariantViolation(
                    seed, "chaos-bitexact",
                    f"supervised run stalled before {total} rounds "
                    f"(recoveries={len(live.recoveries)})")
        final = live.scheduler
        _check_exact_resume(
            seed, ref,
            {"history": final.history,
             "params": jax.tree.map(np.asarray, final.params)},
            invariant="chaos-bitexact")
        _check_zero_recompile(seed, harness)
        return {"seed": seed, "rounds": total,
                "events": len(events),
                "recoveries": len(live.recoveries),
                "mttr_s": [r["mttr_s"] for r in live.recoveries],
                "fired": [list(t) for t in plan.fired],
                "events_merged": live.events_merged,
                "snapshot_failures": live.snapshot_failures}


def run_chaos_corpus(seeds, *, harness: Optional[FuzzHarness] = None,
                     **kw) -> dict:
    """Fuzzed-chaos verification over a seed corpus — shared by the
    tier-1 test and benchmarks/fuzz_bench.py."""
    if harness is None:
        harness = FuzzHarness()
    rows = [run_chaos_case(harness, int(s), **kw) for s in seeds]
    mttrs = [m for r in rows for m in r["mttr_s"]]
    return {"cases": len(rows),
            "rounds": int(sum(r["rounds"] for r in rows)),
            "recoveries": int(sum(r["recoveries"] for r in rows)),
            "events": int(sum(r["events"] for r in rows)),
            "events_merged": int(sum(r["events_merged"]
                                     for r in rows)),
            "mttr_mean_s": float(np.mean(mttrs)) if mttrs else 0.0,
            "mttr_max_s": float(np.max(mttrs)) if mttrs else 0.0,
            "per_case": rows}


# -- corpus entry points -------------------------------------------------------

def run_fuzz_case(harness: FuzzHarness, seed: int, *,
                  check_plan_parity: bool = True,
                  case: Optional[FuzzCase] = None) -> dict:
    """Generate (or replay) one case and assert every invariant.  Returns
    case statistics for corpus reporting."""
    if case is None:
        case = generate_case(seed, n_founding=harness.n_founding,
                             capacity=harness.capacity,
                             n_arrival_pool=harness.n_arrival_pool)
    ref = _execute(harness, case, mode="device", honor_kills=False)
    _check_zero_recompile(seed, harness)
    _check_weight_sanity(seed, harness, ref)
    killed = _execute(harness, case, mode="device", honor_kills=True)
    _check_zero_recompile(seed, harness)
    _check_exact_resume(seed, ref, killed)
    stats = {"seed": seed, "ops": len(case.ops),
             "rounds": case.total_rounds, "kills": case.n_kills,
             "resumes": killed["n_resumes"],
             "events_applied": ref["state"].events_applied,
             "plan_parity": False}
    if check_plan_parity:
        plan = _execute(harness, case, mode="plan", honor_kills=True)
        _check_zero_recompile(seed, harness)
        _check_weight_sanity(seed, harness, plan)
        # compare against the *killed* device run: both resume at the
        # same boundaries, so their span-arg recompute logs line up
        _check_plan_parity(seed, killed, plan)
        stats["plan_parity"] = True
    return stats


def run_corpus(seeds, *, harness: Optional[FuzzHarness] = None,
               check_plan_parity: bool = True) -> dict:
    """Run a seed corpus; returns aggregate statistics (and the per-case
    rows) — shared by the tier-1 test and benchmarks/fuzz_bench.py."""
    if harness is None:
        harness = FuzzHarness()
    rows = [run_fuzz_case(harness, int(s),
                          check_plan_parity=check_plan_parity)
            for s in seeds]
    return {"cases": len(rows),
            "rounds": int(sum(r["rounds"] for r in rows)),
            "kills": int(sum(r["kills"] for r in rows)),
            "resumes": int(sum(r["resumes"] for r in rows)),
            "events_applied": int(sum(r["events_applied"]
                                      for r in rows)),
            "seeds": [r["seed"] for r in rows],
            "per_case": rows}
