"""FederationService: concurrent event ingestion over a live scheduler,
with optional crash supervision.

Closes the ROADMAP's "serve.py gap": the StreamScheduler consumes events
pushed between blocking ``run()`` calls, but nothing *produced* them while
training ran.  This layer makes the control plane live:

  * a worker thread runs scheduler spans (``span_rounds`` per iteration)
    while any number of producer threads ``submit()`` ParticipationEvents
    concurrently;
  * the inbox is a bounded queue — a full inbox blocks (or rejects, with
    ``block=False``) the producer: backpressure instead of unbounded
    memory growth under heavy traffic;
  * ``pause()``/``resume()`` gate span execution without stopping
    ingestion; ``drain()`` waits until every submitted event has been
    handed to the scheduler;
  * ``snapshot()`` captures a span-boundary-consistent checkpoint (the
    FedState dict + params, optionally persisted via
    ``StreamScheduler.save``) without tearing the service down — the
    mid-stream checkpoint/resume path for deployments.

Supervision (``supervise=True``, requires ``snapshot_dir``) hardens the
worker against arbitrary failure.  A supervisor thread watches for worker
death (exception) and span hangs (heartbeat older than ``span_timeout``)
and recovers:

  1. bump the generation, set the old generation's abort event (releases
     cooperative stalls), join the dead worker;
  2. restore a fresh scheduler from the newest periodic snapshot, falling
     back past corrupt ones (checksum failures raise
     CorruptCheckpointError) to older generations;
  3. re-push the event journal: every ingested event is tagged with the
     snapshot epoch current at ingest, so events not yet baked into the
     restored snapshot are replayed onto the restored queue — ingestion
     is never lost to a crash;
  4. swap in the restored scheduler with a NEW span lock (a truly hung
     worker may hold the old one forever), back off exponentially
     (``backoff0 * 2**streak``; streak resets on a successful span), and
     start a new worker — giving up with the original error after
     ``max_restarts`` consecutive failures.

Because per-round randomness is derived by folding the round index into a
never-split base key, a recovered run replays the lost rounds *exactly*:
the post-recovery trajectory is bit-identical to an uninterrupted one
(asserted by the chaos tests).

The scheduler's own event queue can additionally be bounded:
``queue_policy="merge-stale"`` drops, at ingest, any TraceShift whose tau
has already passed and that restates the target's *current* trace
(last-write-wins makes that a no-op), and compacts stale duplicates
whenever the queue tops ``max_queue`` — the absorbing policy for edges
that re-announce known availability laws on every retry.

All jax work stays on the worker thread; producers only touch the inbox.
Scheduler state is guarded by one lock the worker releases between spans,
so control calls (snapshot/pause/stats) interleave at span granularity.

Usage::

    svc = FederationService(scheduler, span_rounds=4, eval_every=8,
                            max_rounds=200)
    with svc:                          # starts the worker
        svc.submit(Arrival(tau=12, client=new_client))   # any thread
        svc.wait_rounds(200)
    print(svc.stats())
"""
from __future__ import annotations

import os
import queue
import shutil
import threading
import time
from typing import Callable, List, Optional, Tuple

from repro.checkpoint import CorruptCheckpointError
from repro.fed.events import ParticipationEvent, TraceShift
from repro.fed.stream import StreamScheduler
from repro.obs.metrics import MetricsRegistry
from repro.obs.telemetry import resolve as resolve_telemetry

_QUEUE_POLICIES = ("none", "merge-stale")


def _is_stale_noop(state, e) -> bool:
    """A TraceShift whose tau already passed and that restates the
    client's current trace: applying it is the identity (last-write-wins
    semantics), so merge-stale drops it at ingest."""
    return (isinstance(e, TraceShift) and e.tau <= state.next_tau
            and 0 <= e.client_id < len(state.clients)
            and e.trace == state.clients[e.client_id].trace)


class FederationService:
    """Thread-safe ingestion + span-execution service over one
    StreamScheduler, optionally supervised for auto-recovery."""

    def __init__(self, scheduler: StreamScheduler, *,
                 span_rounds: int = 4, eval_every: int = 1 << 30,
                 max_rounds: Optional[int] = None,
                 max_pending: int = 1024,
                 idle_sleep: float = 0.002,
                 supervise: bool = False,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 4,
                 keep_snapshots: int = 3,
                 max_restarts: int = 5,
                 backoff0: float = 0.05,
                 span_timeout: Optional[float] = None,
                 join_timeout: float = 5.0,
                 queue_policy: str = "none",
                 max_queue: int = 1024,
                 injector=None,
                 engine_factory: Optional[Callable] = None,
                 restore_kwargs: Optional[dict] = None,
                 warmup_factor: float = 10.0,
                 telemetry=None):
        if span_rounds < 1:
            raise ValueError(f"span_rounds must be >= 1, got {span_rounds}")
        if queue_policy not in _QUEUE_POLICIES:
            raise ValueError(f"queue_policy must be one of "
                             f"{_QUEUE_POLICIES}, got {queue_policy!r}")
        if supervise and snapshot_dir is None:
            raise ValueError("supervise=True requires snapshot_dir "
                             "(recovery restores from periodic snapshots)")
        self.scheduler = scheduler
        self.span_rounds = span_rounds
        self.eval_every = eval_every
        self.max_rounds = max_rounds
        # inbox items are (t_submit, event): the monotonic submit stamp
        # feeds the svc_ingest_lag_seconds histogram
        self._inbox: "queue.Queue[Tuple[float, ParticipationEvent]]" = \
            queue.Queue(maxsize=max_pending)
        self._idle_sleep = idle_sleep
        self.warmup_factor = warmup_factor
        # supervision config
        self._supervised = supervise
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = max(1, snapshot_every)
        self.keep_snapshots = max(1, keep_snapshots)
        self.max_restarts = max_restarts
        self.backoff0 = backoff0
        self.span_timeout = span_timeout
        self.join_timeout = join_timeout
        self.queue_policy = queue_policy
        self.max_queue = max_queue
        self._injector = (injector if injector is not None
                          else getattr(scheduler, "injector", None))
        self._engine_factory = engine_factory
        self._restore_kwargs = dict(restore_kwargs or {})
        # locking: _meta hands out the *current* (lock, scheduler,
        # generation, abort) quadruple — recovery swaps all four at once,
        # because a hung worker may never release the old span lock
        self._meta = threading.Lock()
        self._lock = threading.RLock()       # guards scheduler state
        self._abort = threading.Event()      # releases this generation
        self._gen = 0
        # waiters get their own condition so they never contend with (or
        # deadlock against a hung holder of) the span lock
        self._wait_cv = threading.Condition(threading.Lock())
        self._stop = threading.Event()
        # the worker parks on this instead of sleep-polling: submit(),
        # resume(), stop() and recovery set it, so an idle (paused or
        # budget-reached) worker reacts to news immediately instead of on
        # the next poll tick
        self._wake = threading.Event()
        self._paused = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self._supervisor: Optional[threading.Thread] = None
        self._worker_died = threading.Event()
        # (generation, error, monotonic death time) — the stamp feeds the
        # recovery record's detect_latency_s
        self._died: Optional[Tuple[int, BaseException, float]] = None
        self._error: Optional[BaseException] = None
        self._heartbeat = time.monotonic()
        # spans completed by the CURRENT generation: the watchdog grants
        # a warmup grace (warmup_factor * span_timeout) until the first
        # span lands, because a first span legitimately spends seconds in
        # jax compilation — indistinguishable from a hang by heartbeat
        self._gen_spans = 0
        # snapshot/journal bookkeeping (guarded by _snap_lock)
        self._snap_lock = threading.Lock()
        self._snapshots: List[Tuple[int, str]] = []   # (epoch, path)
        self._epoch = 0
        self._journal: Optional[List[Tuple[int, ParticipationEvent]]] = \
            [] if (supervise and snapshot_dir is not None) else None
        self._delayed: List[ParticipationEvent] = []
        self._fail_streak = 0
        self.recoveries: List[dict] = []

        # telemetry: default to the scheduler's own telemetry so one
        # wiring point covers the whole stack.  The service counters are
        # *functional* state (drain() compares them), so with a null
        # telemetry they live on a private registry — same code path,
        # nothing rendered
        self.telemetry = tel = resolve_telemetry(
            telemetry if telemetry is not None
            else getattr(scheduler, "telemetry", None))
        reg = tel.registry if tel.enabled else MetricsRegistry()
        self._registry = reg
        if (tel.enabled and self._injector is not None
                and hasattr(self._injector, "attach_telemetry")):
            self._injector.attach_telemetry(tel)
        self._c_submitted = reg.counter(
            "svc_events_submitted_total", "events accepted by submit()")
        self._c_ingested = reg.counter(
            "svc_events_ingested_total",
            "events handed from the inbox to the scheduler")
        self._c_merged = reg.counter(
            "svc_events_merged_total",
            "events dropped/compacted by the merge-stale queue policy")
        self._c_duplicated = reg.counter(
            "svc_events_duplicated_total",
            "events delivered twice by an injected ingest fault")
        self._c_delayed = reg.counter(
            "svc_events_delayed_total",
            "events held back one ingest cycle by an injected fault")
        self._c_flooded = reg.counter(
            "svc_events_flooded_total",
            "stale events pushed by injected floods")
        self._c_spans = reg.counter(
            "svc_spans_total", "scheduler spans run by the worker")
        self._c_snap_failures = reg.counter(
            "svc_snapshot_failures_total",
            "periodic snapshots that failed to write")
        self._c_recoveries = reg.counter(
            "svc_recoveries_total", "supervised recoveries completed")
        self._c_busy = reg.counter(
            "svc_busy_seconds_total",
            "worker wall time inside scheduler spans")
        self._c_idle = reg.counter(
            "svc_idle_seconds_total",
            "worker wall time parked waiting for work")
        self._c_overhead = reg.counter(
            "svc_overhead_seconds_total",
            "worker wall time in per-iteration service bookkeeping "
            "(locking, ingest, notify) — neither spans nor idle waits")
        self._g_inbox = reg.gauge(
            "svc_inbox_depth", "events waiting in the bounded inbox")
        self._g_heartbeat = reg.gauge(
            "svc_heartbeat_age_s",
            "seconds since the worker's last heartbeat (set on read)")
        self._g_generation = reg.gauge(
            "svc_generation", "current worker generation")
        self._h_lag = reg.histogram(
            "svc_ingest_lag_seconds",
            "submit()-to-scheduler latency per event")
        self._h_recovery = reg.histogram(
            "svc_recovery_seconds", "supervised recovery wall time (MTTR)")

    # -- registry-backed counters (the pre-telemetry public surface) ----------
    @property
    def events_submitted(self) -> int:
        return int(self._c_submitted.value)

    @property
    def events_ingested(self) -> int:
        return int(self._c_ingested.value)

    @property
    def events_merged(self) -> int:
        return int(self._c_merged.value)

    @property
    def events_duplicated(self) -> int:
        return int(self._c_duplicated.value)

    @property
    def events_delayed(self) -> int:
        return int(self._c_delayed.value)

    @property
    def events_flooded(self) -> int:
        return int(self._c_flooded.value)

    @property
    def spans_run(self) -> int:
        return int(self._c_spans.value)

    @property
    def snapshot_failures(self) -> int:
        return int(self._c_snap_failures.value)

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "FederationService":
        if self._stop.is_set():
            raise RuntimeError(
                "FederationService cannot be restarted after stop(); "
                "build a new service (restore from a snapshot to resume)")
        with self._meta:
            if self._worker is not None and self._worker.is_alive():
                return self
            gen, lock, abort, sch = (self._gen, self._lock,
                                     self._abort, self.scheduler)
        if self._supervised and not self._snapshots:
            os.makedirs(self.snapshot_dir, exist_ok=True)
            # generation-0 base snapshot: recovery always has somewhere to
            # roll back to, even if the first crash precedes the first
            # periodic snapshot.  A few attempts ride out injected or
            # transient write failures.
            for _ in range(3):
                if self._auto_snapshot(sch):
                    break
            else:
                raise RuntimeError(
                    "could not write the initial supervision snapshot "
                    f"to {self.snapshot_dir!r}")
        self._heartbeat = time.monotonic()
        self._worker = threading.Thread(
            target=self._loop, args=(gen, lock, abort, sch),
            name=f"federation-service-g{gen}", daemon=True)
        self._worker.start()
        if self._supervised and self._supervisor is None:
            self._supervisor = threading.Thread(
                target=self._supervise, name="federation-supervisor",
                daemon=True)
            self._supervisor.start()
        return self

    def stop(self, wait: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the worker (and supervisor).  ``wait=True`` joins the
        threads — up to ``timeout`` seconds each when given — and raises
        if the worker died of an unrecovered error, or if it failed to
        stop in time (a wedged span)."""
        self._stop.set()
        with self._meta:
            abort, worker = self._abort, self._worker
        abort.set()                          # release cooperative stalls
        self._worker_died.set()              # kick the supervisor awake
        self._wake.set()                     # unpark an idle worker
        self._notify()                       # wake wait_rounds() callers
        if wait:
            if self._supervisor is not None:
                self._supervisor.join(timeout)
            if worker is not None:
                worker.join(timeout)
                if worker.is_alive():
                    raise RuntimeError(
                        f"federation worker failed to stop within "
                        f"{timeout}s")
            # the worker is down: retire the scheduler's prefetch
            # staging thread too (idempotent; no-op without a bank)
            self.scheduler.close()
        if self._error is not None:
            raise RuntimeError("federation worker died") from self._error

    def __enter__(self) -> "FederationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(wait=True)

    @property
    def running(self) -> bool:
        with self._meta:
            worker = self._worker
        return (worker is not None and worker.is_alive()
                and not self._stop.is_set())

    @property
    def generation(self) -> int:
        return self._gen

    # -- ingestion (any thread) ------------------------------------------------
    def submit(self, *events: ParticipationEvent, block: bool = True,
               timeout: Optional[float] = None) -> bool:
        """Enqueue events for ingestion.  A full inbox applies
        backpressure: blocks (optionally up to ``timeout``) when
        ``block=True``, else returns False without enqueueing anything
        beyond the events already accepted.  Raises once the service has
        been stopped — those events would never be ingested."""
        if self._stop.is_set():
            raise RuntimeError("cannot submit to a stopped "
                               "FederationService")
        ok = True
        for e in events:
            try:
                self._inbox.put((time.monotonic(), e), block=block,
                                timeout=timeout)
            except queue.Full:
                ok = False
                break
            # the registry counter's own lock makes the increment atomic
            # under concurrent producers — drain() compares against it,
            # so a lost update would report drained with an event still
            # in flight
            self._c_submitted.inc()
        self._g_inbox.set(self._inbox.qsize())
        self._wake.set()                     # a parked worker has news
        return ok

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every submitted event has been handed to the
        scheduler (it may still be *pending* on the scheduler's own queue
        until its tau is reached).  True if drained within timeout."""
        def drained() -> bool:
            return (self._error is not None
                    or (self.events_ingested >= self.events_submitted
                        and self._inbox.empty() and not self._delayed))

        # condition-variable wait: the worker notifies after every ingest
        # cycle that moved events, so this parks instead of sleep-polling
        with self._wait_cv:
            ok = self._wait_cv.wait_for(drained, timeout=timeout)
        if self._error is not None:
            raise RuntimeError("federation worker died") from self._error
        return ok

    # -- control ---------------------------------------------------------------
    def pause(self) -> None:
        """Stop span execution (ingestion continues).  Returns once the
        in-flight span has finished, so scheduler state is boundary-
        consistent afterwards.  Generation-aware: if a recovery swaps the
        span lock while we wait, the barrier re-targets the new one."""
        self._paused.set()
        while True:
            with self._meta:
                gen, lock = self._gen, self._lock
            if lock.acquire(timeout=0.2):
                try:
                    with self._meta:
                        same = (gen == self._gen)
                finally:
                    lock.release()
                if same:
                    return                # barrier done at a boundary
            if self._stop.is_set():
                return

    def resume(self) -> None:
        self._paused.clear()
        self._wake.set()

    def wait_rounds(self, n: int, timeout: Optional[float] = None) -> bool:
        """Block until the scheduler clock reaches round n."""
        with self._wait_cv:
            ok = self._wait_cv.wait_for(
                lambda: self.scheduler._next_tau >= n
                or self._error is not None or self._stop.is_set(),
                timeout=timeout)
        if self._error is not None:
            raise RuntimeError("federation worker died") from self._error
        return ok and self.scheduler._next_tau >= n

    def snapshot(self, path: Optional[str] = None) -> dict:
        """Span-boundary-consistent control-plane snapshot.  With
        ``path``, also persists the full resumable checkpoint
        (StreamScheduler.save — params + FedState + history).  Returns
        the FedState dict."""
        was_paused = self._paused.is_set()
        self.pause()                  # settle at a span boundary
        try:
            with self._meta:
                lock, sch = self._lock, self.scheduler
            with lock:
                self._ingest(sch)     # fold already-submitted events in
                state = sch.state.to_dict()
                if path is not None:
                    sch.save(path)
        finally:
            if not was_paused:
                self.resume()
        return state

    def stats(self) -> dict:
        sch = self.scheduler
        # refresh the point-in-time gauges so a prom scrape taken right
        # after stats() agrees with it
        self._g_heartbeat.set(time.monotonic() - self._heartbeat)
        self._g_generation.set(self._gen)
        self._g_inbox.set(self._inbox.qsize())
        return {"rounds": sch._next_tau,
                "spans_run": self.spans_run,
                "events_submitted": self.events_submitted,
                "events_ingested": self.events_ingested,
                "events_applied": sch.events_applied,
                "events_pending": sch.pending,
                "events_merged": self.events_merged,
                "events_duplicated": self.events_duplicated,
                "events_delayed": self.events_delayed,
                "events_flooded": self.events_flooded,
                "inbox_depth": self._inbox.qsize(),
                "running": self.running,
                "paused": self._paused.is_set(),
                "supervised": self._supervised,
                "generation": self._gen,
                "recoveries": len(self.recoveries),
                "snapshot_failures": self.snapshot_failures,
                "snapshots_kept": len(self._snapshots),
                "journal_len": (len(self._journal)
                                if self._journal is not None else 0),
                "prefetch": sch.prefetch_stats()}

    def chaos_report(self) -> dict:
        """Supervision outcome summary: one record per recovery (cause,
        epoch restored, snapshots skipped as corrupt, events replayed,
        detection latency, MTTR seconds) plus aggregate counters — the
        payload behind ``fed_serve --chaos`` and
        BENCH_stream.json["chaos"].  All durations come from
        ``time.monotonic()`` — the same clock the tracing spans use, so
        MTTR figures line up with ``svc.recover`` span timings."""
        mttrs = [r["mttr_s"] for r in self.recoveries]
        detects = [r.get("detect_latency_s", 0.0)
                   for r in self.recoveries]
        rec_rounds = sum(max(0, r["tau_at_failure"] - r["tau_resumed"])
                         for r in self.recoveries)
        report = {
            "recoveries": list(self.recoveries),
            "n_recoveries": len(self.recoveries),
            "mttr_mean_s": (sum(mttrs) / len(mttrs)) if mttrs else 0.0,
            "mttr_max_s": max(mttrs) if mttrs else 0.0,
            "detect_latency_mean_s": (sum(detects) / len(detects)
                                      if detects else 0.0),
            "detect_latency_max_s": max(detects) if detects else 0.0,
            "recovered_rounds": int(rec_rounds),
            "snapshot_failures": self.snapshot_failures,
            "events_merged": self.events_merged,
            "final_rounds": int(self.scheduler._next_tau),
        }
        if self._injector is not None and hasattr(self._injector,
                                                  "summary"):
            report["faults"] = self._injector.summary()
        return report

    # -- worker ----------------------------------------------------------------
    def _notify(self) -> None:
        with self._wait_cv:
            self._wait_cv.notify_all()

    def _push_event(self, sch: StreamScheduler, e) -> None:
        """Hand one event to the scheduler, applying the queue policy."""
        if self.queue_policy == "merge-stale":
            if _is_stale_noop(sch.state, e):
                self._c_merged.inc()
                return
            sch.push(e)
            if sch.pending > self.max_queue:
                self._c_merged.inc(
                    sch.state.compact_stale_traceshifts())
        else:
            sch.push(e)

    def _accept(self, sch: StreamScheduler, e, count: bool = True) -> None:
        if self._journal is not None:
            with self._snap_lock:
                self._journal.append((self._epoch, e))
        self._push_event(sch, e)
        if count:
            self._c_ingested.inc()

    def _ingest(self, sch: StreamScheduler) -> int:
        """Move everything in the inbox (plus any fault-delayed holdbacks)
        onto the scheduler queue (caller holds the span lock)."""
        n = 0
        held, self._delayed = self._delayed, []
        for e in held:
            self._accept(sch, e)
            n += 1
        now = time.monotonic()
        while True:
            try:
                t_submit, e = self._inbox.get_nowait()
            except queue.Empty:
                break
            self._h_lag.observe(now - t_submit)
            f = (self._injector.fire("ingest")
                 if self._injector is not None else None)
            if f is not None and f.kind == "delay":
                self._delayed.append(e)      # out-of-order: next cycle
                self._c_delayed.inc()
                continue
            self._accept(sch, e)
            n += 1
            if f is not None and f.kind == "dup":
                self._accept(sch, e, count=False)   # delivered twice
                self._c_duplicated.inc()
        if n:
            self._g_inbox.set(self._inbox.qsize())
            self._notify()   # drain() waits on the ingest high-water mark
        return n

    def _maybe_flood(self, sch: StreamScheduler) -> None:
        f = self._injector.fire("flood")
        if f is not None and f.kind == "flood":
            from repro.fed.faults import make_flood
            flood = make_flood(sch.state, f.size or 1,
                               self._injector._rng)
            for ev in flood:
                self._push_event(sch, ev)    # policy absorbs the stale
            self._c_flooded.inc(len(flood))

    def _loop(self, gen: int, lock, abort: threading.Event,
              sch: StreamScheduler) -> None:
        """One worker generation.  Everything scheduler-touching uses the
        captured (lock, sch) pair: after a recovery, a released zombie of
        an old generation can only ever touch its own (discarded) pair."""
        tel = self.telemetry
        try:
            while not self._stop.is_set() and not abort.is_set():
                t_iter = time.monotonic()
                if gen == self._gen:
                    self._heartbeat = t_iter
                with lock:
                    if abort.is_set():
                        break
                    if not self._inbox.empty() or self._delayed:
                        with tel.span("svc.ingest"):
                            self._ingest(sch)
                    done = (self.max_rounds is not None
                            and sch._next_tau >= self.max_rounds)
                    if done:
                        # budget reached: wake waiters so wait_rounds(n)
                        # with an unreachable n re-checks its predicate
                        # instead of sleeping past a concurrent stop()
                        self._notify()
                    elif not self._paused.is_set():
                        if self._injector is not None:
                            self._maybe_flood(sch)
                            self._injector.fire("worker", abort=abort)
                            if abort.is_set() or self._stop.is_set():
                                break        # hang released by recovery
                        n = self.span_rounds
                        if self.max_rounds is not None:
                            n = min(n, self.max_rounds - sch._next_tau)
                        t_span = time.monotonic()
                        self._c_overhead.inc(t_span - t_iter)
                        with tel.span("svc.span", gen=gen,
                                      tau=int(sch._next_tau), rounds=n):
                            sch.run(n, eval_every=self.eval_every)
                        self._c_busy.inc(time.monotonic() - t_span)
                        self._c_spans.inc()
                        self._gen_spans += 1
                        self._fail_streak = 0
                        self._notify()
                        if (self._supervised
                                and self.spans_run % self.snapshot_every
                                == 0):
                            self._auto_snapshot(sch)
                        continue
                    self._c_overhead.inc(time.monotonic() - t_iter)
                # paused or round budget reached: park until submit()/
                # resume()/stop() wakes us (bounded fallback wait keeps
                # fault-delayed holdbacks and missed wakeups moving)
                t_park = time.monotonic()
                self._wake.wait(timeout=0.05 if self._delayed else 0.25)
                self._wake.clear()
                self._c_idle.inc(time.monotonic() - t_park)
        except BaseException as e:
            if self._supervised:
                self._died = (gen, e, time.monotonic())
                self._worker_died.set()      # hand off to the supervisor
            else:
                self._error = e              # surface on control threads
            self._notify()

    # -- snapshots / journal ---------------------------------------------------
    def _auto_snapshot(self, sch: StreamScheduler) -> bool:
        """Write the periodic snapshot for the current epoch; advance the
        epoch, enforce retention, and prune the journal entries that are
        now baked into every retained snapshot.  A write failure leaves
        the epoch unchanged (the journal keeps covering those events)."""
        with self._snap_lock:
            epoch = self._epoch
        path = os.path.join(self.snapshot_dir, f"snap-{epoch:06d}")
        try:
            with self.telemetry.span("svc.snapshot", epoch=epoch):
                sch.save(path)
        except OSError:
            self._c_snap_failures.inc()
            shutil.rmtree(path, ignore_errors=True)
            return False
        with self._snap_lock:
            self._snapshots.append((epoch, path))
            self._epoch = epoch + 1
            doomed = []
            while len(self._snapshots) > self.keep_snapshots:
                doomed.append(self._snapshots.pop(0)[1])
            oldest = self._snapshots[0][0]
            if self._journal is not None:
                # entries tagged <= oldest retained epoch are inside every
                # snapshot we could still restore from
                self._journal = [it for it in self._journal
                                 if it[0] > oldest]
        for p in doomed:
            shutil.rmtree(p, ignore_errors=True)
        return True

    # -- supervision -----------------------------------------------------------
    def _supervise(self) -> None:
        poll = (min(0.25, self.span_timeout / 4)
                if self.span_timeout is not None else 0.25)
        while not self._stop.is_set():
            self._worker_died.wait(timeout=poll)
            if self._stop.is_set():
                break
            if self._worker_died.is_set():
                self._worker_died.clear()
                died = self._died
                self._died = None
                if died is not None:
                    # detection latency: death stamp -> recovery start,
                    # same monotonic clock as the tracing spans
                    self._recover(died[0], died[1],
                                  detect_latency_s=time.monotonic()
                                  - died[2])
                continue
            if self.span_timeout is None:
                continue
            with self._meta:
                gen, worker = self._gen, self._worker
            # warmup grace: until this generation completes its first
            # span, heartbeat silence is more plausibly jax compilation
            # (a restored scheduler retraces its span fns) than a hang —
            # a tight span_timeout would otherwise fire a false-positive
            # recovery storm on slow hosts
            limit = (self.span_timeout if self._gen_spans > 0
                     else self.span_timeout * max(1.0, self.warmup_factor))
            stale = time.monotonic() - self._heartbeat
            if (worker is not None and worker.is_alive()
                    and stale > limit):
                self._recover(gen, TimeoutError(
                    f"span watchdog: no worker heartbeat for "
                    f"{stale:.2f}s (limit {limit}s)"),
                    detect_latency_s=stale - limit)

    def _give_up(self, err: BaseException) -> None:
        self._error = err
        self._stop.set()
        with self._meta:
            self._abort.set()
        self._notify()

    def _recover(self, gen: int, err: BaseException,
                 detect_latency_s: float = 0.0) -> None:
        """Supervisor-side recovery: abort+join generation ``gen``,
        restore the newest good snapshot, replay the journal tail, swap
        in a fresh (scheduler, lock) pair and start generation gen+1.
        ``detect_latency_s`` is how long the failure went unnoticed
        (death stamp / heartbeat limit -> now, monotonic clock)."""
        t0 = time.monotonic()
        with self._meta:
            if gen != self._gen or self._stop.is_set():
                return                       # stale report, already done
            self._gen = gen + 1
            old_abort, old_worker = self._abort, self._worker
            old_sch = self.scheduler
        with self.telemetry.span("svc.recover", gen=gen):
            old_abort.set()
            self._notify()
            if old_worker is not None:
                old_worker.join(timeout=self.join_timeout)
            joined = old_worker is None or not old_worker.is_alive()
            if joined:
                # drop the dead scheduler's in-flight staging work; the
                # restored scheduler rebuilds its bank + hot set from
                # the snapshot's clients (StreamScheduler.restore)
                old_sch.close()
            tau_at_failure = int(old_sch._next_tau)

            if self._fail_streak >= self.max_restarts:
                self._give_up(err)
                return
            streak = self._fail_streak
            self._fail_streak = streak + 1

            # restore: newest snapshot first, fall back past corrupt ones
            with self._snap_lock:
                candidates = list(self._snapshots)
            rkw = dict(self._restore_kwargs)
            if self.telemetry.enabled:
                rkw.setdefault("telemetry", self.telemetry)
            # a scheduler that was logging span args keeps logging after
            # recovery (restore defaults log_spans off) — the fuzzer's
            # weight/LR forward-fill reads the log across restarts
            rkw.setdefault("log_spans", old_sch.span_log is not None)
            restored = None
            restored_epoch = None
            corrupt_skipped = []
            engine_reused = False
            for epoch, path in reversed(candidates):
                # reusing the warm engine is only safe once the old
                # worker is provably no longer driving it
                eng = (self._engine_factory()
                       if (joined and self._engine_factory is not None)
                       else None)
                try:
                    restored = StreamScheduler.restore(
                        path, engine=eng, injector=self._injector,
                        **rkw)
                    restored_epoch = epoch
                    engine_reused = eng is not None
                    break
                except CorruptCheckpointError as ce:
                    corrupt_skipped.append({"path": path,
                                            "error": str(ce)})
                    continue
                except Exception as re:
                    self._give_up(re)
                    return
            if restored is None:
                self._give_up(err if not corrupt_skipped else
                              CorruptCheckpointError(
                                  "no restorable snapshot: all "
                                  f"{len(candidates)} candidates "
                                  "corrupt"))
                return

            # replay the journal tail: events ingested after the restored
            # snapshot was written are not inside it — push them again
            # (the restored queue orders them by tau/seq exactly as
            # before)
            with self._snap_lock:
                replay = ([e for tag, e in self._journal
                           if tag > restored_epoch]
                          if self._journal is not None else [])
            for e in replay:
                self._push_event(restored, e)

            new_lock = threading.RLock()
            new_abort = threading.Event()
            with self._meta:
                self.scheduler = restored
                self._lock = new_lock
                self._abort = new_abort
            mttr = time.monotonic() - t0
            self.recoveries.append({
                "generation": gen + 1,
                "cause": repr(err),
                "detect_latency_s": max(0.0, float(detect_latency_s)),
                "tau_at_failure": tau_at_failure,
                "tau_resumed": int(restored._next_tau),
                "restored_epoch": restored_epoch,
                "corrupt_skipped": corrupt_skipped,
                "events_replayed": len(replay),
                "worker_joined": joined,
                "engine_reused": engine_reused,
                "backoff_s": self.backoff0 * (2 ** streak),
                "mttr_s": mttr,
            })
            self._c_recoveries.inc()
            self._h_recovery.observe(mttr)
        # exponential backoff before the restart (abortable by stop)
        if self._stop.wait(self.backoff0 * (2 ** streak)):
            return
        self._heartbeat = time.monotonic()
        self._gen_spans = 0          # re-arm the watchdog warmup grace
        worker = threading.Thread(
            target=self._loop,
            args=(gen + 1, new_lock, new_abort, restored),
            name=f"federation-service-g{gen + 1}", daemon=True)
        with self._meta:
            self._worker = worker
        worker.start()
        self._wake.set()
        self._notify()
