"""FederationService: concurrent event ingestion over a live scheduler.

Closes the ROADMAP's "serve.py gap": the StreamScheduler consumes events
pushed between blocking ``run()`` calls, but nothing *produced* them while
training ran.  This layer makes the control plane live:

  * a worker thread runs scheduler spans (``span_rounds`` per iteration)
    while any number of producer threads ``submit()`` ParticipationEvents
    concurrently;
  * the inbox is a bounded queue — a full inbox blocks (or rejects, with
    ``block=False``) the producer: backpressure instead of unbounded
    memory growth under heavy traffic;
  * ``pause()``/``resume()`` gate span execution without stopping
    ingestion; ``drain()`` waits until every submitted event has been
    handed to the scheduler;
  * ``snapshot()`` captures a span-boundary-consistent checkpoint (the
    FedState dict + params, optionally persisted via
    ``StreamScheduler.save``) without tearing the service down — the
    mid-stream checkpoint/resume path for deployments.

All jax work stays on the worker thread; producers only touch the inbox.
Scheduler state is guarded by one lock the worker releases between spans,
so control calls (snapshot/pause/stats) interleave at span granularity.

Usage::

    svc = FederationService(scheduler, span_rounds=4, eval_every=8,
                            max_rounds=200)
    with svc:                          # starts the worker
        svc.submit(Arrival(tau=12, client=new_client))   # any thread
        svc.wait_rounds(200)
    print(svc.stats())
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional

from repro.fed.events import ParticipationEvent
from repro.fed.stream import StreamScheduler


class FederationService:
    """Thread-safe ingestion + span-execution service over one
    StreamScheduler."""

    def __init__(self, scheduler: StreamScheduler, *,
                 span_rounds: int = 4, eval_every: int = 1 << 30,
                 max_rounds: Optional[int] = None,
                 max_pending: int = 1024,
                 idle_sleep: float = 0.002):
        if span_rounds < 1:
            raise ValueError(f"span_rounds must be >= 1, got {span_rounds}")
        self.scheduler = scheduler
        self.span_rounds = span_rounds
        self.eval_every = eval_every
        self.max_rounds = max_rounds
        self._inbox: "queue.Queue[ParticipationEvent]" = queue.Queue(
            maxsize=max_pending)
        self._idle_sleep = idle_sleep
        self._lock = threading.RLock()       # guards scheduler state
        self._rounds_cv = threading.Condition(self._lock)
        # producers never take _lock (a span in flight would stall
        # ingestion); the submission counter gets its own tiny lock
        self._submit_lock = threading.Lock()
        self._stop = threading.Event()
        self._paused = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.events_submitted = 0
        self.events_ingested = 0
        self.spans_run = 0

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "FederationService":
        if self._worker is not None and self._worker.is_alive():
            return self
        self._stop.clear()
        self._worker = threading.Thread(target=self._loop,
                                        name="federation-service",
                                        daemon=True)
        self._worker.start()
        return self

    def stop(self, wait: bool = True) -> None:
        self._stop.set()
        with self._rounds_cv:                # wake wait_rounds() callers
            self._rounds_cv.notify_all()
        if wait and self._worker is not None:
            self._worker.join()
        if self._error is not None:
            raise RuntimeError("federation worker died") from self._error

    def __enter__(self) -> "FederationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(wait=True)

    @property
    def running(self) -> bool:
        return (self._worker is not None and self._worker.is_alive()
                and not self._stop.is_set())

    # -- ingestion (any thread) ------------------------------------------------
    def submit(self, *events: ParticipationEvent, block: bool = True,
               timeout: Optional[float] = None) -> bool:
        """Enqueue events for ingestion.  A full inbox applies
        backpressure: blocks (optionally up to ``timeout``) when
        ``block=True``, else returns False without enqueueing anything
        beyond the events already accepted."""
        for e in events:
            try:
                self._inbox.put(e, block=block, timeout=timeout)
            except queue.Full:
                return False
            with self._submit_lock:          # concurrent producers: the
                self.events_submitted += 1   # += is not atomic, and
            # drain() compares against this counter — a lost update
            # would let it return with an event still in flight
        return True

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every submitted event has been handed to the
        scheduler (it may still be *pending* on the scheduler's own queue
        until its tau is reached).  True if drained within timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.events_ingested < self.events_submitted \
                or not self._inbox.empty():
            if self._error is not None:
                raise RuntimeError("federation worker died") from self._error
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(self._idle_sleep)
        return True

    # -- control ---------------------------------------------------------------
    def pause(self) -> None:
        """Stop span execution (ingestion continues).  Returns once the
        in-flight span has finished, so scheduler state is boundary-
        consistent afterwards."""
        self._paused.set()
        with self._lock:
            pass                      # barrier: wait out the current span

    def resume(self) -> None:
        self._paused.clear()

    def wait_rounds(self, n: int, timeout: Optional[float] = None) -> bool:
        """Block until the scheduler clock reaches round n."""
        with self._rounds_cv:
            ok = self._rounds_cv.wait_for(
                lambda: self.scheduler._next_tau >= n
                or self._error is not None or self._stop.is_set(),
                timeout=timeout)
        if self._error is not None:
            raise RuntimeError("federation worker died") from self._error
        return ok and self.scheduler._next_tau >= n

    def snapshot(self, path: Optional[str] = None) -> dict:
        """Span-boundary-consistent control-plane snapshot.  With
        ``path``, also persists the full resumable checkpoint
        (StreamScheduler.save — params + FedState + history).  Returns
        the FedState dict."""
        was_paused = self._paused.is_set()
        self.pause()                  # settle at a span boundary
        try:
            with self._lock:
                self._ingest()        # fold already-submitted events in
                state = self.scheduler.state.to_dict()
                if path is not None:
                    self.scheduler.save(path)
        finally:
            if not was_paused:
                self.resume()
        return state

    def stats(self) -> dict:
        sch = self.scheduler
        return {"rounds": sch._next_tau,
                "spans_run": self.spans_run,
                "events_submitted": self.events_submitted,
                "events_ingested": self.events_ingested,
                "events_applied": sch.events_applied,
                "events_pending": sch.pending,
                "inbox_depth": self._inbox.qsize(),
                "running": self.running,
                "paused": self._paused.is_set()}

    # -- worker ----------------------------------------------------------------
    def _ingest(self) -> int:
        """Move everything in the inbox onto the scheduler queue (caller
        holds the lock)."""
        n = 0
        while True:
            try:
                e = self._inbox.get_nowait()
            except queue.Empty:
                break
            self.scheduler.push(e)
            self.events_ingested += 1
            n += 1
        return n

    def _loop(self) -> None:
        try:
            while not self._stop.is_set():
                with self._lock:
                    self._ingest()
                    done = (self.max_rounds is not None
                            and self.scheduler._next_tau >= self.max_rounds)
                    if done:
                        # budget reached: wake waiters so wait_rounds(n)
                        # with an unreachable n re-checks its predicate
                        # instead of sleeping past a concurrent stop()
                        self._rounds_cv.notify_all()
                    elif not self._paused.is_set():
                        n = self.span_rounds
                        if self.max_rounds is not None:
                            n = min(n, self.max_rounds
                                    - self.scheduler._next_tau)
                        self.scheduler.run(n, eval_every=self.eval_every)
                        self.spans_run += 1
                        self._rounds_cv.notify_all()
                        continue
                # paused or round budget reached: idle, keep ingesting
                time.sleep(self._idle_sleep)
        except BaseException as e:          # surface on the control thread
            self._error = e
            with self._rounds_cv:
                self._rounds_cv.notify_all()
