"""Theory-scored validation: run fuzzed schedules, score against Thm 3.1.

The fuzzer (fed/fuzz.py) checks *control-plane* invariants — resume,
recompile, weight sanity — but never the paper's actual claim: that the
iterate gap ||w_tau - w*||^2 stays under the Theorem 3.1 envelope, and
that the scheme-C debiasing beats schemes A/B under heterogeneous
device participation.  This module is the validate half of a
run/validate split:

  run       QuadraticRunner executes real engine rounds (device-mode
            sampling, scheme coefficients in-jit, the exact production
            path) on a synthetic quadratic federation where every paper
            constant is *closed form*: each client k holds identical
            one-hot samples, so the batch loss is exactly
            F_k(w) = 0.5 (w - c_k)^T A_k (w - c_k) with sigma_k = 0,
            and w*, L, mu, Gamma_k come from
            core.theory.quadratic_problem_constants.

  validate  TheoryValidator replays the run's dump — the observed
            per-round participation matrix (p, s), not a forecast —
            through core.theory.observed_participation_stats +
            theorem31_terms + convergence_bound, and asserts
            (1) the measured gap stays under slack * bound at every
            evaluated round (the bound is loose by construction —
            gamma ~ 1e3 for these configs — so this is a divergence
            tripwire, catching sign/scale breakage in the aggregation
            weights), and
            (2) the paper's Table-1 ordering: scheme C's tail error is
            decisively below A's and B's, which *does* discriminate —
            mis-weighting C (e.g. dropping the E/s debias) collapses it
            onto B's bias plateau and trips the check.

Fuzzed chaos schedules come from generate_participation_schedule:
objective-preserving event streams (TraceShift within the slow-trace
pool, InactivityBurst) so w* is pinned while the participation law
churns mid-run.  tests/test_theory_validator.py runs the tier-1
corpus; benchmarks/fuzz_bench.py records validator throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import theta_bound
from repro.core.participation import TRACES
from repro.core.theory import (ProblemConstants, convergence_bound,
                               observed_participation_stats,
                               quadratic_problem_constants,
                               theorem31_terms)
from repro.fed.engine import RoundEngine
from repro.fed.events import InactivityBurst, TraceShift
from repro.fed.fuzz import InvariantViolation
from repro.fed.stream import StreamScheduler
from repro.fed.task import ArrayTask

__all__ = ["QuadraticProblem", "make_quadratic_problem", "RunDump",
           "QuadraticRunner", "TheoryValidator",
           "generate_participation_schedule"]


# -- the closed-form problem ---------------------------------------------------

# heterogeneous availability: one always-on device, two CPU-contended
# ones, one with 30% inactivity — strong enough scheme-A/B bias that the
# Table-1 ordering is decisive, yet every trace keeps training moving
DEFAULT_TRACE_NAMES = ("cpu_0", "cpu_70", "cpu_90", "bw_low")
_TRACE_BY_NAME = {t.name: t for t in TRACES}

# TraceShift pool for fuzzed schedules: slow/flaky traces only, so the
# participation *law* churns while the A/B-vs-C bias gap (and with it
# the ordering check's discrimination) survives every shift
SHIFT_POOL = ("cpu_50", "cpu_70", "cpu_90", "bw_low", "bw_med")


@dataclass(frozen=True)
class QuadraticProblem:
    """A federation of diagonal quadratics with every Assumption 3.1-3.4
    constant exact (G2 is a trajectory estimate, see
    make_quadratic_problem)."""
    a_diag: np.ndarray      # (N, D) diagonal of A_k
    c: np.ndarray           # (N, D) per-client optimum c_k
    n_k: np.ndarray         # (N,) samples per client -> data weights
    p: np.ndarray           # (N,) normalized data weights
    pc: ProblemConstants
    w_star: np.ndarray      # (D,) global optimum of sum_k p_k F_k
    G2: float               # plug-in stochastic-gradient bound
    traces: tuple = ()      # per-client Trace assignment

    @property
    def n_clients(self) -> int:
        return len(self.n_k)

    @property
    def dim(self) -> int:
        return self.a_diag.shape[1]


def make_quadratic_problem(n_clients: int = 4, dim: int = 6, *,
                           seed: int = 0,
                           trace_names: Sequence[str] = DEFAULT_TRACE_NAMES
                           ) -> QuadraticProblem:
    """Sample a well-conditioned heterogeneous quadratic federation.

    Client k's dataset is n_k copies of the one-hot row e_k, so a batch
    loss of 0.5 mean_b sum_d (x_b @ A)(w - x_b @ c)^2 is *exactly*
    F_k(w): zero gradient variance (sigma_k = 0) and closed-form
    constants, the setup Li et al. / MIFA use to validate convergence
    predictions."""
    rng = np.random.default_rng(seed)
    a_diag = rng.uniform(0.5, 2.0, size=(n_clients, dim))
    c = rng.uniform(-1.0, 1.0, size=(n_clients, dim))
    n_k = rng.integers(6, 13, size=n_clients)
    p = n_k / n_k.sum()
    pc, w_star = quadratic_problem_constants(
        [np.diag(a) for a in a_diag], list(c), p)
    # G2: sup ||grad F_k|| over the trajectory's hull — iterates live
    # between w0 = 0 and w*, so bound at both endpoints with headroom
    g_at = lambda w: float(np.max(np.sum(
        (a_diag * (w[None, :] - c)) ** 2, axis=1)))
    G2 = 4.0 * max(g_at(np.zeros(dim)), g_at(w_star)) + 1.0
    traces = tuple(_TRACE_BY_NAME[trace_names[k % len(trace_names)]]
                   for k in range(n_clients))
    return QuadraticProblem(a_diag=a_diag, c=c, n_k=n_k, p=p, pc=pc,
                            w_star=w_star, G2=G2, traces=traces)


# -- fuzzed participation schedules -------------------------------------------

def generate_participation_schedule(seed: int, *, n_clients: int,
                                    rounds: int,
                                    max_events: int = 6) -> List:
    """A seeded objective-preserving event stream: TraceShifts (drawn
    from SHIFT_POOL, never touching the always-on client 0) and short
    InactivityBursts.  No arrivals/departures — membership and hence
    w* stay fixed, so the same Theorem 3.1 envelope scores the whole
    run while the participation law churns mid-stream."""
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(int(rng.integers(2, max_events + 1))):
        tau = int(rng.integers(1, max(2, rounds - 4)))
        if rng.random() < 0.6:
            i = int(rng.integers(1, n_clients))
            name = SHIFT_POOL[int(rng.integers(0, len(SHIFT_POOL)))]
            events.append(TraceShift(tau, client_id=i,
                                     trace=_TRACE_BY_NAME[name]))
        else:
            size = int(rng.integers(1, max(2, n_clients - 1)))
            ids = tuple(sorted(rng.choice(
                np.arange(1, n_clients), size=size,
                replace=False).tolist()))
            events.append(InactivityBurst(
                tau, duration=int(rng.integers(1, 4)), client_ids=ids))
    return events


# -- runner --------------------------------------------------------------------

@dataclass
class RunDump:
    """Everything the validator needs from one executed run: the error
    trajectory and the *observed* participation matrix."""
    scheme: str
    E: int
    seed: int
    taus: np.ndarray        # (R,) round indices
    errs: np.ndarray        # (R,) ||w_tau+1 - w*||^2 after each round
    s: np.ndarray           # (R, N) realized completed epochs
    p: np.ndarray           # (R, N) forward-filled span data weights
    n_events: int = 0


class QuadraticRunner:
    """Executes quadratic federations through the real engine + stream
    scheduler (device-mode sampling, in-jit scheme coefficients), one
    pooled warm engine per scheme — the run half of the validator."""

    def __init__(self, problem: Optional[QuadraticProblem] = None, *,
                 local_epochs: int = 4, batch_size: int = 4,
                 eta0: float = 0.4, chunk_size: int = 8,
                 compression=None):
        self.problem = problem if problem is not None \
            else make_quadratic_problem()
        self.E = local_epochs
        self.B = batch_size
        self.eta0 = eta0
        self.chunk_size = chunk_size
        # delta wire format for every run this runner executes: quantized
        # runs are scored against the same Thm 3.1 envelope — a sane
        # quantizer perturbs the trajectory below the bound's slack,
        # while an over-coarse one (e.g. "int8:levels=1,chunk=4096")
        # destroys the debiased update and trips the validator (the
        # mutation smoke in tests/test_compression.py pins this)
        from repro.core.compression import resolve_compression
        self.compression = resolve_compression(compression)
        pr = self.problem
        a_mat = jnp.asarray(pr.a_diag, jnp.float32)
        c_mat = jnp.asarray(pr.c, jnp.float32)

        def loss_fn(params, batch):
            x = batch["x"].astype(jnp.float32)
            a = x @ a_mat               # (..., B, D): this batch's A_k
            cc = x @ c_mat              # (..., B, D): this batch's c_k
            return 0.5 * jnp.mean(
                jnp.sum(a * (params["w"] - cc) ** 2, axis=-1))

        self.task = ArrayTask(loss_fn, (pr.n_clients,))
        self.init_params = {"w": jnp.zeros(pr.dim, jnp.float32)}
        self._w_star = jnp.asarray(pr.w_star, jnp.float32)
        self._engines: Dict[str, RoundEngine] = {}

    def _clients(self):
        from repro.fed.driver import Client
        pr = self.problem
        out = []
        for k in range(pr.n_clients):
            x = np.zeros((int(pr.n_k[k]), pr.n_clients), np.float32)
            x[:, k] = 1.0
            out.append(Client(x=x, y=np.zeros(int(pr.n_k[k]), np.int32),
                              trace=pr.traces[k]))
        return out

    def _engine(self, scheme: str) -> RoundEngine:
        # one engine per scheme: the scheme is baked at trace time, so
        # schemes can't share a jit cache — but all runs of one scheme do
        if scheme not in self._engines:
            pr = self.problem
            self._engines[scheme] = RoundEngine(
                task=self.task, clients=self._clients(),
                local_epochs=self.E, batch_size=self.B, scheme=scheme,
                eta0=self.eta0, chunk_size=self.chunk_size,
                capacity=pr.n_clients, compression=self.compression,
                max_samples=int(pr.n_k.max()))
        return self._engines[scheme]

    def run(self, scheme: str, *, rounds: int = 64, seed: int = 0,
            events: Sequence = ()) -> RunDump:
        """One executed federation: returns the dump the validator
        scores.  Clients are rebuilt per run (TraceShift mutates
        Client.trace in place) and re-staged into the pooled engine."""
        pr = self.problem
        eng = self._engine(scheme)
        for slot in range(eng.capacity):
            eng.evict(slot)
        clients = self._clients()
        eng.admit_many(list(enumerate(clients)))
        w_star = self._w_star

        def gap(params):
            return (float(jnp.sum((params["w"] - w_star) ** 2)),
                    float("nan"))

        sch = StreamScheduler(
            clients=clients, init_params=self.init_params, engine=eng,
            mode="device", seed=seed, log_spans=True, evaluate=gap)
        events = list(events)
        sch.push(*events)
        sch.run(rounds, eval_every=1)
        hist = sch.history
        taus = np.array([r.tau for r in hist])
        errs = np.array([r.loss for r in hist])
        s = np.stack([np.asarray(r.s, np.float64) for r in hist])
        # forward-fill the span-arg log into a per-round weight matrix
        log = sorted(sch.span_log, key=lambda t: t[0])
        p = np.empty((len(hist), eng.capacity))
        j = 0
        for i, rec in enumerate(hist):
            while j + 1 < len(log) and log[j + 1][0] <= rec.tau:
                j += 1
            p[i] = log[j][1]
        return RunDump(scheme=scheme, E=self.E, seed=seed, taus=taus,
                       errs=errs, s=s, p=p, n_events=len(events))


# -- validator -----------------------------------------------------------------

class TheoryValidator:
    """Scores RunDumps against Theorem 3.1 computed from the *observed*
    participation matrix.

    slack calibrates the bound check: the Thm 3.1 envelope is loose
    (gamma ~ 1e3, V >= gamma^2 on these configs, vs measured gaps of
    order 1), so the default slack 1.0 makes check_bound a divergence
    tripwire — any mis-signed or mis-scaled aggregation that sends the
    iterate away from w* crosses the envelope within a few rounds.
    Discrimination against *subtle* mis-weighting comes from
    check_scheme_ordering (Table 1): scheme C's tail error must beat
    A's and B's bias plateaus by `factor`."""

    def __init__(self, problem: QuadraticProblem, *, slack: float = 1.0):
        self.problem = problem
        self.slack = slack

    def score(self, dump: RunDump) -> dict:
        pr = self.problem
        stats = observed_participation_stats(
            dump.scheme, dump.p, dump.s, dump.E)
        theta = theta_bound(dump.scheme, pr.n_clients, dump.E)
        terms = theorem31_terms(
            replace(pr.pc, G2=pr.G2), pr.p, dump.E, theta,
            np.maximum(stats["E_ps"], 1e-9))
        M = stats["M"]
        bounds = np.array([
            convergence_bound(int(t) + 1, terms, float(M[i]))
            for i, t in enumerate(dump.taus)])
        ok = np.isfinite(dump.errs)
        ratios = dump.errs[ok] / np.maximum(bounds[ok], 1e-12)
        margin = float(ratios.max()) if ratios.size else 0.0
        return {"terms": terms, "bounds": bounds, "margin": margin,
                "S": stats["S"], "biased_frac":
                    float(stats["z"].mean()) if len(stats["z"]) else 0.0}

    @staticmethod
    def _tail_err(dump: RunDump, tail: float) -> float:
        errs = dump.errs[np.isfinite(dump.errs)]
        n = max(1, int(round(len(errs) * tail)))
        return float(np.mean(errs[-n:]))

    def check_bound(self, dump: RunDump) -> dict:
        sc = self.score(dump)
        evaluated = ~np.isnan(dump.errs)     # NaN = no eval that round
        if not np.all(np.isfinite(dump.errs[evaluated])):
            raise InvariantViolation(
                dump.seed, "theory-bound",
                f"scheme {dump.scheme}: iterate gap diverged to "
                f"non-finite")
        if sc["margin"] > self.slack:
            i = int(np.nanargmax(
                dump.errs / np.maximum(sc["bounds"], 1e-12)))
            raise InvariantViolation(
                dump.seed, "theory-bound",
                f"scheme {dump.scheme}: gap {dump.errs[i]:.4g} > "
                f"{self.slack:g} x bound {sc['bounds'][i]:.4g} at "
                f"tau={int(dump.taus[i])} (margin={sc['margin']:.3g})")
        return sc

    def check_scheme_ordering(self, dumps: Dict[str, RunDump], *,
                              factor: float = 0.6,
                              tail: float = 0.25) -> dict:
        """Table 1: scheme C (debiased) must converge decisively below
        the A/B bias plateaus — tail-mean gap_C <= factor * gap_A and
        <= factor * gap_B."""
        tails = {s: self._tail_err(d, tail) for s, d in dumps.items()}
        seed = dumps["C"].seed
        for other in ("A", "B"):
            if other not in dumps:
                continue
            if not tails["C"] <= factor * tails[other]:
                raise InvariantViolation(
                    seed, "scheme-ordering",
                    f"tail gap C={tails['C']:.4g} not <= {factor:g} x "
                    f"{other}={tails[other]:.4g} (paper Table 1 "
                    f"predicts the debiased scheme wins)")
        return tails


def validate_corpus(seeds, *, runner: Optional[QuadraticRunner] = None,
                    rounds: int = 64, slack: float = 1.0,
                    factor: float = 0.6, compression=None) -> dict:
    """Run + validate a seed corpus: each seed fuzzes a participation
    schedule, executes it under all three schemes, and scores every run
    against the bound plus the cross-scheme ordering.  Shared by the
    tier-1 test and benchmarks/fuzz_bench.py.  ``compression`` selects
    the delta wire format of the default runner — quantized corpora are
    held to the same envelope and Table-1 ordering as f32."""
    if runner is None:
        runner = QuadraticRunner(compression=compression)
    validator = TheoryValidator(runner.problem, slack=slack)
    rows = []
    for seed in seeds:
        seed = int(seed)
        events = generate_participation_schedule(
            seed, n_clients=runner.problem.n_clients, rounds=rounds)
        dumps = {s: runner.run(s, rounds=rounds, seed=seed,
                               events=events)
                 for s in ("A", "B", "C")}
        scores = {s: validator.check_bound(d) for s, d in dumps.items()}
        tails = validator.check_scheme_ordering(dumps, factor=factor)
        rows.append({"seed": seed, "n_events": dumps["C"].n_events,
                     "rounds": rounds,
                     "margin_C": scores["C"]["margin"],
                     "biased_frac_C": scores["C"]["biased_frac"],
                     "tails": tails})
    return {"cases": len(rows), "rounds": int(rounds * 3 * len(rows)),
            "max_margin": max((r["margin_C"] for r in rows),
                              default=0.0),
            "per_case": rows}
