"""Streaming participation: an event queue driving capacity-slotted spans.

The paper's core claim is that devices may "depart or arrive in the middle
of training" — yet FederatedTrainer required every arrival/departure to be
declared at construction time (Client.active_from / departs_at).  This
module makes participation an external *stream* (cf. Gu et al. 2021 on
arbitrary device unavailability; Wang & Ji 2022 on arbitrary client
participation):

  * typed ParticipationEvents — Arrival (carrying a brand-new client's
    data and trace, admitted into a free engine slot), Departure (with the
    paper's include/exclude/auto §4.3 policy), TraceShift (a client's
    availability law changes), InactivityBurst (a cohort masked for a
    window — correlated unavailability);
  * a StreamScheduler that coalesces pending events at span boundaries,
    recomputes weights / reboot / LR-restart state, and drives
    RoundEngine.run_span.  Between events, R rounds run per host dispatch
    on device-resident data; events cost one slot write each, never an
    engine rebuild or a scan recompile.

FederatedTrainer (fed/driver.py) is a thin adapter over this scheduler:
it translates its precomputed Client.active_from/departs_at schedule into
an event stream at the first engine run, so the legacy API and the
streaming API share one span-splitting implementation.

Event application semantics: events are applied at the first span boundary
with tau >= event.tau (spans always break at queued event taus, so an
event pushed before run() fires on its exact round; an event pushed with a
tau already in the past fires at the next boundary — the honest streaming
behavior for late-arriving news).

Usage::

    sch = StreamScheduler(clients=clients, init_params=params,
                          loss_fn=loss_fn, capacity=16,
                          events=[Arrival(tau=5, client=new_client)])
    sch.run(n_rounds=20, eval_every=5)   # push() more events, run() again
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import RebootState
from repro.core.departures import BoundTerms, should_exclude
from repro.core.participation import Trace
from repro.fed.driver import Client, RoundRecord
from repro.fed.engine import RoundEngine


# -- the event model ----------------------------------------------------------

@dataclass(frozen=True)
class Arrival:
    """A device joins training at round tau.

    Either ``client`` is a brand-new Client (constructed after the engine
    was built; admitted into a free capacity slot), or ``client_id``
    references an already-registered client (activation only — the path
    the FederatedTrainer adapter uses for precomputed schedules).
    """
    tau: int
    client: Optional[Client] = None
    client_id: Optional[int] = None
    fast_reboot: Optional[bool] = None   # None => scheduler default


@dataclass(frozen=True)
class Departure:
    """A device leaves at round tau.  policy: include | exclude | auto
    (Corollary 4.0.3 remaining-time criterion); None uses the client's
    own departure_policy."""
    tau: int
    client_id: int
    policy: Optional[str] = None


@dataclass(frozen=True)
class TraceShift:
    """A client's availability law changes at round tau (e.g. a device
    moves from charger+wifi to battery+cellular)."""
    tau: int
    client_id: int
    trace: Trace


@dataclass(frozen=True)
class InactivityBurst:
    """A cohort goes dark for ``duration`` rounds starting at tau
    (correlated unavailability: a regional outage, a synchronized OS
    update).  Masked clients stay in the objective — their weight mass is
    unchanged — but contribute s = 0 until the burst expires."""
    tau: int
    duration: int
    client_ids: Tuple[int, ...]


ParticipationEvent = Union[Arrival, Departure, TraceShift, InactivityBurst]


# -- the scheduler ------------------------------------------------------------

class StreamScheduler:
    """Consumes a stream of ParticipationEvents while driving
    RoundEngine.run_span over the event-free gaps.

    Scheduling loop: at each span start, pop every queued event with
    tau <= now and apply it (slot admit/evict, objective shift, reboot
    boost, LR restart, trace swap, burst masking); then run rounds until
    the next event tau / burst expiry / eval round, whichever is first.
    Membership-derived span arguments (weights p, active mask, reboot
    arrays) are recomputed only when an event dirtied them.

    mode="device": fully fused on-device sampling (the fast path).
    mode="plan":   host numpy-RNG sampling in the seed draw order —
                   sample-for-sample identical to the legacy host loop,
                   used by the trainer-parity tests.
    """

    def __init__(self, *, clients: Sequence[Client], init_params,
                 engine: Optional[RoundEngine] = None,
                 loss_fn: Optional[Callable] = None,
                 task=None, engine_mode: str = "client_parallel",
                 eval_fn: Optional[Callable] = None,
                 capacity: Optional[int] = None,
                 max_samples: Optional[int] = None,
                 sharding=None,
                 local_epochs: int = 5, batch_size: int = 10,
                 scheme: str = "C", eta0: float = 0.01,
                 chunk_size: int = 16, agg: str = "auto",
                 interpret=None, donate: Optional[bool] = None,
                 with_metrics: bool = False,
                 reboot_boost: float = 3.0, fast_reboot: bool = True,
                 horizon: Optional[int] = None,
                 bound_terms: Optional[BoundTerms] = None,
                 seed: int = 0, mode: str = "device",
                 rng: Optional[np.random.Generator] = None,
                 key=None, evaluate: Optional[Callable] = None,
                 history: Optional[List[RoundRecord]] = None,
                 reboots: Optional[List[RebootState]] = None,
                 objective: Optional[set] = None,
                 events: Sequence[ParticipationEvent] = ()):
        if mode not in ("device", "plan"):
            raise ValueError(f"mode must be device|plan, got {mode!r}")
        self.mode = mode
        self.clients: List[Client] = list(clients)
        if engine is None:
            engine = RoundEngine(
                loss_fn=loss_fn, task=task, clients=self.clients,
                local_epochs=local_epochs, batch_size=batch_size,
                scheme=scheme, eta0=eta0, chunk_size=chunk_size, agg=agg,
                interpret=interpret, donate=donate,
                with_metrics=with_metrics, capacity=capacity,
                max_samples=max_samples, sharding=sharding,
                mode=engine_mode)
        self.engine = engine
        self.E = engine.E
        self.B = engine.B
        self.eta0 = engine.eta0
        self.params = init_params
        self.eval_fn = eval_fn
        self._evaluate = evaluate          # optional external eval callback
        self.reboot_boost = reboot_boost
        self.fast_reboot = fast_reboot
        self.horizon = horizon
        self.bound_terms = bound_terms or BoundTerms(
            D=5.0, V=20.0, gamma=10.0, E=self.E)
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self._key = key if key is not None else jax.random.PRNGKey(seed)

        # slot registry: client id == index into self.clients; founding
        # clients occupy slots 0..C-1 in id order
        C = len(self.clients)
        self.slot_of: Dict[int, int] = {i: i for i in range(C)}
        self.client_at: Dict[int, int] = {i: i for i in range(C)}
        self.free_slots: List[int] = list(range(C, engine.capacity))
        heapq.heapify(self.free_slots)

        # membership state
        self.objective: set = (objective if objective is not None
                               else set(range(C)))
        self.joined: Dict[int, int] = {i: 0 for i in self.objective}
        self.departed: set = set()
        self.mask_until: Dict[int, int] = {}
        self._expiry_taus: set = set()
        self.lr_shift_tau = 0
        self._rb_tau0 = np.zeros(engine.capacity, np.int32)
        self._rb_boost = np.ones(engine.capacity, np.float32)
        self.reboots: List[RebootState] = (reboots if reboots is not None
                                           else [])
        self.history: List[RoundRecord] = (history if history is not None
                                           else [])

        # the event queue (heap keyed by (tau, arrival order))
        self._queue: List[Tuple[int, int, ParticipationEvent]] = []
        self._seq = itertools.count()
        self._next_tau = 0
        self._span_args = None
        self._dirty = True
        self.events_applied = 0
        self.push(*events)

    # -- queue ---------------------------------------------------------------
    def push(self, *events: ParticipationEvent) -> None:
        """Enqueue participation events (any order; any time — including
        between run() calls, which is the streaming use case)."""
        for e in events:
            heapq.heappush(self._queue, (e.tau, next(self._seq), e))

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- membership ----------------------------------------------------------
    def _active(self, i: int, tau: int) -> bool:
        return (i in self.objective and i not in self.departed
                and self.joined.get(i, tau + 1) <= tau
                and self.mask_until.get(i, tau) <= tau)

    def _register(self, client: Client) -> int:
        self.clients.append(client)
        return len(self.clients) - 1

    def _alloc_slot(self, i: int) -> int:
        if not self.free_slots:
            raise RuntimeError(
                f"engine capacity {self.engine.capacity} exhausted: no "
                f"free slot for arriving client {i} (build the engine "
                f"with a larger capacity=)")
        slot = heapq.heappop(self.free_slots)
        self.slot_of[i] = slot
        self.client_at[slot] = i
        return slot

    def _free_slot(self, i: int) -> None:
        slot = self.slot_of.pop(i, None)
        if slot is None:
            return
        del self.client_at[slot]
        self.engine.evict(slot)
        self._rb_tau0[slot] = 0
        self._rb_boost[slot] = 1.0
        heapq.heappush(self.free_slots, slot)

    # -- event application ----------------------------------------------------
    def _admit(self, slot: int, client: Client,
               admits: Optional[list]) -> None:
        """Stage a slot admission: coalesced into one admit_many burst at
        the span boundary when a batch list is given (the scheduler
        path), else written through immediately."""
        if admits is None:
            self.engine.admit(slot, client)
        else:
            admits.append((slot, client))

    def _apply(self, e: ParticipationEvent, tau: int,
               admits: Optional[list] = None) -> str:
        if isinstance(e, Arrival):
            if e.client is not None:
                i = self._register(e.client)
                slot = self._alloc_slot(i)
                self._admit(slot, e.client, admits)
            else:
                i = e.client_id
                if i is None or not 0 <= i < len(self.clients):
                    raise ValueError(f"Arrival without client needs a "
                                     f"registered client_id, got {i!r}")
                if i not in self.slot_of:
                    slot = self._alloc_slot(i)
                    self._admit(slot, self.clients[i], admits)
            if i in self.objective:
                if i not in self.departed:
                    return ""                   # duplicate arrival: no-op
                # rejoin of an include-departed device: the objective
                # never shifted, so no LR restart / reboot boost — the
                # device simply resumes participating
                self.departed.discard(i)
                self.joined[i] = tau
                return f"rejoin:{i};"
            self.objective.add(i)
            self.joined[i] = tau
            self.departed.discard(i)
            self.lr_shift_tau = tau
            fast = self.fast_reboot if e.fast_reboot is None else \
                e.fast_reboot
            if fast:
                self.reboots.append(RebootState(tau, i, self.reboot_boost))
                slot = self.slot_of[i]
                self._rb_tau0[slot] = tau
                self._rb_boost[slot] = self.reboot_boost
            return f"arrival:{i};"

        if isinstance(e, Departure):
            i = e.client_id
            if i not in self.objective or i in self.departed:
                return ""                       # duplicate/unknown: no-op
            cl = self.clients[i]
            policy = e.policy or cl.departure_policy
            if policy == "auto":
                # Corollary 4.0.3: exclude iff enough training remains
                T = self.horizon if self.horizon is not None else tau + 100
                policy = "exclude" if should_exclude(
                    T, tau, self.bound_terms, cl.gamma_l) else "include"
            self.departed.add(i)
            self._free_slot(i)
            if policy == "exclude":
                self.objective.discard(i)
                self.lr_shift_tau = tau
                return f"departure-exclude:{i};"
            return f"departure-include:{i};"

        if isinstance(e, TraceShift):
            i = e.client_id
            self.clients[i].trace = e.trace     # plan-mode draws follow
            slot = self.slot_of.get(i)
            if slot is not None:
                self.engine.set_trace(slot, e.trace)
            return f"trace-shift:{i};"

        if isinstance(e, InactivityBurst):
            until = tau + e.duration
            for i in e.client_ids:
                self.mask_until[i] = max(self.mask_until.get(i, 0), until)
            self._expiry_taus.add(until)
            ids = ",".join(str(i) for i in e.client_ids)
            return f"burst:{ids}@{e.duration};"

        raise TypeError(f"unknown participation event {e!r}")

    def _apply_events(self, tau: int) -> str:
        ev = ""
        # an arrival burst coalesces into one fused admit_many: slot
        # writes are deferred while consecutive Arrivals pop, and flushed
        # before any event type that may read or free a slot
        admits: List = []

        def flush():
            if admits:
                self.engine.admit_many(admits)
                admits.clear()

        try:
            while self._queue and self._queue[0][0] <= tau:
                _, _, e = heapq.heappop(self._queue)
                if not isinstance(e, Arrival):
                    flush()
                ev += self._apply(e, tau, admits)
                self.events_applied += 1
        finally:
            # a raising event must not strand staged admissions: slot
            # bookkeeping already recorded them, so the engine writes
            # have to land even on the error path
            flush()
        if tau in self._expiry_taus:
            self._expiry_taus.discard(tau)
            self._dirty = True                  # masked cohort resumes
        if ev:
            self._dirty = True
        return ev

    # -- span arguments -------------------------------------------------------
    def data_weights(self) -> np.ndarray:
        """Slot-indexed data weights p over the current objective.  An
        include-departed client keeps its mass in the normalization (the
        paper's §4.3 'include' keeps the old objective) but holds no
        slot, so its column simply never appears — arithmetically
        identical to a zero-coefficient column."""
        p = np.zeros(self.engine.capacity)
        total = sum(self.clients[i].n for i in self.objective)
        for i in self.objective:
            slot = self.slot_of.get(i)
            if slot is not None:
                p[slot] = self.clients[i].n / total
        return p

    def _build_span_args(self, tau: int):
        p = self.data_weights()
        active = np.zeros(self.engine.capacity, np.float32)
        for slot, i in self.client_at.items():
            if self._active(i, tau):
                active[slot] = 1.0
        return dict(p=jnp.asarray(p, jnp.float32),
                    active=jnp.asarray(active),
                    lr_shift_tau=self.lr_shift_tau,
                    reboot_tau0=jnp.asarray(self._rb_tau0),
                    reboot_boost=jnp.asarray(self._rb_boost))

    def _span_end(self, tau: int, stop: int, ev: str,
                  eval_every: int) -> int:
        """Largest t <= stop such that [tau, t) has fixed membership and
        at most one eval, which lands on the final round of the span."""
        end = stop
        if self._queue:
            end = min(end, max(self._queue[0][0], tau + 1))
        for t in self._expiry_taus:
            if tau < t < end:
                end = t
        if ev:
            return tau + 1      # event round: evaluate right after it
        next_eval = tau + ((-tau) % eval_every)
        if next_eval < end:
            end = next_eval + 1
        return end

    # -- plan-mode sampling (seed RNG draw order) -----------------------------
    def _sample_plan(self, tau: int):
        Cs = self.engine.capacity
        alpha = np.zeros((Cs, self.E), np.float32)
        idx = np.zeros((Cs, self.E, self.B), np.int64)
        for slot in range(Cs):
            i = self.client_at.get(slot)
            if i is None or not self._active(i, tau):
                continue
            cl = self.clients[i]
            alpha[slot] = (np.arange(self.E)
                           < cl.trace.sample_s(self.rng, self.E)
                           ).astype(np.float32)
            idx[slot] = self.rng.integers(0, cl.n, size=(self.E, self.B))
        return alpha, idx

    # -- evaluation -----------------------------------------------------------
    def evaluate(self):
        if self._evaluate is not None:
            return self._evaluate(self.params)
        if self.eval_fn is None:
            return float("nan"), float("nan")
        xs = [self.clients[i].x_test for i in sorted(self.objective)
              if self.clients[i].x_test is not None]
        ys = [self.clients[i].y_test for i in sorted(self.objective)
              if self.clients[i].y_test is not None]
        if not xs:
            return float("nan"), float("nan")
        return self.eval_fn(self.params, jnp.asarray(np.concatenate(xs)),
                            jnp.asarray(np.concatenate(ys)))

    # -- main loop ------------------------------------------------------------
    def run(self, n_rounds: int, eval_every: int = 1):
        eng = self.engine
        start = self._next_tau
        stop = start + n_rounds
        tau = start
        while tau < stop:
            ev = self._apply_events(tau)
            end = self._span_end(tau, stop, ev, eval_every)
            R = end - tau
            if self._span_args is None or self._dirty:
                self._span_args = self._build_span_args(tau)
                self._dirty = False
            kwargs = self._span_args
            if self.mode == "device":
                self._key, sub = jax.random.split(self._key)
                self.params, m = eng.run_span(self.params, tau, R,
                                              key=sub, **kwargs)
            else:
                plans = [self._sample_plan(t) for t in range(tau, end)]
                alphas = np.stack([pl[0] for pl in plans])
                idxs = np.stack([pl[1] for pl in plans])
                self.params, m = eng.run_span(self.params, tau, R,
                                              plan=(alphas, idxs), **kwargs)
            eval_last = (end - 1) % eval_every == 0 or (ev and R == 1)
            for j, t in enumerate(range(tau, end)):
                loss = acc = float("nan")
                if eval_last and t == end - 1:
                    loss, acc = self.evaluate()
                s = m["s"][j]
                self.history.append(RoundRecord(
                    t, float(loss), float(acc), float(m["eta"][j]),
                    int((s > 0).sum()), s, ev if t == tau else ""))
            tau = end
        self._next_tau = stop
        return self.history
