"""Streaming participation: an event-sourced control plane driving spans.

The paper's core claim is that devices may "depart or arrive in the middle
of training" — yet FederatedTrainer required every arrival/departure to be
declared at construction time (Client.active_from / departs_at).  This
module makes participation an external *stream* (cf. Gu et al. 2021 on
arbitrary device unavailability; Wang & Ji 2022 on arbitrary client
participation), split into two layers:

  * FedState (fed/state.py) — the pure, serializable control plane: slot
    registry, objective/joined/departed/mask membership, reboot arrays,
    LR-shift round, the pending event queue and the RNG/key state, with
    event application as plain-data state transitions that *return* the
    implied engine actions;
  * StreamScheduler (here) — the thin span-execution loop: it pops due
    events at span boundaries, executes the returned slot actions against
    the capacity-slotted RoundEngine (arrival runs coalesce into one
    fused admit_many burst), and drives RoundEngine.run_span over the
    event-free gaps.  Between events, R rounds run per host dispatch on
    device-resident data; events cost one slot write each, never an
    engine rebuild or a scan recompile.

Because FedState round-trips through to_dict()/from_dict() and per-round
randomness is derived by folding the round index into a never-split base
key (fed/engine.py), ``save()``/``restore()`` give exact mid-stream
checkpoint/resume: a killed run restored from disk replays the remaining
rounds bit-for-bit against an uninterrupted one
(tests/test_checkpoint_resume.py).  fed/service.py layers a thread-safe
ingestion service on top; FederatedTrainer (fed/driver.py) remains a thin
adapter translating its precomputed schedule into events.

Event application semantics: events are applied at the first span boundary
with tau >= event.tau (spans always break at queued event taus, so an
event pushed before run() fires on its exact round; an event pushed with a
tau already in the past fires at the next boundary — the honest streaming
behavior for late-arriving news).

Usage::

    sch = StreamScheduler(clients=clients, init_params=params,
                          loss_fn=loss_fn, capacity=16,
                          events=[Arrival(tau=5, client=new_client)])
    sch.run(n_rounds=20, eval_every=5)   # push() more events, run() again
    sch.save("ckpt/")                    # ... crash ...
    sch = StreamScheduler.restore("ckpt/", loss_fn=loss_fn)
    sch.run(n_rounds=20, eval_every=5)   # resumes round-for-round
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import RebootState
from repro.core.departures import BoundTerms
from repro.fed.bank import ClientBank, CohortStager
from repro.fed.driver import Client, RoundRecord
from repro.fed.engine import RoundEngine, trace_cdf_row
# event types re-exported for compatibility (they lived here pre-PR-5)
from repro.fed.events import (Arrival, Departure,  # noqa: F401
                              InactivityBurst, ParticipationEvent,
                              TraceShift)
from repro.fed.state import FedState
from repro.obs.fedmetrics import FedObserver
from repro.obs.telemetry import resolve as resolve_telemetry


class StreamScheduler:
    """Consumes a stream of ParticipationEvents while driving
    RoundEngine.run_span over the event-free gaps.

    Scheduling loop: at each span start, pop every queued event with
    tau <= now, apply it to the FedState (slot bookkeeping, objective
    shift, reboot boost, LR restart, burst masking) and execute the
    returned engine actions (admit/evict/set_trace — consecutive admits
    coalesce into one fused admit_many burst); then run rounds until the
    next event tau / burst expiry / eval round, whichever is first.
    Membership-derived span arguments (weights p, active mask, reboot
    arrays) are recomputed only when an event dirtied them.

    mode="device": fully fused on-device sampling (the fast path).
    mode="plan":   host numpy-RNG sampling in the seed draw order —
                   sample-for-sample identical to the legacy host loop,
                   used by the trainer-parity tests.
    """

    def __init__(self, *, clients: Sequence[Client] = (), init_params,
                 engine: Optional[RoundEngine] = None,
                 loss_fn: Optional[Callable] = None,
                 task=None, engine_mode: str = "client_parallel",
                 eval_fn: Optional[Callable] = None,
                 capacity: Optional[int] = None,
                 max_samples: Optional[int] = None,
                 sharding=None,
                 local_epochs: int = 5, batch_size: int = 10,
                 scheme: str = "C", eta0: float = 0.01,
                 chunk_size: int = 16, agg: str = "auto",
                 interpret=None, donate: Optional[bool] = None,
                 compression=None, with_metrics: bool = False,
                 reboot_boost: float = 3.0, fast_reboot: bool = True,
                 horizon: Optional[int] = None,
                 bound_terms: Optional[BoundTerms] = None,
                 seed: int = 0, mode: str = "device",
                 rng: Optional[np.random.Generator] = None,
                 key=None, evaluate: Optional[Callable] = None,
                 history: Optional[List[RoundRecord]] = None,
                 reboots: Optional[List[RebootState]] = None,
                 objective: Optional[set] = None,
                 state: Optional[FedState] = None,
                 events: Sequence[ParticipationEvent] = (),
                 injector=None, log_spans: bool = False,
                 telemetry=None, bank=None, prefetch: bool = False):
        if mode not in ("device", "plan"):
            raise ValueError(f"mode must be device|plan, got {mode!r}")
        self.mode = mode
        # telemetry (repro.obs): null default — a reused engine keeps its
        # own telemetry; a freshly built one inherits the scheduler's
        self.telemetry = resolve_telemetry(telemetry)
        self.observer = FedObserver(self.telemetry)
        self._m_applied = self.telemetry.counter(
            "sched_spans_total", "event-free spans executed")
        self._m_cache_hit = self.telemetry.counter(
            "sched_eval_cache_hits_total",
            "eval-array cache hits (objective unchanged)")
        self._m_cache_miss = self.telemetry.counter(
            "sched_eval_cache_miss_total",
            "eval-array cache rebuilds (objective membership changed)")
        # fault-injection hook (fed/faults.py): fires site "sched_span"
        # at every span iteration so chaos tests can crash mid-run
        self.injector = injector
        # optional per-span argument log: (tau, p, active, lr_shift_tau)
        # appended whenever membership-derived span args are recomputed —
        # the fuzzer's weight/LR invariants forward-fill from it
        self.span_log: Optional[List[tuple]] = [] if log_spans else None
        clients = list(clients) if state is None else state.clients
        if engine is None:
            engine = RoundEngine(
                loss_fn=loss_fn, task=task, clients=clients,
                local_epochs=local_epochs, batch_size=batch_size,
                scheme=scheme, eta0=eta0, chunk_size=chunk_size, agg=agg,
                interpret=interpret, donate=donate,
                compression=compression,
                with_metrics=with_metrics, capacity=capacity,
                max_samples=max_samples, sharding=sharding,
                mode=engine_mode, telemetry=telemetry)
        self.engine = engine
        self.E = engine.E
        self.B = engine.B
        self.eta0 = engine.eta0
        self.params = init_params
        self.eval_fn = eval_fn
        self._evaluate = evaluate          # optional external eval callback
        if state is None:
            state = FedState(
                clients=clients, capacity=engine.capacity,
                reboot_boost=reboot_boost, fast_reboot=fast_reboot,
                horizon=horizon, bound_terms=bound_terms,
                local_epochs=engine.E, seed=seed, rng=rng, key=key,
                objective=objective, reboots=reboots)
        self.state = state
        self.history: List[RoundRecord] = (history if history is not None
                                           else [])
        # tiered client store (fed/bank.py): the fleet's host-side home —
        # bank=True builds one from the engine geometry, or pass a
        # configured ClientBank (spill_dir / ram budget); prefetch=True
        # additionally overlaps arrival staging with span compute on a
        # background thread (implies a bank)
        if prefetch and bank is None:
            bank = True
        if bank:
            self.bank = (bank if isinstance(bank, ClientBank)
                         else ClientBank(engine.task, engine.nmax))
            for i, c in enumerate(self.state.clients):
                self.bank.put(i, c)
        else:
            self.bank = None
        self._stager = (CohortStager(engine, self.bank)
                        if prefetch else None)
        self._prefetch_sig = None
        self._staged = None          # retained cohort (spans boundaries)
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._m_prefetch_hits = self.telemetry.counter(
            "sched_prefetch_hits_total",
            "admits served from a prefetched cohort")
        self._m_prefetch_miss = self.telemetry.counter(
            "sched_prefetch_misses_total",
            "admits that fell back to the synchronous staging path")
        self._span_args = None
        self._dirty = True
        self._eval_cache = None            # (objective_version, x, y)
        self.push(*events)

    # -- control-plane views (the public surface pre-refactor) ----------------
    @property
    def clients(self) -> List[Client]:
        return self.state.clients

    @property
    def objective(self) -> set:
        return self.state.objective

    @property
    def departed(self) -> set:
        return self.state.departed

    @property
    def slot_of(self):
        return self.state.slot_of

    @property
    def client_at(self):
        return self.state.client_at

    @property
    def free_slots(self):
        return self.state.free_slots

    @property
    def reboots(self) -> List[RebootState]:
        return self.state.reboots

    @property
    def lr_shift_tau(self) -> int:
        return self.state.lr_shift_tau

    @property
    def events_applied(self) -> int:
        return self.state.events_applied

    @property
    def rng(self) -> np.random.Generator:
        return self.state.rng

    @property
    def _next_tau(self) -> int:
        return self.state.next_tau

    @property
    def _queue(self):
        return self.state.queue

    def data_weights(self) -> np.ndarray:
        return self.state.data_weights()

    # -- queue ---------------------------------------------------------------
    def push(self, *events: ParticipationEvent) -> None:
        """Enqueue participation events (any order; any time — including
        between run() calls, which is the streaming use case).  With the
        tiered bank on, staging starts here — at ingestion — not at the
        next span start: the boundary is the deadline, so the staging
        thread should get the full span of lead time, not the sliver
        between span dispatch and the boundary."""
        self.state.push(*events)
        if self._stager is not None:
            self._maybe_prefetch()

    @property
    def pending(self) -> int:
        return self.state.pending

    # -- event application (executes FedState transitions on the engine) -----
    def _apply_events(self, tau: int) -> str:
        st = self.state
        if not st.due(tau):
            # fast path: nothing queued for this boundary — skip the
            # span/observer machinery entirely (most boundaries)
            if st.expire(tau):
                self._dirty = True
            return ""
        with self.telemetry.span("sched.apply_events", tau=tau):
            return self._apply_due_events(tau)

    def _apply_due_events(self, tau: int) -> str:
        st = self.state
        ev = ""
        # an arrival burst coalesces into one fused admit burst: slot
        # writes are deferred while consecutive admit actions accumulate,
        # and flushed before any action that may read or free a slot
        admits: List = []

        def flush():
            if admits:
                try:
                    self._flush_admits(admits)
                finally:
                    admits.clear()

        try:
            while st.due(tau):
                e = st.pop_event()
                s, actions = st.apply(e, tau)
                self.observer.observe_event(e, tau)
                for act in actions:
                    if act[0] == "admit":
                        admits.append((act[1], act[2]))
                    elif act[0] == "evict":
                        flush()
                        self.engine.evict(act[1])
                    else:                       # ("set_trace", slot, trace)
                        flush()
                        self.engine.set_trace(act[1], act[2])
                ev += s
                st.events_applied += 1
        finally:
            # a raising event must not strand staged admissions: slot
            # bookkeeping already recorded them, so the engine writes
            # have to land even on the error path
            flush()
        if st.expire(tau):
            self._dirty = True                  # masked cohort resumes
        if ev:
            self._dirty = True
        return ev

    def _flush_admits(self, admits: List[tuple]) -> None:
        """Land a coalesced admit burst: admits is (slot, client_id)
        pairs.  With a prefetched cohort covering some of the clients,
        those slots commit from the already-on-device stack (one fused
        gather+scatter); the rest take the synchronous admit_many path.
        n and trace CDFs always come from the live Client at commit
        time, so prefetched rows can never publish a stale law."""
        st = self.state
        pairs = [(slot, i, st.clients[i]) for slot, i in admits]
        staged = self._staged
        if self._stager is not None:
            fresh = self._stager.collect()
            if fresh is not None:
                # retain: later boundaries commit their subset of the
                # same stack without re-staging (rows are immutable)
                staged = self._staged = fresh
        if self.bank is not None:
            # fresh arrivals enter the bank here (first time their
            # client_id exists); a staged client's host rows ride along
            # so the span loop's thread never re-pads them
            for _, i, c in pairs:
                j = staged.index.get(id(c)) if staged is not None else None
                self.bank.put(i, c,
                              rows=staged.rows[j] if j is not None
                              else None)
        hits, misses = [], []
        for slot, _, c in pairs:
            j = staged.index.get(id(c)) if staged is not None else None
            if j is not None:
                hits.append((slot, c, j))
            else:
                misses.append((slot, c))
        if hits:
            self.engine.commit_burst(
                staged.dev,
                slots=[slot for slot, _, _ in hits],
                ns=[c.n for _, c, _ in hits],
                cdfs=[trace_cdf_row(c.trace, self.engine.E)
                      for _, c, _ in hits],
                idx=[j for _, _, j in hits])
            self.prefetch_hits += len(hits)
            self._m_prefetch_hits.inc(len(hits))
        if misses:
            if self._stager is not None:
                self.prefetch_misses += len(misses)
                self._m_prefetch_miss.inc(len(misses))
            self.engine.admit_many(misses)

    # -- evaluation -----------------------------------------------------------
    def _eval_arrays(self):
        """Concatenated held-out arrays over the objective, cached on
        device and invalidated only when objective *membership* changes
        (FedState.objective_version) — evaluate() used to re-concatenate
        and re-transfer every eval round."""
        version = self.state.objective_version
        if self._eval_cache is not None and self._eval_cache[0] == version:
            self._m_cache_hit.inc()
            return self._eval_cache[1], self._eval_cache[2]
        self._m_cache_miss.inc()
        xs = [self.clients[i].x_test for i in sorted(self.objective)
              if self.clients[i].x_test is not None]
        ys = [self.clients[i].y_test for i in sorted(self.objective)
              if self.clients[i].y_test is not None]
        if not xs:
            x = y = None
        else:
            x = jnp.asarray(np.concatenate(xs))
            y = jnp.asarray(np.concatenate(ys))
        self._eval_cache = (version, x, y)
        return x, y

    def evaluate(self):
        if self._evaluate is not None:
            return self._evaluate(self.params)
        if self.eval_fn is None:
            return float("nan"), float("nan")
        x, y = self._eval_arrays()
        if x is None:
            return float("nan"), float("nan")
        return self.eval_fn(self.params, x, y)

    # -- main loop ------------------------------------------------------------
    def run(self, n_rounds: int, eval_every: int = 1):
        eng = self.engine
        st = self.state
        start = st.next_tau
        stop = start + n_rounds
        tau = start
        # spans dispatch asynchronously: per-span metrics stay
        # device-side (host_metrics=False) and materialize only after
        # the loop, so the host races ahead applying events / staging
        # cohorts / dispatching the next boundary while the device is
        # still crunching earlier spans.  An evaluate() (which reads
        # params) is the only in-loop sync point.
        pending = []      # (tau, end, ev_label, device metrics, eval)
        try:
            while tau < stop:
                if self.injector is not None:
                    self.injector.fire("sched_span", tau=tau)
                ev = self._apply_events(tau)
                end = st.span_end(tau, stop, ev, eval_every)
                R = end - tau
                if self._stager is not None:
                    # double buffer: while this span computes, the
                    # staging thread assembles + ships the next event
                    # boundary's arrival cohort from the bank
                    self._maybe_prefetch()
                if self._span_args is None or self._dirty:
                    a = st.span_args(tau)
                    if self.span_log is not None:
                        self.span_log.append((tau, a["p"].copy(),
                                              a["active"].copy(),
                                              a["lr_shift_tau"]))
                    # one batched transfer for the four membership
                    # arrays (separate puts are a host dispatch each,
                    # paid at every churn boundary)
                    p_d, act_d, rb0_d, rbb_d = jax.device_put((
                        np.asarray(a["p"], np.float32),
                        np.asarray(a["active"], np.float32),
                        np.asarray(a["reboot_tau0"], np.int32),
                        np.asarray(a["reboot_boost"], np.float32)))
                    self._span_args = dict(
                        p=p_d, active=act_d,
                        lr_shift_tau=a["lr_shift_tau"],
                        reboot_tau0=rb0_d, reboot_boost=rbb_d)
                    self._dirty = False
                kwargs = self._span_args
                with self.telemetry.span("sched.run_span", tau=tau,
                                         rounds=R):
                    if self.mode == "device":
                        # the base key is never split: per-round
                        # randomness folds the round index on device, so
                        # the sample stream is invariant to span/chunk
                        # structure (resume parity)
                        self.params, m = eng.run_span(
                            self.params, tau, R, key=st.key,
                            host_metrics=False, **kwargs)
                    else:
                        plans = [st.sample_plan(t, self.E, self.B)
                                 for t in range(tau, end)]
                        alphas = np.stack([pl[0] for pl in plans])
                        idxs = np.stack([pl[1] for pl in plans])
                        self.params, m = eng.run_span(
                            self.params, tau, R, plan=(alphas, idxs),
                            host_metrics=False, **kwargs)
                self._m_applied.inc()
                eval_last = (end - 1) % eval_every == 0 or (ev and R == 1)
                ev_result = self.evaluate() if eval_last else None
                pending.append((tau, end, ev, m, ev_result))
                tau = end
            st.next_tau = stop
        finally:
            # materialize whatever completed, even if a mid-run fault
            # unwound the loop — those spans did run
            self._flush_spans(pending)
        return self.history

    def _flush_spans(self, pending) -> None:
        """Convert deferred device-side span metrics to host records —
        history rows, observer signals, and wire accounting, in span
        order."""
        eng = self.engine
        # one batched transfer for every span's device metrics — a
        # per-array np.asarray would pay a separate sync each (dozens of
        # tiny readbacks per churned span run)
        hosted = jax.device_get([m for _, _, _, m, _ in pending])
        for (tau, end, ev, _, ev_result), m in zip(pending, hosted):
            m = {k: np.concatenate(chunks) for k, chunks in m.items()}
            eng.account_uploads(m["s"])
            self.observer.observe_span(self.state, tau, m, eng.scheme,
                                       self.E)
            for j, t in enumerate(range(tau, end)):
                loss = acc = float("nan")
                if ev_result is not None and t == end - 1:
                    loss, acc = ev_result
                s = m["s"][j]
                self.history.append(RoundRecord(
                    t, float(loss), float(acc), float(m["eta"][j]),
                    int((s > 0).sum()), s, ev if t == tau else ""))

    def _maybe_prefetch(self) -> None:
        """Submit the queued-arrival horizon as ONE staged cohort (not
        one per boundary): every Arrival currently in the queue pads,
        stacks and ships together, and successive boundaries commit
        their own subset of the retained stack.  Safe because the staged
        stack carries data rows only — n and the trace CDF are read from
        the live Client at commit — so a row can't go stale between
        boundaries.  Idempotent: skips when the retained cohort already
        covers the horizon; a genuinely new arrival set supersedes the
        in-flight staging work."""
        st = self.state
        if not st.queue:
            self._staged = None                 # horizon drained
            return
        until = max(t for t, _, _ in st.queue)
        items = st.upcoming_arrivals(until)
        if not items:
            return
        staged = self._staged
        if staged is not None and all(id(c) in staged.index
                                      for _, c in items):
            return
        sig = tuple(sorted(id(c) for _, c in items))
        if sig == self._prefetch_sig:
            return
        self._prefetch_sig = sig
        self._stager.submit(items)

    def close(self) -> None:
        """Stop the prefetch staging thread (if any).  Idempotent; the
        scheduler itself stays usable — the next prefetch would simply
        restage.  FederationService calls this whenever it retires a
        scheduler (stop / supervised recovery)."""
        self._staged = None
        if self._stager is not None:
            self._stager.close()

    def prefetch_stats(self) -> dict:
        """Bank + stager counters for dashboards and benches (empty when
        the tiered store is off)."""
        out = {}
        if self.bank is not None:
            out["bank"] = self.bank.stats()
        if self._stager is not None:
            out["stager"] = self._stager.stats()
            out["hits"] = self.prefetch_hits
            out["misses"] = self.prefetch_misses
        return out

    # -- checkpoint / resume ---------------------------------------------------
    def engine_config(self) -> dict:
        """The geometry/hyperparameters needed to rebuild the engine on
        restore (the loss/task callables are the caller's to re-supply)."""
        eng = self.engine
        return {"local_epochs": eng.E, "batch_size": eng.B,
                "scheme": eng.scheme, "eta0": eng.eta0,
                "chunk_size": eng.chunk_size, "agg": eng.agg,
                "compression": eng.compression.name,
                "with_metrics": eng.with_metrics,
                "engine_mode": eng.mode, "capacity": eng.capacity,
                "max_samples": eng.nmax, "mode": self.mode,
                "bank": self.bank is not None,
                "prefetch": self._stager is not None}

    def save(self, path: str, extra: Optional[dict] = None,
             client_chunks: Optional[bool] = None) -> None:
        """Persist params + FedState + history + engine config so a killed
        run resumes round-for-round (checkpoint/io.save_fed_checkpoint).
        Bank-backed schedulers default to the chunked fleet format
        (fed-checkpoint-v2): one checksummed npz per client, streamed,
        so a host-RAM-scale fleet never materializes twice."""
        from repro.checkpoint.io import save_fed_checkpoint
        if client_chunks is None:
            client_chunks = self.bank is not None
        save_fed_checkpoint(
            path, self.params, self.state.to_dict(),
            history=history_to_dict(self.history),
            config=self.engine_config(), extra=extra,
            injector=self.injector, telemetry=self.telemetry,
            client_chunks=client_chunks)

    @classmethod
    def restore(cls, path: str, *, loss_fn: Optional[Callable] = None,
                task=None, eval_fn: Optional[Callable] = None,
                evaluate: Optional[Callable] = None, sharding=None,
                interpret=None, donate: Optional[bool] = None,
                engine: Optional[RoundEngine] = None, injector=None,
                log_spans: bool = False, telemetry=None,
                **overrides) -> "StreamScheduler":
        """Rebuild a scheduler from ``save()`` output: the engine is
        reconstructed from the persisted geometry, every occupied slot is
        re-admitted from the serialized client data, and the FedState
        (queue, membership, RNG/key) resumes exactly where it stopped.
        Only the non-serializable callables (loss_fn/task, eval hooks)
        must be re-supplied.

        ``engine``: reuse an existing engine of the same geometry instead
        of building (and recompiling) a fresh one — every slot is evicted
        and the checkpoint's occupancy re-staged.  Safe only when no
        other thread still drives that engine (the service supervisor
        reuses its warm engine only after joining the dead worker).

        Raises checkpoint.CorruptCheckpointError when the checkpoint
        fails its checksum — supervised services fall back to an older
        snapshot."""
        from repro.checkpoint.io import load_fed_checkpoint
        params, state_dict, history, config, _extra = \
            load_fed_checkpoint(path, telemetry=telemetry)
        state = FedState.from_dict(state_dict)
        cfg = dict(config)
        cfg.update(overrides)
        if engine is None:
            if task is None and loss_fn is not None and state.clients:
                from repro.fed.task import ArrayTask
                task = ArrayTask(loss_fn,
                                 np.asarray(state.clients[0].x).shape[1:])
            engine = RoundEngine(
                task=task, clients=[], local_epochs=cfg["local_epochs"],
                batch_size=cfg["batch_size"], scheme=cfg["scheme"],
                eta0=cfg["eta0"], chunk_size=cfg["chunk_size"],
                agg=cfg["agg"], with_metrics=cfg["with_metrics"],
                # pre-compression checkpoints carry no key: f32 wire
                compression=cfg.get("compression", "none"),
                capacity=cfg["capacity"], max_samples=cfg["max_samples"],
                sharding=sharding, interpret=interpret, donate=donate,
                mode=cfg["engine_mode"], telemetry=telemetry)
        else:
            if engine.capacity != cfg["capacity"]:
                raise ValueError(
                    f"reused engine capacity {engine.capacity} != "
                    f"checkpoint capacity {cfg['capacity']}")
            if engine.compression.name != cfg.get("compression", "none"):
                raise ValueError(
                    f"reused engine compression "
                    f"{engine.compression.name!r} != checkpoint "
                    f"compression {cfg.get('compression', 'none')!r}")
            for slot in range(engine.capacity):
                engine.evict(slot)
        # re-stage every occupied slot (one fused burst; trace CDFs ride
        # along with each admit)
        engine.admit_many(sorted(
            ((slot, state.clients[i])
             for i, slot in state.slot_of.items()),
            key=lambda sc: sc[0]))
        sch = cls(init_params=jax.tree.map(jnp.asarray, params),
                  engine=engine, state=state, mode=cfg["mode"],
                  eval_fn=eval_fn, evaluate=evaluate,
                  history=history_from_dict(history),
                  injector=injector, log_spans=log_spans,
                  telemetry=telemetry,
                  # the bank rebuilds from the restored clients (its
                  # contents are derivable state, never persisted raw)
                  bank=cfg.get("bank", False),
                  prefetch=cfg.get("prefetch", False))
        return sch


# -- history (de)serialization -------------------------------------------------

def history_to_dict(history: Sequence[RoundRecord]) -> dict:
    """Columnar plain-data form of a RoundRecord list (numpy arrays +
    JSON-able lists) — round-trips exactly through history_from_dict."""
    R = len(history)
    cap = len(history[0].s) if R else 0
    return {
        "tau": np.asarray([h.tau for h in history], np.int64),
        "loss": np.asarray([h.loss for h in history], np.float64),
        "acc": np.asarray([h.acc for h in history], np.float64),
        "eta": np.asarray([h.eta for h in history], np.float64),
        "n_active": np.asarray([h.n_active for h in history], np.int64),
        "s": (np.stack([np.asarray(h.s, np.float32) for h in history])
              if R else np.zeros((0, cap), np.float32)),
        "event": [h.event for h in history],
    }


def history_from_dict(d: Optional[dict]) -> List[RoundRecord]:
    if not d or len(d.get("tau", ())) == 0:
        return []
    return [RoundRecord(int(d["tau"][j]), float(d["loss"][j]),
                        float(d["acc"][j]), float(d["eta"][j]),
                        int(d["n_active"][j]), np.asarray(d["s"][j]),
                        str(d["event"][j]))
            for j in range(len(d["tau"]))]
