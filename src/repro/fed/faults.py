"""Deterministic fault injection for the federation service stack.

The paper's premise is that *devices* fail arbitrarily; this module makes
the *service* fail arbitrarily too — on a seed-reproducible schedule — so
the supervision layer (fed/service.py) can be tested against the same
chaos the algorithm tolerates from clients.

A ``FaultPlan`` is a list of ``Fault`` entries, each bound to an
*injection site* and a 0-based call index at that site.  The hook points
threaded through the stack call ``plan.fire(site, ...)``; the plan either
does nothing (no fault scheduled for that call) or injects the scheduled
failure:

  site ``worker``       — top of each FederationService worker span:
                          ``crash`` raises InjectedFault, ``hang`` stalls
                          the worker (watchdog-visible) until the span
                          timeout or service abort releases it;
  site ``sched_span``   — each span iteration inside StreamScheduler.run:
                          ``crash`` raises *mid-run*, leaving the
                          scheduler torn (history appended, next_tau
                          stale) — the supervisor must discard it;
  site ``ckpt_save``    — inside save_fed_checkpoint, after the payload
                          was staged but before the atomic rename:
                          ``io-error`` raises InjectedWriteError (the
                          canonical checkpoint is never touched);
  site ``ckpt_written`` — after a checkpoint landed on disk: ``corrupt``
                          flips bytes in the npz (silent bitrot, detected
                          by the load-time checksum);
  site ``flood``        — top of each worker span: ``flood`` returns the
                          Fault so the service can push ``size`` stale
                          no-op TraceShifts (ingestion outrunning span
                          boundaries — the event-heap overflow scenario);
  site ``ingest``       — per event moved from the inbox to the
                          scheduler: ``dup`` delivers the event twice,
                          ``delay`` holds it back one ingest cycle
                          (out-of-order delivery).

Every random choice (corruption offsets, flood targets) comes from the
plan's own seeded generator, and fault firing is keyed by deterministic
per-site call counters — rerunning the same workload with the same plan
injects byte-identical chaos, which is what makes chaos failures
replayable (``fed_serve --chaos <seed>``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A deliberately injected crash (FaultPlan kind='crash')."""


class InjectedWriteError(OSError):
    """A deliberately injected checkpoint write failure."""


_KINDS_BY_SITE = {
    "worker": ("crash", "hang"),
    "sched_span": ("crash",),
    "ckpt_save": ("io-error",),
    "ckpt_written": ("corrupt",),
    "flood": ("flood",),
    "ingest": ("dup", "delay"),
}


@dataclass(frozen=True)
class Fault:
    """One scheduled failure: fire ``kind`` on the ``at``-th call (0-based)
    to injection site ``site``.  ``size`` scales flood events / corrupted
    bytes; ``seconds`` is the hang duration."""
    site: str
    at: int
    kind: str
    size: int = 0
    seconds: float = 0.0

    def __post_init__(self):
        if self.site not in _KINDS_BY_SITE:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {sorted(_KINDS_BY_SITE)}")
        if self.kind not in _KINDS_BY_SITE[self.site]:
            raise ValueError(f"kind {self.kind!r} invalid at site "
                             f"{self.site!r} (allowed: "
                             f"{_KINDS_BY_SITE[self.site]})")


@dataclass
class FaultPlan:
    """A deterministic, seed-reproducible schedule of injected failures.

    Thread-safe: per-site call counters are guarded by one lock (hook
    sites run on the service worker thread, corruption helpers may be
    reached from control threads).
    """
    faults: List[Fault] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self._by_site: Dict[Tuple[str, int], Fault] = {}
        for f in self.faults:
            key = (f.site, f.at)
            if key in self._by_site:
                raise ValueError(f"duplicate fault at {key}")
            self._by_site[key] = f
        self._counts: Dict[str, int] = {}
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, int, str]] = []
        from repro.obs.telemetry import NULL
        self._m_fired = NULL.counter("faults_fired_total")

    def attach_telemetry(self, telemetry) -> None:
        """Count fired faults on a live registry
        (``faults_fired_total{site,kind}``); null-safe."""
        from repro.obs.telemetry import resolve
        self._m_fired = resolve(telemetry).counter(
            "faults_fired_total", "injected faults fired, by site and "
            "kind", labelnames=("site", "kind"))

    @classmethod
    def generate(cls, seed: int, *, spans: int = 12, saves: int = 6,
                 hang_seconds: float = 30.0,
                 flood_size: int = 256, hang: bool = True) -> "FaultPlan":
        """A reproducible mixed chaos plan: one worker crash, one mid-span
        crash, one hang, one write failure, one corruption and one flood,
        placed at seeded positions — the ``fed_serve --chaos <seed>``
        profile.

        ``hang=False`` omits the worker hang (recovering a hang costs a
        span-timeout of watchdog latency — the fuzzed-chaos tier-1
        corpus trades that fault for wall-clock).  The rng draw order is
        unchanged, so a seed names the same plan either way."""
        rng = np.random.default_rng(seed)
        worker_slots = rng.choice(max(spans, 4), size=3, replace=False)
        faults = [
            Fault("worker", int(worker_slots[0]), "crash"),
            Fault("worker", int(worker_slots[1]), "hang",
                  seconds=hang_seconds),
            Fault("sched_span", int(worker_slots[2]), "crash"),
            Fault("ckpt_save", int(rng.integers(0, max(saves, 1))),
                  "io-error"),
            Fault("ckpt_written", int(rng.integers(0, max(saves, 1))),
                  "corrupt", size=16),
            Fault("flood", int(rng.integers(0, max(spans, 1))), "flood",
                  size=flood_size),
        ]
        if not hang:
            faults = [f for f in faults if f.kind != "hang"]
        return cls(faults=faults, seed=seed)

    # -- firing ---------------------------------------------------------------
    def _take(self, site: str) -> Optional[Fault]:
        with self._lock:
            k = self._counts.get(site, 0)
            self._counts[site] = k + 1
            f = self._by_site.get((site, k))
            if f is not None:
                self.fired.append((site, k, f.kind))
                self._m_fired.labels(site, f.kind).inc()
            return f

    def fire(self, site: str, *, abort: Optional[threading.Event] = None,
             path: Optional[str] = None, **ctx) -> Optional[Fault]:
        """Consult the plan at an injection site.  Raises for crash/write
        faults, stalls for hangs, corrupts ``path`` for bitrot faults, and
        returns the Fault for caller-interpreted kinds (flood/dup/delay).
        Returns None when nothing is scheduled for this call."""
        f = self._take(site)
        if f is None:
            return None
        if f.kind == "crash":
            raise InjectedFault(f"injected crash at {site}#{f.at}")
        if f.kind == "io-error":
            raise InjectedWriteError(
                f"injected checkpoint write failure at {site}#{f.at}")
        if f.kind == "hang":
            # watchdog-visible stall: wait on the service's abort event so
            # a recovered (or stopping) service releases the stuck worker
            # instead of leaking a sleeping thread
            (abort if abort is not None else threading.Event()).wait(
                f.seconds)
            return f
        if f.kind == "corrupt":
            if path is not None:
                corrupt_file(path, self._rng, nbytes=f.size or 16)
            return f
        return f                            # flood / dup / delay

    def summary(self) -> dict:
        return {"seed": self.seed,
                "scheduled": len(self.faults),
                "fired": [list(t) for t in self.fired]}


def corrupt_file(path: str, rng: np.random.Generator,
                 nbytes: int = 16) -> None:
    """Flip ``nbytes`` bytes at seeded offsets of an on-disk file —
    silent bitrot that only a content checksum can catch."""
    import os
    size = os.path.getsize(path)
    if size == 0:
        return
    offsets = rng.integers(0, size, size=max(1, nbytes))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(int(off))
            b = f.read(1)
            f.seek(int(off))
            f.write(bytes([b[0] ^ 0xFF if b else 0xFF]))
        f.flush()
        os.fsync(f.fileno())


def make_flood(state, size: int, rng: np.random.Generator) -> list:
    """``size`` stale no-op TraceShifts over the currently slotted
    objective members, each restating the client's *current* trace —
    the heap-growth traffic pattern the merge-stale queue policy exists
    to absorb (a retrying edge re-announcing known availability laws)."""
    from repro.fed.events import TraceShift
    targets = sorted(i for i in state.slot_of if i in state.objective)
    if not targets:
        return []
    picks = rng.integers(0, len(targets), size=size)
    return [TraceShift(0, client_id=targets[int(j)],
                       trace=state.clients[targets[int(j)]].trace)
            for j in picks]
