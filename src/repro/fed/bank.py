"""Tiered client store + double-buffered cohort prefetch.

The RoundEngine's capacity slots hold client data *on device* — which
caps the fleet at device memory and makes every arrival a synchronous
host->device stall at a span boundary.  This module upgrades the slots
into a managed hot cache over a host-side tier:

  * ClientBank — the fleet's home: every client's per-sample buffers
    live host-side as pre-padded ``(Nmax, *spec.shape)`` numpy rows
    keyed by client id, optionally spilling least-recently-used entries
    to per-client ``.npz`` files under ``spill_dir`` when a
    ``ram_budget_bytes`` is set.  Registration is idempotent and the
    store is lock-protected, so the staging thread and the scheduler's
    event loop can touch it concurrently.  Fleet size is now bounded by
    host RAM (or disk), not device memory.

  * CohortStager — the double buffer: while span k runs on device, the
    coalesced Arrival/rejoin cohort for the next event boundary is
    gathered from the bank on a staging thread, stacked into one
    pow2-padded buffer and moved with ``jax.device_put``
    (RoundEngine.put_burst).  At the boundary the scheduler pays only a
    fused gather+scatter (RoundEngine.commit_burst) — the transfer
    overlapped compute instead of serializing with it.

Staged cohorts carry *data rows only*: a slot's ``n`` and trace-CDF row
are written synchronously at commit time from the live Client object, so
a TraceShift landing between staging and commit can never publish a
stale availability law.  Cohort rows are keyed by ``id(client)`` — the
stager pins the staged Client objects, and FedState registers arrival
payloads by reference, so the key is stable from prefetch to admit.

Correctness is unchanged by construction: the bytes that reach a slot
are the same pre-padded rows the synchronous path would stage, only
earlier — bank-backed runs are bit-identical to device-resident runs of
the same schedule (tests/test_bank.py pins this on the scenario
library).
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def pad_rows(task, nmax: int, client) -> Dict[str, np.ndarray]:
    """Zero-padded (Nmax, *spec.shape) host rows for every task buffer —
    the exact bytes RoundEngine stages into a slot (shape-checked
    against the task's buffer specs)."""
    if client.n > nmax:
        raise ValueError(
            f"client has {client.n} samples > bank row capacity {nmax}; "
            f"build the engine/bank with max_samples >= {client.n}")
    rows = {}
    for name, arr in task.client_arrays(client).items():
        spec = task.buffers[name]
        if arr.shape != (client.n,) + spec.shape:
            raise ValueError(
                f"feature shape {arr.shape[1:]} != bank feature shape "
                f"{spec.shape} (buffer {name!r})")
        row = np.zeros((nmax,) + spec.shape, spec.dtype)
        row[:client.n] = arr
        rows[name] = row
    return rows


class ClientBank:
    """Host-RAM (optionally disk-spillable) store of pre-padded client
    rows, keyed by client id.

    Every row dict has identical geometry (the engine's buffer specs
    padded to Nmax), so memory accounting is exact: ``row_nbytes`` per
    resident client.  With ``ram_budget_bytes`` set (requires
    ``spill_dir``), least-recently-used entries spill to per-client
    ``client-<id>.npz`` files and transparently reload on access.
    """

    def __init__(self, task, nmax: int, *,
                 spill_dir: Optional[str] = None,
                 ram_budget_bytes: Optional[int] = None):
        self.task = task
        self.nmax = nmax
        self.spill_dir = spill_dir
        if ram_budget_bytes is not None and spill_dir is None:
            raise ValueError("ram_budget_bytes needs spill_dir= to have "
                             "somewhere to evict to")
        self.ram_budget_bytes = ram_budget_bytes
        self.row_nbytes = sum(
            int(np.prod((nmax,) + spec.shape)) * np.dtype(spec.dtype).itemsize
            for spec in task.buffers.values())
        self._resident: "OrderedDict[int, Dict[str, np.ndarray]]" = \
            OrderedDict()
        self._spilled: Dict[int, str] = {}
        self._lock = threading.RLock()
        self.puts = 0
        self.loads = 0
        self.spills = 0

    def __contains__(self, cid: int) -> bool:
        with self._lock:
            return cid in self._resident or cid in self._spilled

    def __len__(self) -> int:
        with self._lock:
            return len(self._resident) + len(self._spilled)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return len(self._resident) * self.row_nbytes

    def put(self, cid: int, client,
            rows: Optional[Dict[str, np.ndarray]] = None) -> bool:
        """Register a client's rows (idempotent — an id already banked is
        a cheap no-op).  ``rows=`` accepts pre-padded rows (e.g. a staged
        cohort's host stack) to skip re-padding."""
        with self._lock:
            if cid in self._resident:
                self._resident.move_to_end(cid)
                return False
            if cid in self._spilled:
                return False
            if rows is None:
                rows = pad_rows(self.task, self.nmax, client)
            self._resident[cid] = rows
            self.puts += 1
            self._enforce_budget(keep=cid)
            return True

    def rows(self, cid: int) -> Dict[str, np.ndarray]:
        """The client's pre-padded rows, reloading from spill if needed
        (marks the entry most-recently-used)."""
        with self._lock:
            if cid in self._resident:
                self._resident.move_to_end(cid)
                return self._resident[cid]
            path = self._spilled.get(cid)
            if path is None:
                raise KeyError(f"client {cid} not in bank")
            with np.load(path) as z:
                rows = {name: z[name] for name in z.files}
            del self._spilled[cid]
            self._resident[cid] = rows
            self.loads += 1
            self._enforce_budget(keep=cid)
            return rows

    def drop(self, cid: int) -> None:
        with self._lock:
            self._resident.pop(cid, None)
            path = self._spilled.pop(cid, None)
        if path is not None:
            try:
                os.unlink(path)
            except OSError:
                pass

    def _enforce_budget(self, keep: Optional[int] = None) -> None:
        # caller holds the lock
        if self.ram_budget_bytes is None:
            return
        while (len(self._resident) * self.row_nbytes > self.ram_budget_bytes
               and len(self._resident) > 1):
            cid = next(iter(self._resident))
            if cid == keep:
                # the entry being protected is LRU-first (fresh put into
                # an over-budget bank): spill the next-oldest instead
                cids = iter(self._resident)
                next(cids)
                try:
                    cid = next(cids)
                except StopIteration:
                    return
            self._spill_one(cid)

    def _spill_one(self, cid: int) -> None:
        rows = self._resident.pop(cid)
        os.makedirs(self.spill_dir, exist_ok=True)
        path = os.path.join(self.spill_dir, f"client-{cid:08d}.npz")
        np.savez(path, **rows)
        self._spilled[cid] = path
        self.spills += 1

    def stats(self) -> dict:
        with self._lock:
            return {"clients": len(self._resident) + len(self._spilled),
                    "resident": len(self._resident),
                    "spilled": len(self._spilled),
                    "resident_bytes": len(self._resident) * self.row_nbytes,
                    "row_nbytes": self.row_nbytes,
                    "puts": self.puts, "loads": self.loads,
                    "spills": self.spills}


@dataclass
class StagedCohort:
    """One prefetched arrival cohort: pow2-padded device stacks plus the
    row index of each staged client (keyed by ``id(client)`` — the
    ``clients`` list pins those ids for the cohort's lifetime).
    ``rows`` keeps the per-client HOST rows so the boundary can bank a
    fresh arrival without re-padding it on the span loop's thread."""
    clients: List
    index: Dict[int, int]
    dev: Dict[str, "object"]
    rows: List[Dict[str, np.ndarray]]
    k: int
    stage_seconds: float


class CohortStager:
    """Stages upcoming arrival cohorts on a background worker thread.

    ``submit()`` hands the cohort to a persistent daemon worker that
    gathers rows (from the bank when the client is registered, padding
    fresh payloads otherwise), stacks them pow2-padded, and ships them
    with RoundEngine.put_burst — all while the current span computes.
    ``collect()`` waits for the staging to finish (recording how long
    the boundary actually waited) and hands the cohort to the scheduler
    exactly once.  A new submit supersedes an uncollected one.  Staging
    errors are swallowed into ``stage_errors`` and surface as an
    ordinary prefetch miss — the synchronous admit path remains the
    fallback for correctness.

    The worker exits after ``IDLE_TIMEOUT_S`` without work and is
    respawned on the next submit, so schedulers that are built in bulk
    and abandoned without ``close()`` (fuzz corpora) don't accumulate
    parked threads, while a hot span loop never pays thread spawn at a
    boundary.
    """

    IDLE_TIMEOUT_S = 5.0

    def __init__(self, engine, bank: Optional[ClientBank] = None):
        self._engine = engine
        self._bank = bank
        self._cv = threading.Condition()
        self._work: Optional[Tuple[list, dict]] = None   # (items, box)
        self._pending: Optional[dict] = None             # box
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self.cohorts_staged = 0
        self.rows_staged = 0
        self.stage_seconds_total = 0.0
        self.wait_seconds_total = 0.0
        self.superseded = 0
        self.stage_errors = 0

    def submit(self, items: Sequence[Tuple[Optional[int], object]]) -> None:
        """items: (client_id or None, Client) pairs — ids register into
        the bank on the staging thread; fresh payloads (unregistered
        arrivals) are padded directly."""
        items = list(items)
        if not items:
            return
        box: dict = {"cohort": None, "err": None,
                     "done": threading.Event()}
        with self._cv:
            if self._pending is not None:
                # superseded: the event set for the boundary changed
                self._pending = None
                self.superseded += 1
            self._work = (items, box)
            self._pending = box
            self._closed = False
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._worker_loop, name="fed-cohort-stager",
                    daemon=True)
                self._worker.start()
            self._cv.notify_all()

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                deadline = time.monotonic() + self.IDLE_TIMEOUT_S
                while self._work is None and not self._closed:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0.0 or not self._cv.wait(remaining):
                        if self._work is None:
                            return        # idle timeout: park
                if self._work is None:    # closed with nothing queued
                    return
                work, self._work = self._work, None
            self._stage(*work)

    def _stage(self, items, box) -> None:
        import jax

        try:
            t0 = time.perf_counter()
            clients, rows_list = [], []
            for cid, c in items:
                if self._bank is not None and cid is not None:
                    self._bank.put(cid, c)
                    rows_list.append(self._bank.rows(cid))
                else:
                    rows_list.append(pad_rows(self._engine.task,
                                              self._engine.nmax, c))
                clients.append(c)
            k = len(clients)
            kp = _pow2(k)
            stacks = {
                name: np.stack([r[name] for r in rows_list]
                               + [rows_list[-1][name]] * (kp - k))
                for name in self._engine.task.buffers}
            dev = self._engine.put_burst(stacks)
            # force the transfers here, on the staging thread — the whole
            # point is that collect() at the boundary finds them done
            jax.block_until_ready(list(dev.values()))
            box["cohort"] = StagedCohort(
                clients=clients,
                index={id(c): j for j, c in enumerate(clients)},
                dev=dev, rows=rows_list, k=k,
                stage_seconds=time.perf_counter() - t0)
        except Exception as e:        # pragma: no cover - defensive
            box["err"] = e
        finally:
            box["done"].set()

    def collect(self) -> Optional[StagedCohort]:
        """The staged cohort for this boundary, or None (nothing
        submitted / staging failed).  Consumes the cohort."""
        with self._cv:
            box, self._pending = self._pending, None
        if box is None:
            return None
        t0 = time.perf_counter()
        box["done"].wait()
        self.wait_seconds_total += time.perf_counter() - t0
        if box["err"] is not None:
            self.stage_errors += 1
            return None
        cohort = box["cohort"]
        self.cohorts_staged += 1
        self.rows_staged += cohort.k
        self.stage_seconds_total += cohort.stage_seconds
        return cohort

    def close(self) -> None:
        """Drop any in-flight staging work and retire the worker (so no
        stray device_put outlives the scheduler).  Idempotent; a later
        submit() simply respawns the worker."""
        with self._cv:
            box, self._pending = self._pending, None
            work, self._work = self._work, None
            self._closed = True
            worker, self._worker = self._worker, None
            self._cv.notify_all()
        if work is not None:
            work[1]["done"].set()         # never picked up: unblock waiters
        if box is not None:
            box["done"].wait()
        if worker is not None and worker.is_alive():
            worker.join(timeout=self.IDLE_TIMEOUT_S + 1.0)

    def overlap_fraction(self) -> float:
        """Fraction of staging wall time hidden behind span compute:
        1 - wait/stage (1.0 = boundaries never waited)."""
        if self.stage_seconds_total <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.wait_seconds_total
                   / self.stage_seconds_total)

    def stats(self) -> dict:
        return {"cohorts_staged": self.cohorts_staged,
                "rows_staged": self.rows_staged,
                "stage_seconds_total": self.stage_seconds_total,
                "wait_seconds_total": self.wait_seconds_total,
                "overlap_fraction": self.overlap_fraction(),
                "superseded": self.superseded,
                "stage_errors": self.stage_errors}


def _pow2(k: int) -> int:
    return 1 << (k - 1).bit_length() if k > 1 else 1
