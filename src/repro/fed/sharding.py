"""Sharding specs for the federation (client) axis.

The paper's aggregation schemes only become interesting at scale — the
non-IID effects of inactivity and incomplete updates assume federations of
hundreds to thousands of devices — so the engine's capacity-slotted client
buffers (``data_x (C, Nmax, …)``, ``data_y``, ``n``, ``s_cdf``) carry a
``'data'``-sharded leading axis: each mesh device owns ``C / n_shards``
client slots, per-client local epochs run fully in parallel across
devices, and the per-round delta reduction ends in a cross-device
all-reduce that leaves the global params replicated (no host round-trip).

This module is the single place the slot-buffer layout is decided:

  * :class:`FedSharding` — an immutable spec (mesh + federation axis name)
    with helpers to place (``put_client`` / ``put_replicated``) and
    constrain (``constrain_client`` / ``constrain_replicated``) arrays;
  * :func:`make_fed_sharding` — build a spec over a 1-D ``'data'`` mesh of
    local devices (``launch/mesh.make_data_mesh``), or over any existing
    mesh that has a ``'data'`` axis (e.g. the production
    ``launch/mesh.make_production_mesh``).

Slot ownership invariant: capacity is always padded to a multiple of the
shard count (``pad_capacity``), so every shard owns the same number of
whole slots and a slot mutation (``RoundEngine.admit/evict/set_trace``)
stays one replicated-row ``device_put`` plus a dynamic-update-slice that
XLA lowers to a masked, shard-local write — membership churn never moves
data between shards and never recompiles the span scans.

Usage::

    from repro.fed.sharding import make_fed_sharding
    fs = make_fed_sharding()            # 1-D 'data' mesh over all devices
    eng = RoundEngine(..., sharding=fs) # client axis sharded over the mesh
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class FedSharding:
    """Where the federation's client axis lives on the mesh.

    mesh: any jax Mesh with an axis named ``axis`` (default ``'data'``);
    the client/slot axis of every engine buffer is sharded over it, and
    everything else (params, scalars) is replicated.
    """
    mesh: Mesh
    axis: str = "data"

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"mesh has no {self.axis!r} axis (axes: "
                f"{self.mesh.axis_names}); the federation axis must name "
                f"an existing mesh axis")

    # -- geometry -------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def pad_capacity(self, capacity: int) -> int:
        """Round capacity up so every shard owns the same number of whole
        slots (padded slots behave exactly like empty capacity slots)."""
        n = self.n_shards
        return -(-capacity // n) * n

    # -- specs ----------------------------------------------------------------
    def client_spec(self, ndim: int, axis_dim: int = 0) -> P:
        """PartitionSpec sharding dimension ``axis_dim`` over the
        federation axis (the leading slot axis of engine buffers; plan
        arrays carry the client axis at dim 1)."""
        spec = [None] * ndim
        spec[axis_dim] = self.axis
        return P(*spec)

    def client(self, ndim: int, axis_dim: int = 0) -> NamedSharding:
        return NamedSharding(self.mesh, self.client_spec(ndim, axis_dim))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- placement (host -> device, commits the layout) -----------------------
    def put_client(self, x, axis_dim: int = 0):
        return jax.device_put(x, self.client(np.ndim(x), axis_dim))

    def put_replicated(self, tree):
        repl = self.replicated()
        return jax.tree.map(lambda l: jax.device_put(l, repl), tree)

    # -- constraints (inside jit, steer GSPMD) --------------------------------
    def constrain_client(self, x, axis_dim: int = 0):
        return jax.lax.with_sharding_constraint(
            x, self.client(x.ndim, axis_dim))

    def constrain_client_tree(self, tree, axis_dim: int = 0):
        return jax.tree.map(
            lambda l: self.constrain_client(l, axis_dim), tree)

    def constrain_replicated(self, tree):
        repl = self.replicated()
        return jax.tree.map(
            lambda l: jax.lax.with_sharding_constraint(l, repl), tree)


def make_fed_sharding(n_devices: Optional[int] = None, *,
                      mesh: Optional[Mesh] = None,
                      axis: str = "data") -> FedSharding:
    """FedSharding over a fresh 1-D ``'data'`` mesh of local devices
    (n_devices=None uses all of them), or over an existing ``mesh`` that
    already has the federation axis."""
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(n_devices)
    return FedSharding(mesh=mesh, axis=axis)
