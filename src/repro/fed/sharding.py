"""Sharding specs for the federation (client) axis.

The paper's aggregation schemes only become interesting at scale — the
non-IID effects of inactivity and incomplete updates assume federations of
hundreds to thousands of devices — so the engine's capacity-slotted client
buffers (``data buffers (C, Nmax, …)``, ``n``, ``s_cdf``) carry a
federation-sharded leading axis: each mesh device owns ``C / n_shards``
client slots, per-client local epochs run fully in parallel across
devices, and the per-round delta reduction ends in a cross-device
all-reduce over the federation axes.

This module is the single place the slot-buffer layout is decided:

  * :class:`FedSharding` — an immutable spec (mesh + federation axis
    name(s)) with helpers to place (``put_client`` / ``put_replicated``)
    and constrain (``constrain_client`` / ``constrain_replicated``)
    arrays;
  * :func:`make_fed_sharding` — build a spec over a 1-D ``'data'`` mesh of
    local devices (``launch/mesh.make_data_mesh``), or over any existing
    mesh that has the federation axes (e.g. the production
    ``launch/mesh.make_production_mesh``).

Composite federation axes: ``axis`` may be a single mesh-axis name
(``'data'``) or a tuple (``('pod', 'data')``) for multi-pod federations —
the client axis then shards over the *product* of those axes
(``P(('pod', 'data'))``) and every cross-device reduction psums over
exactly that set.  Axes of the mesh **not** named (e.g. ``'model'``) are
left alone: params may stay sharded over them per the model's partition
specs (FSDP x TP, ``models/sharding.py``), which is how one mesh carries
both the federation and the large-model layout — see docs/scaling.md.

Slot ownership invariant: capacity is always padded to a multiple of the
shard count (``pad_capacity``), so every shard owns the same number of
whole slots and a slot mutation (``RoundEngine.admit/evict/set_trace``)
stays one replicated-row ``device_put`` plus a dynamic-update-slice that
XLA lowers to a masked, shard-local write — membership churn never moves
data between shards and never recompiles the span scans.

Usage::

    from repro.fed.sharding import make_fed_sharding
    fs = make_fed_sharding()            # 1-D 'data' mesh over all devices
    eng = RoundEngine(..., sharding=fs) # client axis sharded over the mesh

    # multi-pod federation: clients shard over pod x data
    fs = make_fed_sharding(mesh=pod_mesh, axis=("pod", "data"))
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class FedSharding:
    """Where the federation's client axis lives on the mesh.

    mesh: any jax Mesh with the axis (or axes) named by ``axis`` (default
    ``'data'``; a tuple such as ``('pod', 'data')`` declares a composite
    federation axis).  The client/slot axis of every engine buffer is
    sharded over the named axes; scalars and small-model params are
    replicated, while large-model params may stay sharded over the mesh's
    remaining (e.g. ``'model'``) axes via per-leaf PartitionSpecs.
    """
    mesh: Mesh
    axis: Union[str, Tuple[str, ...]] = "data"

    def __post_init__(self):
        for a in self.axes:
            if a not in self.mesh.axis_names:
                raise ValueError(
                    f"mesh has no {a!r} axis (axes: "
                    f"{self.mesh.axis_names}); every federation axis must "
                    f"name an existing mesh axis")

    # -- geometry -------------------------------------------------------------
    @property
    def axes(self) -> Tuple[str, ...]:
        """The federation axis names as a tuple (composite-safe form)."""
        return (self.axis,) if isinstance(self.axis, str) else \
            tuple(self.axis)

    @property
    def n_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    def pad_capacity(self, capacity: int) -> int:
        """Round capacity up so every shard owns the same number of whole
        slots (padded slots behave exactly like empty capacity slots)."""
        n = self.n_shards
        return -(-capacity // n) * n

    # -- specs ----------------------------------------------------------------
    def _entry(self):
        """The PartitionSpec entry for the client dim: the bare name for a
        single axis, the tuple for a composite one."""
        return self.axis if isinstance(self.axis, str) else tuple(self.axis)

    def client_spec(self, ndim: int, axis_dim: int = 0) -> P:
        """PartitionSpec sharding dimension ``axis_dim`` over the
        federation axis/axes (the leading slot axis of engine buffers;
        plan arrays carry the client axis at dim 1)."""
        spec = [None] * ndim
        spec[axis_dim] = self._entry()
        return P(*spec)

    def client(self, ndim: int, axis_dim: int = 0) -> NamedSharding:
        return NamedSharding(self.mesh, self.client_spec(ndim, axis_dim))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def param_sharding(self, spec: Optional[P]) -> NamedSharding:
        """NamedSharding for a parameter leaf: ``spec=None`` replicates
        (the small-model path); a PartitionSpec from the model's rule
        table (``models.sharding.tree_param_specs``) keeps the leaf
        sharded over the mesh's model/FSDP axes.  Axis names absent from
        the mesh are dropped, so one spec serves every mesh shape."""
        if spec is None:
            return self.replicated()
        from repro.models.sharding import _filter_spec
        return NamedSharding(self.mesh, _filter_spec(spec, self.mesh))

    # -- placement (host -> device, commits the layout) -----------------------
    def put_client(self, x, axis_dim: int = 0):
        return jax.device_put(x, self.client(np.ndim(x), axis_dim))

    def put_replicated(self, tree):
        repl = self.replicated()
        return jax.tree.map(lambda l: jax.device_put(l, repl), tree)

    def put_params(self, tree, specs=None):
        """Place a parameter pytree: replicated when ``specs`` is None,
        else per-leaf model-spec shardings (the large-model path)."""
        if specs is None:
            return self.put_replicated(tree)
        return jax.tree.map(
            lambda l, s: jax.device_put(l, self.param_sharding(s)),
            tree, specs)

    # -- constraints (inside jit, steer GSPMD) --------------------------------
    def constrain_client(self, x, axis_dim: int = 0):
        return jax.lax.with_sharding_constraint(
            x, self.client(x.ndim, axis_dim))

    def constrain_client_tree(self, tree, axis_dim: int = 0):
        return jax.tree.map(
            lambda l: self.constrain_client(l, axis_dim), tree)

    def constrain_compressed(self, payload, scales):
        """Constrain a compressed client-delta pair (int8 payload
        (C, Dp) + per-chunk f32 scales (C, Dp/chunk)) so each shard owns
        its own clients' compressed bytes — the quantized local
        dequant-and-reduce launch then runs shard-local and only the f32
        (D,) partial crosses devices in the psum epilogue."""
        return (self.constrain_client(payload),
                self.constrain_client(scales))

    def constrain_replicated(self, tree):
        repl = self.replicated()
        return jax.tree.map(
            lambda l: jax.lax.with_sharding_constraint(l, repl), tree)

    def constrain_params(self, tree, specs=None):
        """Constrain a parameter pytree to its model specs (or replicated
        when ``specs`` is None) — the in-jit counterpart of put_params."""
        if specs is None:
            return self.constrain_replicated(tree)
        return jax.tree.map(
            lambda l, s: jax.lax.with_sharding_constraint(
                l, self.param_sharding(s)), tree, specs)


def make_fed_sharding(n_devices: Optional[int] = None, *,
                      mesh: Optional[Mesh] = None,
                      axis: Union[str, Tuple[str, ...]] = "data"
                      ) -> FedSharding:
    """FedSharding over a fresh 1-D ``'data'`` mesh of local devices
    (n_devices=None uses all of them), or over an existing ``mesh`` that
    already has the federation axis/axes (pass ``axis=('pod', 'data')``
    for a composite multi-pod federation)."""
    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(n_devices)
    return FedSharding(mesh=mesh, axis=axis)
