from repro.fed.driver import Client, FederatedTrainer, RoundRecord
from repro.fed.engine import RoundEngine
from repro.fed.events import (Arrival, Departure, InactivityBurst,
                              ParticipationEvent, TraceShift)
from repro.fed.faults import (Fault, FaultPlan, InjectedFault,
                              InjectedWriteError)
from repro.fed.fuzz import (FuzzHarness, InvariantViolation, generate_case,
                            run_corpus, run_fuzz_case)
from repro.fed.service import FederationService
from repro.fed.sharding import FedSharding, make_fed_sharding
from repro.fed.state import FedState
from repro.fed.stream import StreamScheduler
from repro.fed.task import ArrayTask, ClientTask, LMTask

__all__ = ["Client", "FederatedTrainer", "RoundRecord", "RoundEngine",
           "Arrival", "Departure", "InactivityBurst", "ParticipationEvent",
           "StreamScheduler", "TraceShift", "FedSharding",
           "make_fed_sharding", "ArrayTask", "ClientTask", "LMTask",
           "FedState", "FederationService", "Fault", "FaultPlan",
           "InjectedFault", "InjectedWriteError", "FuzzHarness",
           "InvariantViolation", "generate_case", "run_corpus",
           "run_fuzz_case"]
