from repro.fed.driver import Client, FederatedTrainer, RoundRecord
from repro.fed.engine import RoundEngine
from repro.fed.sharding import FedSharding, make_fed_sharding
from repro.fed.stream import (Arrival, Departure, InactivityBurst,
                              ParticipationEvent, StreamScheduler,
                              TraceShift)
from repro.fed.task import ArrayTask, ClientTask, LMTask

__all__ = ["Client", "FederatedTrainer", "RoundRecord", "RoundEngine",
           "Arrival", "Departure", "InactivityBurst", "ParticipationEvent",
           "StreamScheduler", "TraceShift", "FedSharding",
           "make_fed_sharding", "ArrayTask", "ClientTask", "LMTask"]
