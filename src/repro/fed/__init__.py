from repro.core.compression import CompressionSpec, resolve_compression
from repro.fed.driver import Client, FederatedTrainer, RoundRecord
from repro.fed.engine import RoundEngine
from repro.fed.events import (Arrival, Departure, InactivityBurst,
                              ParticipationEvent, TraceShift)
from repro.fed.faults import (Fault, FaultPlan, InjectedFault,
                              InjectedWriteError)
from repro.fed.fuzz import (FuzzHarness, InvariantViolation, generate_case,
                            make_backend_pool, run_backend_matrix,
                            run_chaos_case, run_chaos_corpus, run_corpus,
                            run_cross_backend_case, run_fuzz_case)
from repro.fed.service import FederationService
from repro.fed.sharding import FedSharding, make_fed_sharding
from repro.fed.state import FedState
from repro.fed.stream import StreamScheduler
from repro.fed.task import ArrayTask, ClientTask, LMTask
from repro.fed.validate import (QuadraticProblem, QuadraticRunner, RunDump,
                                TheoryValidator, generate_participation_schedule,
                                make_quadratic_problem, validate_corpus)

__all__ = ["CompressionSpec", "resolve_compression",
           "Client", "FederatedTrainer", "RoundRecord", "RoundEngine",
           "Arrival", "Departure", "InactivityBurst", "ParticipationEvent",
           "StreamScheduler", "TraceShift", "FedSharding",
           "make_fed_sharding", "ArrayTask", "ClientTask", "LMTask",
           "FedState", "FederationService", "Fault", "FaultPlan",
           "InjectedFault", "InjectedWriteError", "FuzzHarness",
           "InvariantViolation", "generate_case", "run_corpus",
           "run_fuzz_case", "make_backend_pool", "run_backend_matrix",
           "run_cross_backend_case", "run_chaos_case", "run_chaos_corpus",
           "QuadraticProblem", "QuadraticRunner", "RunDump",
           "TheoryValidator", "generate_participation_schedule",
           "make_quadratic_problem", "validate_corpus"]
