from repro.fed.driver import Client, FederatedTrainer

__all__ = ["Client", "FederatedTrainer"]
