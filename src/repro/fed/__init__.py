from repro.fed.driver import Client, FederatedTrainer, RoundRecord
from repro.fed.engine import RoundEngine

__all__ = ["Client", "FederatedTrainer", "RoundRecord", "RoundEngine"]
