"""Shared model building blocks: norms, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, scale, eps=1e-5):
    """RMSNorm with a hand-written VJP (§Perf): the automatic backward
    materialises several full-residual float32 intermediates per layer
    (the dominant HBM-traffic term of the train shapes); this VJP keeps
    the saved residuals and the returned cotangent in the model dtype,
    doing float32 math only inside the fused reductions."""
    return _rmsnorm_fwd(x, scale, eps)[0]


def _rmsnorm_fwd(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    y = (x32 * rstd * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    return y, (x, scale, rstd)


def _rmsnorm_bwd(eps, res, dy):
    x, scale, rstd = res
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    xhat = x32 * rstd
    g = dy32 * (1.0 + scale.astype(jnp.float32))
    dscale = jnp.sum(dy32 * xhat,
                     axis=tuple(range(dy.ndim - 1))).astype(scale.dtype)
    dx = rstd * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dscale


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, p, cfg):
    """p is {"scale": ...} or {"scale": ..., "bias": ...}."""
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"], cfg.norm_eps)
    return layernorm(x, p["scale"], p.get("bias"), cfg.norm_eps)


def activation_fn(name):
    if name == "silu":
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def mlp_apply(p, x, cfg):
    """Dense FFN: gated (SwiGLU/GeGLU) or plain 2-matmul."""
    act = activation_fn(cfg.activation)
    if cfg.gated_mlp:
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = act(g) * u
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = act(h)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


def safe_concat(parts, axis: int):
    """Concatenate via dynamic-update-slices instead of a concatenate op.

    The GSPMD partitioner on this jax/XLA version miscompiles
    ``concatenate`` when the operands carry different shardings and the
    concatenated dim's shard boundary does not align with the piece
    boundaries (observed: a 'model'-sharded (…, 512) next to replicated
    (…, 16) pieces returns wrong *values*, max abs err ~4.5 on unit-scale
    inputs).  Writing each piece into a zeros buffer with
    dynamic_update_slice partitions correctly, and XLA fuses it back into
    a copy — same cost, correct data movement."""
    axis = axis % parts[0].ndim
    total = sum(p.shape[axis] for p in parts)
    shape = list(parts[0].shape)
    shape[axis] = total
    out = jnp.zeros(shape, parts[0].dtype)
    off = 0
    for p in parts:
        out = jax.lax.dynamic_update_slice_in_dim(
            out, p.astype(out.dtype), off, axis)
        off += p.shape[axis]
    return out


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale=0.02, fan_in_axis=None):
    if fan_in_axis is not None:
        fan_in = shape[fan_in_axis]
        scale = 1.0 / jnp.sqrt(fan_in)
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def norm_init(shape_d, dtype, with_bias):
    p = {"scale": jnp.zeros((shape_d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((shape_d,), dtype)
    return p
