"""Multi-head Latent Attention (DeepSeek V2/V3, arXiv:2405.04434).

Train/prefill run the decompressed path (materialise per-head k,v from the
compressed latent).  Decode runs the *absorbed* path: queries are projected
into the kv_lora latent space and attention runs directly against the
compressed cache — the cache holds only (kv_lora + qk_rope) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import NEG_INF, causal_attention, _mask_bias
from repro.models.common import rmsnorm, safe_concat
from repro.models.rotary import apply_rope
from repro.models.sharding import BATCH, constrain


def _project_q(p, x, cfg, positions):
    B, S = x.shape[0], x.shape[1]
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = x @ p["w_dq"]
        cq = rmsnorm(cq, p["q_ln"]["scale"], cfg.norm_eps)
        q = (cq @ p["w_uq"]).reshape(B, S, cfg.n_heads, qk)
    else:
        q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, qk)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, x, cfg, positions):
    ckv_full = x @ p["w_dkv"]                     # (B,S,kv_lora+rope)
    c_kv = rmsnorm(ckv_full[..., : cfg.kv_lora_rank], p["kv_ln"]["scale"],
                   cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:]     # shared single rope head
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(p, x, cfg, positions, cache=None, decode=False):
    """Returns (out, updated_cache_or_None).

    cache (per layer): {"ckv": (B,Slots,kv_lora), "krope": (B,Slots,rope),
                        "pos_map": (Slots,)}.
    """
    B, S = x.shape[0], x.shape[1]
    H = cfg.n_heads
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _compress_kv(p, x, cfg, positions)
    scale = 1.0 / jnp.sqrt(float(cfg.qk_nope_dim + cfg.qk_rope_dim))
    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim)
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)

    if decode:
        assert cache is not None
        slots = cache["ckv"].shape[1]
        pos = positions[0]
        slot = (pos % slots).astype(jnp.int32)
        ckv_c = cache["ckv"].at[:, slot].set(c_kv[:, 0])
        kr_c = cache["krope"].at[:, slot].set(k_rope[:, 0])
        pos_map = cache["pos_map"].at[slot].set(pos.astype(jnp.int32))
        # absorbed path: q into latent space
        q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)
        s = (jnp.einsum("bthl,bsl->bhts", q_abs, ckv_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bthr,bsr->bhts", q_rope, kr_c,
                          preferred_element_type=jnp.float32)) * scale
        valid = (pos_map >= 0) & (pos_map <= pos)
        s = s + _mask_bias(valid)[None, None, None, :]
        w = jax.nn.softmax(s, axis=-1).astype(ckv_c.dtype)
        ctx = jnp.einsum("bhts,bsl->bthl", w, ckv_c)
        o = jnp.einsum("bthl,lhv->bthv", ctx, w_uv)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos_map": pos_map}
    else:
        # decompressed path
        k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, cfg.qk_nope_dim)
        v = (c_kv @ p["w_uv"]).reshape(B, S, H, cfg.v_head_dim)
        # safe_concat: k_nope/q_nope are 'model'-sharded on the head dim
        # while the rope pieces come off replicated projections — the
        # mixed-sharding concatenate GSPMD miscompiles (same pattern as
        # the SSD xBC fix; see models/common.safe_concat)
        k = safe_concat(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, k_rope.shape[-1]))],
            axis=-1)
        q = safe_concat([q_nope, q_rope], axis=-1)
        q = constrain(q, P(BATCH, None, "model", None))
        k = constrain(k, P(BATCH, None, "model", None))
        v = constrain(v, P(BATCH, None, "model", None))
        o = causal_attention(q, k, v, remat_chunks=cfg.remat_attention)
        new_cache = None
        if cache is not None:  # prefill
            write_slots = positions.astype(jnp.int32)
            ckv_c = cache["ckv"].at[:, write_slots].set(c_kv)
            kr_c = cache["krope"].at[:, write_slots].set(k_rope)
            pm = cache["pos_map"].at[write_slots].set(
                positions.astype(jnp.int32))
            new_cache = {"ckv": ckv_c, "krope": kr_c, "pos_map": pm}
    out = o.reshape(B, o.shape[1], H * cfg.v_head_dim) @ p["wo"]
    return out, new_cache
