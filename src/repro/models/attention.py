"""GQA attention: chunked-causal train/prefill path + cached decode path.

Sliding-window archs use a ring-buffer cache of `window` slots so the
long_500k decode shape carries O(window), not O(seq), state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.rotary import apply_rope
from repro.models.sharding import BATCH, constrain

NEG_INF = -1e30


def _mask_bias(valid):
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core: chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------


def causal_attention(q, k, v, *, window: int = 0, q_offset=0,
                     chunk: int = 512, remat_chunks: bool = False):
    """q: (B,S,H,hd)  k,v: (B,S,KV,hd)  ->  (B,S,H,hd).

    Scans over query chunks; each chunk attends to the full key range under
    a causal (+ optional sliding-window) mask.  FLOPs are ~2x the causal
    optimum (future blocks are masked, not skipped) — the Pallas
    flash_attention kernel is the optimized TPU path.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    c = min(chunk, S)
    while S % c:
        c //= 2
    nc = S // c
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qr = q.reshape(B, nc, c, KV, g, hd)
    qr = jnp.moveaxis(qr, 1, 0)  # (nc, B, c, KV, g, hd)
    kpos = jnp.arange(S)

    def body(carry, inp):
        i, q_chunk = inp
        qpos = q_offset + i * c + jnp.arange(c)
        s = jnp.einsum("bckgd,bskd->bkgcs", q_chunk, k,
                       preferred_element_type=jnp.float32) * scale
        valid = kpos[None, :] <= qpos[:, None]
        if window:
            valid &= kpos[None, :] > qpos[:, None] - window
        s = s + _mask_bias(valid)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgcs,bskd->bckgd", w, v)
        return carry, o

    if remat_chunks:
        # §Perf: do not save per-chunk (c, S) softmax probs for backward —
        # recompute them.  Cuts the dominant HBM-traffic term of the train
        # shapes at ~+30% attention FLOPs.
        body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (jnp.arange(nc), qr))
    # note: v head dim may differ from q/k head dim (MLA)
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, v.shape[-1])
    return outs


# ---------------------------------------------------------------------------
# Core: single-token decode against a (ring-buffer) cache
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, pos_map, pos, *, window: int = 0):
    """q: (B,1,H,hd); caches: (B,Slots,KV,hd); pos_map: (Slots,) absolute
    position held by each slot (-1 = empty)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    g = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bckgd,bskd->bkgcs",
                   q.reshape(B, 1, KV, g, hd), k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (pos_map >= 0) & (pos_map <= pos)
    if window:
        valid &= pos_map > pos - window
    s = s + _mask_bias(valid)[None, None, None, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgcs,bskd->bckgd", w, v_cache)
    return o.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# Projected GQA layer
# ---------------------------------------------------------------------------


def gqa_project_qkv(p, x, cfg, positions):
    """Projections are stored flattened (d, H*hd); reshape to heads here."""
    B, S = x.shape[0], x.shape[1]
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, P(BATCH, None, "model", None))
    k = constrain(k, P(BATCH, None, "model", None))
    v = constrain(v, P(BATCH, None, "model", None))
    return q, k, v


def _expand_kv(k, v, cfg):
    """§Perf: repeat kv heads to H so q/k/v/probs all shard on one head
    axis (no grouped-dim resharding per chunk).  Mathematically identical
    to grouped attention; AD sums replica grads onto the kv projections."""
    rep = cfg.n_heads // cfg.n_kv_heads
    k = constrain(jnp.repeat(k, rep, axis=2), P(BATCH, None, "model", None))
    v = constrain(jnp.repeat(v, rep, axis=2), P(BATCH, None, "model", None))
    return k, v


def gqa_attention(p, x, cfg, positions, cache=None, decode=False):
    """Full GQA block.  Returns (out, updated_cache_or_None).

    positions: (S,) int32 absolute positions of the rows of x (decode: (1,)).
    cache (per layer): {"k": (B,Slots,KV,hd), "v": ..., "pos_map": (Slots,)}.
    """
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    B = q.shape[0]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    # caches store the kv dim flattened (KV*hd) so argument shardings stay
    # divisible by the 16-way model axis even for small kv-head counts
    unflat = lambda c: c.reshape(B, c.shape[1], KV, hd)
    if decode:
        assert cache is not None
        slots = cache["k"].shape[1]
        pos = positions[0]
        slot = (pos % slots).astype(jnp.int32)
        k_cache = cache["k"].at[:, slot].set(k[:, 0].reshape(B, KV * hd))
        v_cache = cache["v"].at[:, slot].set(v[:, 0].reshape(B, KV * hd))
        pos_map = cache["pos_map"].at[slot].set(pos.astype(jnp.int32))
        o = decode_attention(q, unflat(k_cache), unflat(v_cache), pos_map,
                             pos, window=cfg.sliding_window)
        new_cache = {"k": k_cache, "v": v_cache, "pos_map": pos_map}
    else:
        ka, va = (k, v)
        if cfg.expand_gqa and cfg.n_kv_heads < cfg.n_heads:
            ka, va = _expand_kv(k, v, cfg)
        if cfg.attn_impl == "flash" and not cfg.sliding_window:
            # Pallas flash kernel (forward-only: serving prefill path)
            from repro.kernels import ops as kops
            if ka.shape[2] < q.shape[2]:
                ka, va = _expand_kv(k, v, cfg)
            o = kops.flash_attention(q.swapaxes(1, 2), ka.swapaxes(1, 2),
                                     va.swapaxes(1, 2)).swapaxes(1, 2)
        else:
            o = causal_attention(q, ka, va, window=cfg.sliding_window,
                                 q_offset=positions[0],
                                 remat_chunks=cfg.remat_attention)
        new_cache = None
        if cache is not None:  # prefill: populate the (ring-buffer) cache
            slots = cache["k"].shape[1]
            S = k.shape[1]
            keep = max(0, S - slots)  # ring buffer keeps the last `slots`
            write_slots = (positions[keep:] % slots).astype(jnp.int32)
            kf = k[:, keep:].reshape(B, S - keep, KV * hd)
            vf = v[:, keep:].reshape(B, S - keep, KV * hd)
            k_cache = cache["k"].at[:, write_slots].set(kf)
            v_cache = cache["v"].at[:, write_slots].set(vf)
            pm = cache["pos_map"].at[write_slots].set(
                positions[keep:].astype(jnp.int32))
            new_cache = {"k": k_cache, "v": v_cache, "pos_map": pm}
    out = o.reshape(B, o.shape[1], -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out, new_cache
