"""Parameter initialization.  Per-layer params are stacked with a leading
(n_layers,) dim for lax.scan; statistically equivalent per-layer normal init.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.common import norm_init


def _split(key, n):
    return list(jax.random.split(key, n))


def _dense(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def _norm(cfg, d=None):
    return norm_init(d or cfg.d_model, jnp.float32,
                     cfg.norm == "layernorm")


def _attn_params(key, cfg, dtype, L=None):
    """GQA or MLA attention params; leading (L,) stack dim if L given.

    Head-structured projections are stored FLATTENED ((d, H*hd) etc.):
    every assigned arch's H*hd product divides the 16-way model axis, while
    raw head counts (56, 25, 24, 5, 2, ...) do not — this keeps argument
    shardings divisible and exact (no padded heads).  Forward code reshapes.
    """
    s = (L,) if L else ()
    ks = _split(key, 8)
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.use_mla:
        p = {}
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        if cfg.q_lora_rank:
            p["w_dq"] = _dense(ks[0], s + (d, cfg.q_lora_rank), dtype)
            p["q_ln"] = {"scale": jnp.zeros(s + (cfg.q_lora_rank,), jnp.float32)}
            p["w_uq"] = _dense(ks[1], s + (cfg.q_lora_rank, cfg.n_heads * qk), dtype)
        else:
            p["wq"] = _dense(ks[1], s + (d, cfg.n_heads * qk), dtype)
        p["w_dkv"] = _dense(ks[2], s + (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype)
        p["kv_ln"] = {"scale": jnp.zeros(s + (cfg.kv_lora_rank,), jnp.float32)}
        p["w_uk"] = _dense(ks[3], s + (cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_dim), dtype)
        p["w_uv"] = _dense(ks[4], s + (cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim), dtype)
        p["wo"] = _dense(ks[5], s + (cfg.n_heads * cfg.v_head_dim, d), dtype)
        return p
    p = {
        "wq": _dense(ks[0], s + (d, cfg.n_heads * hd), dtype),
        "wk": _dense(ks[1], s + (d, cfg.n_kv_heads * hd), dtype),
        "wv": _dense(ks[2], s + (d, cfg.n_kv_heads * hd), dtype),
        "wo": _dense(ks[3], s + (cfg.n_heads * hd, d), dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros(s + (cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros(s + (cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros(s + (cfg.n_kv_heads * hd,), dtype)
        p["bo"] = jnp.zeros(s + (d,), dtype)
    return p


def _mlp_params(key, cfg, dtype, d_ff, L=None):
    s = (L,) if L else ()
    ks = _split(key, 3)
    d = cfg.d_model
    p = {}
    if cfg.gated_mlp:
        p["w_gate"] = _dense(ks[0], s + (d, d_ff), dtype)
    p["w_up"] = _dense(ks[1], s + (d, d_ff), dtype)
    p["w_down"] = _dense(ks[2], s + (d_ff, d), dtype)
    if cfg.use_bias:
        p["b_up"] = jnp.zeros(s + (d_ff,), dtype)
        p["b_down"] = jnp.zeros(s + (d,), dtype)
    return p


def _norm_params(cfg, L=None, d=None):
    s = (L,) if L else ()
    d = d or cfg.d_model
    p = {"scale": jnp.zeros(s + (d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(s + (d,), jnp.float32)
    return p


def _ssm_params(key, cfg, dtype, L=None):
    s = (L,) if L else ()
    ks = _split(key, 8)
    d = cfg.d_model
    d_in = cfg.d_inner
    H = cfg.ssm_n_heads
    G, N, K = cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_d_conv
    conv_ch = d_in + 2 * G * N
    rng = np.random.default_rng(0)
    a_init = jnp.log(jnp.asarray(
        rng.uniform(1.0, 16.0, size=(H,)), jnp.float32))
    dt_init = jnp.log(jnp.expm1(jnp.asarray(
        np.clip(np.exp(rng.uniform(np.log(1e-3), np.log(1e-1), size=(H,))),
                1e-4, None), jnp.float32)))
    bc = lambda a: jnp.broadcast_to(a, s + a.shape) if L else a
    return {
        "in_z": _dense(ks[0], s + (d, d_in), dtype),
        "in_x": _dense(ks[1], s + (d, d_in), dtype),
        "in_B": _dense(ks[2], s + (d, G * N), dtype),
        "in_C": _dense(ks[3], s + (d, G * N), dtype),
        "in_dt": _dense(ks[4], s + (d, H), dtype),
        "conv_w": _dense(ks[5], s + (K, conv_ch), dtype, scale=0.1),
        "conv_b": jnp.zeros(s + (conv_ch,), dtype),
        "A_log": bc(a_init),
        "D": jnp.ones(s + (H,), jnp.float32),
        "dt_bias": bc(dt_init),
        "ssm_norm": jnp.zeros(s + (d_in,), jnp.float32),
        "out_proj": _dense(ks[6], s + (d_in, d), dtype),
    }


def _moe_params(key, cfg, dtype, L=None):
    s = (L,) if L else ()
    ks = _split(key, 6)
    d, f = cfg.d_model, cfg.moe_d_ff
    E = cfg.n_experts
    p = {
        "router": _dense(ks[0], s + (d, E), jnp.float32, scale=0.006),
        "experts": {
            "w_gate": _dense(ks[1], s + (E, d, f), dtype),
            "w_up": _dense(ks[2], s + (E, d, f), dtype),
            "w_down": _dense(ks[3], s + (E, f, d), dtype),
        },
    }
    if cfg.router_score == "sigmoid":
        p["router_bias"] = jnp.zeros(s + (E,), jnp.float32)
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": _dense(ks[4], s + (d, fs), dtype),
            "w_up": _dense(ks[5], s + (d, fs), dtype),
            "w_down": _dense(ks[4], s + (fs, d), dtype),
        }
    return p


def _block_params(key, cfg, kind, dtype, L):
    ks = _split(key, 6)
    p = {"ln1": _norm_params(cfg, L)}
    if kind == "ssm":
        p["ssm"] = _ssm_params(ks[0], cfg, dtype, L)
    elif kind == "hybrid":
        p["attn"] = _attn_params(ks[0], cfg, dtype, L)
        p["ssm"] = _ssm_params(ks[1], cfg, dtype, L)
        p["ln_a"] = _norm_params(cfg, L)
        p["ln_s"] = _norm_params(cfg, L)
        p["ln2"] = _norm_params(cfg, L)
        p["mlp"] = _mlp_params(ks[2], cfg, dtype, cfg.d_ff, L)
    elif kind == "moe":
        p["attn"] = _attn_params(ks[0], cfg, dtype, L)
        p["ln2"] = _norm_params(cfg, L)
        p["moe"] = _moe_params(ks[1], cfg, dtype, L)
    else:  # dense
        p["attn"] = _attn_params(ks[0], cfg, dtype, L)
        p["mlp"] = _mlp_params(ks[2], cfg, dtype, cfg.d_ff, L)
        if not cfg.parallel_residual:
            p["ln2"] = _norm_params(cfg, L)
    return p


def block_kinds(cfg: ArchConfig):
    """Returns [(params_key, kind, n_layers), ...] stack layout."""
    if cfg.family == "ssm":
        return [("blocks", "ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("blocks", "hybrid", cfg.n_layers)]
    if cfg.family == "moe":
        out = []
        if cfg.first_k_dense:
            out.append(("dense_blocks", "dense", cfg.first_k_dense))
        out.append(("moe_blocks", "moe", cfg.n_layers - cfg.first_k_dense))
        return out
    return [("blocks", "dense", cfg.n_layers)]


def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = _split(key, 8)
    params = {}
    V = cfg.vocab_padded
    if cfg.n_codebooks:
        params["embed"] = _dense(ks[0], (cfg.n_codebooks, V,
                                         cfg.d_model), dtype)
    else:
        params["embed"] = _dense(ks[0], (V, cfg.d_model), dtype)
    for i, (name, kind, L) in enumerate(block_kinds(cfg)):
        params[name] = _block_params(ks[1 + i], cfg, kind, dtype, L)
    params["final_norm"] = _norm_params(cfg)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            params["lm_head"] = _dense(ks[4], (cfg.n_codebooks, cfg.d_model,
                                               V), dtype)
        else:
            params["lm_head"] = _dense(ks[4], (cfg.d_model, V), dtype)
    if cfg.mtp_depth:
        params["mtp"] = {
            "mtp_proj": _dense(ks[5], (2 * cfg.d_model, cfg.d_model), dtype),
            "block": _block_params(ks[6], cfg, "dense", dtype, None),
            "norm": _norm_params(cfg),
        }
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
