"""Rotary and sinusoidal position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions, d_model: int):
    """(..., S) -> (..., S, d) classic transformer sinusoids."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
