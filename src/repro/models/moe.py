"""Token-choice top-k MoE with capacity-bounded scatter dispatch.

Design notes (see DESIGN.md §4): the dispatch is pure jnp (scatter/gather),
so it is vmap-safe for the client-parallel federated mode and lowers under
GSPMD with experts sharded over the 'model' axis.  A shard_map all-to-all
variant is the documented hillclimb for the collective-bound MoE pairs.

Router variants:
  softmax  (DeepSeek-V2): softmax scores, top-k renormalised.
  sigmoid  (DeepSeek-V3): sigmoid scores, selection uses score + learned
           bias (aux-loss-free balancing), gates renormalised over top-k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.models.common import activation_fn, mlp_apply
from repro.models.sharding import constrain, current_mesh


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(T * k / E * factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(p, x, cfg, ep: bool = False):
    """x: (..., d) -> (..., d), plus scalar aux loss.

    With ``ep=True`` and a production mesh active, dispatches to the
    shard_map expert-parallel path (§Perf: the pure-jnp scatter path makes
    GSPMD all-reduce full (E, cap, d) expert-buffer gradients — ~8.7 TB
    per step on deepseek-v3 train).  ``ep`` must be False under vmap
    (client_parallel training): shard_map's in_specs would bind the
    per-client batch dim to the data axis, which vmap has already claimed
    for the client dim.  Callers (blocks.block_apply) set it from the
    execution context; the jnp path is always a correct fallback.

    p: {"router": (d,E) [, "router_bias": (E,)],
        "experts": {"w_gate","w_up": (E,d,f), "w_down": (E,f,d)},
        ["shared": dense-mlp params]}
    """
    mesh = current_mesh()
    if ep and mesh is not None and "model" in mesh.axis_names \
            and x.ndim == 3 \
            and x.shape[0] % _batch_div(mesh) == 0:
        return _moe_ffn_ep(p, x, cfg, mesh)
    return _moe_ffn_dense(p, x, cfg)


def _batch_div(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.shape]))


def _moe_ffn_dense(p, x, cfg):
    """Reference jnp path (vmap-safe, mesh-free)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, k = cfg.n_experts, cfg.top_k

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"].astype(jnp.float32)[None, :] \
            if "router_bias" in p else scores
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        scores = probs
        sel = probs
    _, top_i = jax.lax.top_k(sel, k)                       # (T,k)
    top_s = jnp.take_along_axis(scores, top_i, axis=-1)    # (T,k)
    gates = top_s / (jnp.sum(top_s, -1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                           # (E,)
    assign = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    ce = assign / (T * k)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- capacity-bounded scatter dispatch -------------------------------
    cap = _capacity(T, k, E, cfg.capacity_factor)
    fe = top_i.reshape(-1)                                 # (T*k,)
    oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(T * k), fe]  # rank in e
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                       # overflow -> pad

    buf = jnp.zeros((E, cap + 1, d), x.dtype)
    buf = buf.at[fe, slot].add(xf[jnp.arange(T * k) // k])
    buf = constrain(buf, P("model", None, None))

    # --- expert FFN (batched over E, sharded over 'model') ---------------
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"])
    out_buf = constrain(out_buf, P("model", None, None))

    # --- gather + combine -------------------------------------------------
    y_tok = out_buf[fe, slot]                              # (T*k, d)
    y_tok = y_tok * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = jnp.sum(y_tok.reshape(T, k, d), axis=1)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xf, cfg)
    return y.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (§Perf)
# ---------------------------------------------------------------------------


def _routing(xf, p, cfg):
    """Shared router math: returns (top_i (T,k), gates (T,k), aux)."""
    T = xf.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"].astype(jnp.float32)[None, :] \
            if "router_bias" in p else scores
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    _, top_i = jax.lax.top_k(sel, k)
    top_s = jnp.take_along_axis(scores, top_i, axis=-1)
    gates = top_s / (jnp.sum(top_s, -1, keepdims=True) + 1e-9)
    if cfg.router_score == "sigmoid":
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-9)
    else:
        probs = scores
    me = jnp.mean(probs, axis=0)
    assign = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    ce = assign / (T * k)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return top_i, gates, aux


def _moe_ffn_ep(p, x, cfg, mesh):
    """Expert parallelism with replicated activations: every model shard
    routes ALL of its data-shard's tokens, computes only its own E/16
    experts into a local capacity buffer, and the outputs are combined
    with one psum over 'model' (which also carries the TP-sharded shared
    expert).  No cross-shard scatter/gather -> no giant buffer-grad
    all-reduces."""
    E, k = cfg.n_experts, cfg.top_k
    d = x.shape[-1]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    E_l = E // mesh.shape["model"]

    def kernel(xl, router, router_bias, w_gate, w_up, w_down, shared):
        # xl: (b_l, S, d) — replicated across the model row
        midx = jax.lax.axis_index("model")
        xf = xl.reshape(-1, d)
        T_l = xf.shape[0]
        pr = {"router": router}
        if router_bias is not None:
            pr["router_bias"] = router_bias
        top_i, gates, aux = _routing(xf, pr, cfg)
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux

        # local experts only
        lo = midx * E_l
        fe = top_i.reshape(-1) - lo                       # (T_l*k,)
        mine = (fe >= 0) & (fe < E_l)
        fe_c = jnp.where(mine, fe, 0)
        cap = _capacity(T_l, k, E, cfg.capacity_factor)
        oh = jax.nn.one_hot(fe_c, E_l, dtype=jnp.int32) * mine[:, None]
        pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(T_l * k), fe_c]
        keep = mine & (pos < cap)
        slot = jnp.where(keep, pos, cap)

        buf = jnp.zeros((E_l, cap + 1, d), xl.dtype)
        buf = buf.at[fe_c, slot].add(
            xf[jnp.arange(T_l * k) // k] * keep[:, None].astype(xl.dtype))
        act = activation_fn(cfg.activation)
        h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
            jnp.einsum("ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
        y_tok = out_buf[fe_c, slot] * \
            (gates.reshape(-1, 1) * keep[:, None]).astype(xl.dtype)
        y = jnp.sum(y_tok.reshape(T_l, k, d), axis=1)

        if shared is not None:
            # shared expert: TP-sharded hidden, partial sum joins the psum
            hs = act(xf @ shared["w_gate"]) * (xf @ shared["w_up"])
            y = y + hs @ shared["w_down"]
        y = jax.lax.psum(y, "model")
        return y.reshape(xl.shape), aux

    P_ = jax.sharding.PartitionSpec
    in_specs = (
        P_(batch_axes if batch_axes else None, None, None),  # x
        P_(None, None),                                      # router
        P_(None) if "router_bias" in p else None,            # bias
        P_("model", None, None), P_("model", None, None),    # w_gate, w_up
        P_("model", None, None),                             # w_down
        {"w_gate": P_(None, "model"), "w_up": P_(None, "model"),
         "w_down": P_("model", None)} if "shared" in p else None,
    )
    out_specs = (P_(batch_axes if batch_axes else None, None, None), P_())
    fn = shard_map(kernel, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    y, aux = fn(x, p["router"], p.get("router_bias"),
                p["experts"]["w_gate"], p["experts"]["w_up"],
                p["experts"]["w_down"], p.get("shared"))
    return y, aux
