"""Per-layer blocks: dense (GQA/MLA + MLP), MoE, SSM (Mamba2), hybrid
(parallel attention + SSM heads, Hymba-style)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import gqa_attention
from repro.models.common import apply_norm, mlp_apply, rmsnorm
from repro.models.mla import mla_attention
from repro.models.moe import moe_ffn
from repro.models.ssd import mamba_mixer


def _attn(p, x, cfg, positions, cache, decode):
    if cfg.use_mla:
        return mla_attention(p, x, cfg, positions, cache=cache, decode=decode)
    return gqa_attention(p, x, cfg, positions, cache=cache, decode=decode)


def block_apply(p, x, cfg, kind, positions, cache=None, decode=False):
    """Returns (x_out, aux_loss, new_cache)."""
    aux = jnp.float32(0.0)
    new_cache = {}
    cache = cache or {}

    if kind == "ssm":
        h = apply_norm(x, p["ln1"], cfg)
        y, c = mamba_mixer(p["ssm"], h, cfg, cache=cache.get("ssm"),
                           decode=decode)
        if c is not None:
            new_cache["ssm"] = c
        x = x + y

    elif kind == "hybrid":
        h = apply_norm(x, p["ln1"], cfg)
        a, ca = _attn(p["attn"], h, cfg, positions, cache.get("attn"), decode)
        s, cs = mamba_mixer(p["ssm"], h, cfg, cache=cache.get("ssm"),
                            decode=decode)
        if ca is not None:
            new_cache["attn"] = ca
        if cs is not None:
            new_cache["ssm"] = cs
        # Hymba: per-branch norm, mean combine
        y = 0.5 * (rmsnorm(a, p["ln_a"]["scale"], cfg.norm_eps)
                   + rmsnorm(s, p["ln_s"]["scale"], cfg.norm_eps))
        x = x + y
        h2 = apply_norm(x, p["ln2"], cfg)
        x = x + mlp_apply(p["mlp"], h2, cfg)

    elif kind == "moe":
        h = apply_norm(x, p["ln1"], cfg)
        a, ca = _attn(p["attn"], h, cfg, positions, cache.get("attn"), decode)
        if ca is not None:
            new_cache["attn"] = ca
        x = x + a
        h2 = apply_norm(x, p["ln2"], cfg)
        # expert-parallel dispatch is safe whenever we are NOT under the
        # client vmap: serving paths (decode / prefill-with-cache) and
        # client_sequential training
        ep = decode or bool(cache) or cfg.fed.mode == "client_sequential"
        y, aux_moe = moe_ffn(p["moe"], h2, cfg, ep=ep)
        aux = aux + aux_moe
        x = x + y

    else:  # dense
        if cfg.parallel_residual:
            h = apply_norm(x, p["ln1"], cfg)
            a, ca = _attn(p["attn"], h, cfg, positions, cache.get("attn"),
                          decode)
            if ca is not None:
                new_cache["attn"] = ca
            x = x + a + mlp_apply(p["mlp"], h, cfg)
        else:
            h = apply_norm(x, p["ln1"], cfg)
            a, ca = _attn(p["attn"], h, cfg, positions, cache.get("attn"),
                          decode)
            if ca is not None:
                new_cache["attn"] = ca
            x = x + a
            h2 = apply_norm(x, p["ln2"], cfg)
            x = x + mlp_apply(p["mlp"], h2, cfg)

    return x, aux, (new_cache or None)
