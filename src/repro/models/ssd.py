"""Mamba2 SSD (state-space duality, arXiv:2405.21060), chunked TPU-friendly
form: intra-chunk attention-like matmuls (MXU work) + an inter-chunk
lax.scan over running states.  Decode is the O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rmsnorm, safe_concat


def segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{k=j+1..i} x[k] (j<=i),
    -inf above the diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x:  (Bb, S, H, P)     head inputs
    dt: (Bb, S, H)        post-softplus step sizes
    A:  (H,)              negative decay rates
    B:  (Bb, S, G, N)     input  projections (G groups, H % G == 0)
    C:  (Bb, S, G, N)     output projections
    h0: (Bb, G, hg, P, N) optional initial state
    Returns (y: (Bb,S,H,P), h_last: (Bb,G,hg,P,N)).
    """
    Bb, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    hg = H // G
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    f32 = jnp.float32
    xr = x.reshape(Bb, nc, Q, G, hg, Pd).astype(f32)
    dtr = dt.reshape(Bb, nc, Q, G, hg).astype(f32)
    Br = B.reshape(Bb, nc, Q, G, N).astype(f32)
    Cr = C.reshape(Bb, nc, Q, G, N).astype(f32)

    dA = dtr * A.astype(f32).reshape(G, hg)            # (Bb,nc,Q,G,hg)
    dA_t = jnp.moveaxis(dA, 2, -1)                     # (Bb,nc,G,hg,Q)
    dA_cs = jnp.cumsum(dA_t, axis=-1)                  # (Bb,nc,G,hg,Q)
    dA_sum = dA_cs[..., -1]                            # (Bb,nc,G,hg)

    L = jnp.exp(segsum(dA_t))                          # (Bb,nc,G,hg,Q,Q)
    xdt = xr * dtr[..., None]                          # (Bb,nc,Q,G,hg,P)

    # intra-chunk (the "quadratic / attention" dual form)
    y_intra = jnp.einsum("bcqgn,bcsgn,bcghqs,bcsghp->bcqghp",
                         Cr, Br, L, xdt)

    # chunk-final states
    decay_states = jnp.exp(dA_sum[..., None] - dA_cs)  # (Bb,nc,G,hg,Q)
    x_decay = xdt * jnp.moveaxis(decay_states, -1, 2)[..., None]
    states = jnp.einsum("bcsgn,bcsghp->bcghpn", Br, x_decay)

    # inter-chunk recurrence over running state h
    if h0 is None:
        h0 = jnp.zeros((Bb, G, hg, Pd, N), f32)
    else:
        h0 = h0.astype(f32)
    chunk_decay = jnp.exp(dA_sum)                      # (Bb,nc,G,hg)

    def step(h, inp):
        s_c, dec_c = inp
        h_prev = h
        h = h * dec_c[..., None, None] + s_c
        return h, h_prev

    states_t = jnp.moveaxis(states, 1, 0)              # (nc,Bb,G,hg,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)          # (nc,Bb,G,hg)
    h_last, h_prevs = jax.lax.scan(step, h0, (states_t, decay_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (Bb,nc,G,hg,P,N)

    c_in_decay = jnp.exp(dA_cs)                        # (Bb,nc,G,hg,Q)
    y_inter = jnp.einsum("bcqgn,bcghq,bcghpn->bcqghp",
                         Cr, c_in_decay, h_prevs)

    y = (y_intra + y_inter).reshape(Bb, S, H, Pd)
    return y.astype(x.dtype), h_last


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence.
    h: (Bb,G,hg,P,N); x_t: (Bb,H,P); dt_t: (Bb,H); B_t,C_t: (Bb,G,N)."""
    Bb, G, hg, Pd, N = h.shape
    f32 = jnp.float32
    xr = x_t.reshape(Bb, G, hg, Pd).astype(f32)
    dtr = dt_t.reshape(Bb, G, hg).astype(f32)
    dA = jnp.exp(dtr * A.astype(f32).reshape(G, hg))
    h = h.astype(f32) * dA[..., None, None] + jnp.einsum(
        "bgn,bghp->bghpn", B_t.astype(f32), xr * dtr[..., None])
    y = jnp.einsum("bgn,bghpn->bghp", C_t.astype(f32), h)
    return y.reshape(Bb, x_t.shape[1], Pd).astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# Full Mamba2 mixer (in-proj, causal depthwise conv, SSD, gated norm, out)
# ---------------------------------------------------------------------------


def _causal_conv(xBC, w, b):
    """xBC: (Bb,S,Cc); w: (K,Cc); depthwise causal conv."""
    K = w.shape[0]
    S = xBC.shape[1]
    xp = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, j:j + S] * w[j] for j in range(K))
    return y + b


def mamba_mixer(p, u, cfg, cache=None, decode=False):
    """Returns (out, updated_cache_or_None).

    cache: {"conv": (Bb, K-1, Cc) raw pre-conv inputs,
            "state": (Bb, G, hg, P, N)}.
    """
    d_in = p["in_x"].shape[1]
    Pd = cfg.ssm_head_dim
    H = d_in // Pd
    G, N = cfg.ssm_n_groups, cfg.ssm_d_state
    K = cfg.ssm_d_conv

    z = u @ p["in_z"]
    # safe_concat: in_x's output dim is 'model'-sharded while in_B/in_C
    # stay replicated — a raw concatenate miscompiles under GSPMD here
    # (misaligned shard/piece boundaries; see models/common.safe_concat)
    xBC = safe_concat([u @ p["in_x"], u @ p["in_B"], u @ p["in_C"]],
                      axis=-1)
    dt = jax.nn.softplus((u @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        assert cache is not None
        conv_state = cache["conv"]  # (Bb, K-1, Cc)
        y_conv = (jnp.einsum("bkc,kc->bc", conv_state, p["conv_w"][: K - 1])
                  + xBC[:, 0] * p["conv_w"][K - 1] + p["conv_b"])
        # safe_concat: the rolling conv cache is replicated while xBC
        # carries the in-proj's 'model' sharding — same mixed-sharding
        # concatenate pattern as the xBC projection above
        new_conv = safe_concat([conv_state[:, 1:], xBC], axis=1)
        xBC_act = jax.nn.silu(y_conv)[:, None, :]      # (Bb,1,Cc)
        x, B_, C_ = jnp.split(xBC_act, [d_in, d_in + G * N], axis=-1)
        y, h = ssd_decode_step(
            cache["state"],
            x[:, 0].reshape(-1, H, Pd),
            dt[:, 0],
            A,
            B_[:, 0].reshape(-1, G, N),
            C_[:, 0].reshape(-1, G, N),
        )
        y = y[:, None]                                  # (Bb,1,H,P)
        x_skip = x.reshape(*x.shape[:2], H, Pd)
        new_cache = {"conv": new_conv, "state": h}
    else:
        y_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        xBC_act = jax.nn.silu(y_conv)
        x, B_, C_ = jnp.split(xBC_act, [d_in, d_in + G * N], axis=-1)
        Bb, S = x.shape[0], x.shape[1]
        y, h = ssd_chunked(
            x.reshape(Bb, S, H, Pd), dt, A,
            B_.reshape(Bb, S, G, N), C_.reshape(Bb, S, G, N),
            cfg.ssm_chunk,
            h0=cache["state"] if cache is not None else None,
        )
        x_skip = x.reshape(Bb, S, H, Pd)
        new_cache = None
        if cache is not None:  # prefill
            new_conv = xBC[:, -(K - 1):, :]
            new_cache = {"conv": new_conv, "state": h}

    y = y + p["D"].astype(y.dtype)[:, None] * x_skip
    y = y.reshape(*y.shape[:2], d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache
