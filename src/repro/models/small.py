"""The paper's experiment models (McMahan et al. 2016 MLP/CNN + logistic
regression for SYNTHETIC).  Pure-jnp, used by the federated driver."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper import PaperModelConfig


def init_small(key, cfg: PaperModelConfig):
    ks = jax.random.split(key, 6)
    if cfg.kind == "logreg":
        d = cfg.input_shape[0]
        return {"w": 0.01 * jax.random.normal(ks[0], (d, cfg.n_classes)),
                "b": jnp.zeros((cfg.n_classes,))}
    if cfg.kind == "mlp":
        d = int(jnp.prod(jnp.asarray(cfg.input_shape)))
        h = cfg.hidden
        return {
            "w1": jax.random.normal(ks[0], (d, h)) * jnp.sqrt(2.0 / d),
            "b1": jnp.zeros((h,)),
            "w2": jax.random.normal(ks[1], (h, cfg.n_classes)) * jnp.sqrt(2.0 / h),
            "b2": jnp.zeros((cfg.n_classes,)),
        }
    if cfg.kind == "cnn":
        return {
            "c1": jax.random.normal(ks[0], (5, 5, 1, 32)) * 0.1,
            "cb1": jnp.zeros((32,)),
            "c2": jax.random.normal(ks[1], (5, 5, 32, 64)) * 0.05,
            "cb2": jnp.zeros((64,)),
            "w1": jax.random.normal(ks[2], (7 * 7 * 64, 128)) * 0.02,
            "b1": jnp.zeros((128,)),
            "w2": jax.random.normal(ks[3], (128, cfg.n_classes)) * 0.05,
            "b2": jnp.zeros((cfg.n_classes,)),
        }
    raise ValueError(cfg.kind)


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def logits_small(params, cfg: PaperModelConfig, x):
    if cfg.kind == "logreg":
        return x @ params["w"] + params["b"]
    if cfg.kind == "mlp":
        xf = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(xf @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    # cnn
    x = x.reshape(x.shape[0], 28, 28, 1)
    h = jax.nn.relu(_conv(x, params["c1"], params["cb1"]))
    h = _pool(h)
    h = jax.nn.relu(_conv(h, params["c2"], params["cb2"]))
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def make_loss_fn(cfg: PaperModelConfig):
    def loss_fn(params, batch):
        x, y = batch["x"], batch["y"]
        lg = logits_small(params, cfg, x)
        ll = jax.nn.log_softmax(lg)
        # one-hot contraction rather than take_along_axis: same value, but
        # the backward pass is a dense multiply instead of a scatter, which
        # dominates the per-step cost of the federated SGD inner loop
        oh = jax.nn.one_hot(y, lg.shape[-1], dtype=ll.dtype)
        return -jnp.mean(jnp.sum(ll * oh, axis=-1))
    return loss_fn


def accuracy(params, cfg: PaperModelConfig, x, y):
    lg = logits_small(params, cfg, x)
    return jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
