"""Top-level model: embedding, scanned layer stacks, loss, prefill/decode.

All ten assigned architectures run through `model_forward`; family
differences are config- and param-structure-driven (see params.block_kinds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.blocks import block_apply
from repro.models.common import apply_norm
from repro.models.params import block_kinds
from repro.models.rotary import sinusoidal
from repro.models.sharding import BATCH, constrain

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# Embedding / heads
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ArchConfig, tokens, positions):
    if cfg.n_codebooks:
        # tokens: (B,S,K) — summed codebook embeddings
        parts = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                 for k in range(cfg.n_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal(positions, cfg.d_model).astype(x.dtype)
    return constrain(x, P(BATCH, None, None))


def logits_fn(params, cfg: ArchConfig, h):
    """h: (..., d) -> logits (..., V) (audio: (..., K, V)); float32."""
    if cfg.n_codebooks:
        head = params.get("lm_head")
        if head is None:
            head = jnp.swapaxes(params["embed"], 1, 2)
        lg = jnp.einsum("...d,kdv->...kv", h, head,
                        preferred_element_type=jnp.float32)
    else:
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        lg = jnp.einsum("...d,dv->...v", h, head,
                        preferred_element_type=jnp.float32)
    return constrain(lg, P(*([None] * (lg.ndim - 1)), "model"))


# ---------------------------------------------------------------------------
# Layer stacks
# ---------------------------------------------------------------------------


def _run_stack(stack_p, x, cfg, kind, positions, cache=None, decode=False):
    """lax.scan over stacked layer params (+ per-layer cache).

    §Perf (sequence parallelism): in train/prefill the residual stream
    carried between layers is sharded over the *model* axis along the
    sequence dim — the saved-for-backward layer inputs shrink 16x and
    GSPMD turns each block's output psum into reduce-scatter + the next
    block's input all-gather (Megatron SP).  Decode (S=1) is exempt.
    """
    seq_shard = (cfg.seq_parallel and not decode and x.shape[1] > 1)

    def reshard(t):
        if seq_shard:
            return constrain(t, P(BATCH, "model", None))
        return t

    x = reshard(x)

    def body(carry, xs):
        x = carry
        p_layer, cache_layer = xs
        x, aux, new_cache = block_apply(p_layer, x, cfg, kind, positions,
                                        cache=cache_layer, decode=decode)
        return reshard(x), (aux, new_cache)

    if cfg.remat:
        body = jax.checkpoint(body)

    xs = (stack_p, cache)
    if cache is None:
        n_layers = jax.tree.leaves(stack_p)[0].shape[0]
        xs = (stack_p, jnp.zeros((n_layers,), jnp.int32))

        def body_nc(carry, xs):  # cache-free wrapper keeps pytrees static
            p_layer, _ = xs
            x, aux, _ = block_apply(p_layer, carry, cfg, kind, positions,
                                    cache=None, decode=False)
            return reshard(x), (aux, 0)

        body_fn = jax.checkpoint(body_nc) if cfg.remat else body_nc
        x, (auxs, _) = jax.lax.scan(body_fn, x, xs)
        return x, jnp.sum(auxs), None

    x, (auxs, new_cache) = jax.lax.scan(body, x, xs)
    return x, jnp.sum(auxs), new_cache


def model_forward(params, cfg: ArchConfig, tokens, *, patch_emb=None,
                  positions=None, cache=None, decode=False):
    """Returns (hidden (B,S,d), aux_loss, new_cache_or_None).

    tokens: (B,S[,K]); decode: S == 1, positions: (1,) current position.
    patch_emb: (B,P,d) VLM patch embeddings, prepended (train/prefill only).
    """
    B = tokens.shape[0]
    S = tokens.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if patch_emb is not None and not decode:
        Pn = patch_emb.shape[1]
        positions = jnp.arange(Pn + S, dtype=jnp.int32)
        x_text = embed_tokens(params, cfg, tokens, positions[Pn:])
        x = jnp.concatenate([patch_emb.astype(x_text.dtype), x_text], axis=1)
    else:
        x = embed_tokens(params, cfg, tokens, positions)

    total_aux = jnp.float32(0.0)
    new_cache = {} if cache is not None else None
    for name, kind, _L in block_kinds(cfg):
        stack_cache = cache.get(name) if cache is not None else None
        x, aux, nc = _run_stack(params[name], x, cfg, kind, positions,
                                cache=stack_cache, decode=decode)
        total_aux = total_aux + aux
        if new_cache is not None:
            new_cache[name] = nc
    if cfg.seq_parallel and not decode and x.shape[1] > 1:
        # leave the sequence-sharded domain before the (token-chunked,
        # vocab-sharded) loss — avoids GSPMD resharding thrash there
        x = constrain(x, P(BATCH, None, None))
    x = apply_norm(x, params["final_norm"], cfg)
    return x, total_aux, new_cache


# ---------------------------------------------------------------------------
# Training loss (chunked cross-entropy over the token axis)
# ---------------------------------------------------------------------------


def _xent_chunk(params, cfg, h_chunk, labels_chunk):
    lg = logits_fn(params, cfg, h_chunk)  # (c[,K],Vp) f32
    if lg.shape[-1] != cfg.vocab:  # mask padded vocab entries
        vmask = jnp.arange(lg.shape[-1]) < cfg.vocab
        lg = jnp.where(vmask, lg, -1e30)
    logz = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels_chunk[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    valid = (labels_chunk >= 0)
    per = (logz - ll) * valid
    return jnp.sum(per), jnp.sum(valid)


def chunked_xent(params, cfg, hidden2d, labels1d, chunk=LOSS_CHUNK):
    """hidden2d: (T,d); labels1d: (T[,K]).  -1 labels are masked.

    §Perf: T is PADDED up to a chunk multiple (masked labels) rather than
    shrinking the chunk — an off-by-one T (e.g. the MTP head's S-1 tokens)
    previously degenerated to 64-token chunks and a 4095-trip loss scan.
    """
    T = hidden2d.shape[0]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        hidden2d = jnp.pad(hidden2d, ((0, pad),) + ((0, 0),) * (hidden2d.ndim - 1))
        labels1d = jnp.pad(labels1d, ((0, pad),) + ((0, 0),) * (labels1d.ndim - 1),
                           constant_values=-1)
        T += pad
    nc = T // c
    hs = hidden2d.reshape(nc, c, -1)
    ls = labels1d.reshape(nc, c, *labels1d.shape[1:])

    def body(carry, xs):
        s, n = carry
        h, l = xs
        ds, dn = _xent_chunk(params, cfg, h, l)
        return (s + ds, n + dn), None

    body = jax.checkpoint(body)
    (s, n), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                             (hs, ls))
    return s / jnp.maximum(n, 1.0)


def train_loss(params, cfg: ArchConfig, batch):
    """batch: {"tokens": (B,S[,K]), "labels": (B,S[,K])
               [, "patch_emb": (B,P,d)]}.  Returns scalar loss."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    patch_emb = batch.get("patch_emb")
    h, aux, _ = model_forward(params, cfg, tokens, patch_emb=patch_emb)
    if patch_emb is not None:
        h = h[:, patch_emb.shape[1]:]  # loss on text positions only
    B, S = labels.shape[0], labels.shape[1]
    loss = chunked_xent(params, cfg, h.reshape(B * S, -1),
                        labels.reshape(B * S, *labels.shape[2:]))
    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.1 * _mtp_loss(params, cfg, h, tokens, labels)
    return loss + aux


def _mtp_loss(params, cfg, h, tokens, labels):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
    [norm(h_t); embed(token_{t+1})] through one extra block."""
    mtp = params["mtp"]
    emb_next = embed_tokens(params, cfg, tokens[:, 1:],
                            jnp.arange(1, tokens.shape[1], dtype=jnp.int32))
    h_in = jnp.concatenate([apply_norm(h[:, :-1], mtp["norm"], cfg),
                            emb_next], axis=-1)
    x = h_in @ mtp["mtp_proj"]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _aux, _ = block_apply(mtp["block"], x, cfg, "dense", positions)
    x = apply_norm(x, params["final_norm"], cfg)
    labels2 = labels[:, 1:]
    B, S2 = labels2.shape[0], labels2.shape[1]
    return chunked_xent(params, cfg, x.reshape(B * S2, -1),
                        labels2.reshape(B * S2, *labels2.shape[2:]))


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Stacked per-layer caches; ring buffer of `sliding_window` slots for
    SWA archs."""
    dtype = jnp.dtype(cfg.dtype)
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    cache = {}
    for name, kind, L in block_kinds(cfg):
        c = {}
        if kind in ("dense", "moe", "hybrid") and cfg.n_heads:
            if cfg.use_mla:
                c["attn"] = {
                    "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank),
                                     dtype),
                    "krope": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim),
                                       dtype),
                    "pos_map": jnp.full((L, max_len), -1, jnp.int32),
                }
            else:
                # kv dim flattened (KV*hd): divisible by the model axis
                c["attn"] = {
                    "k": jnp.zeros((L, batch, slots,
                                    cfg.n_kv_heads * cfg.head_dim), dtype),
                    "v": jnp.zeros((L, batch, slots,
                                    cfg.n_kv_heads * cfg.head_dim), dtype),
                    "pos_map": jnp.full((L, slots), -1, jnp.int32),
                }
        if kind in ("ssm", "hybrid"):
            G, N = cfg.ssm_n_groups, cfg.ssm_d_state
            hg = cfg.ssm_n_heads // G
            conv_ch = cfg.d_inner + 2 * G * N
            c["ssm"] = {
                "conv": jnp.zeros((L, batch, cfg.ssm_d_conv - 1, conv_ch),
                                  dtype),
                "state": jnp.zeros((L, batch, G, hg, cfg.ssm_head_dim, N),
                                   jnp.float32),
            }
        cache[name] = c
    return cache


def prefill(params, cfg: ArchConfig, tokens, cache, *, patch_emb=None):
    """Run the prompt, fill the cache; returns (last-position logits, cache)."""
    h, _aux, new_cache = model_forward(params, cfg, tokens,
                                       patch_emb=patch_emb, cache=cache,
                                       decode=False)
    lg = logits_fn(params, cfg, h[:, -1:])[..., : cfg.vocab]
    return lg, new_cache


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    """One decode step.  token: (B,1[,K]); pos: scalar int32 absolute
    position.  Returns (logits (B,1[,K],V), new_cache)."""
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    h, _aux, new_cache = model_forward(params, cfg, token,
                                       positions=positions, cache=cache,
                                       decode=True)
    lg = logits_fn(params, cfg, h)[..., : cfg.vocab]
    return lg, new_cache
