"""Mesh context + PartitionSpec rules.

The model code is mesh-agnostic: ``constrain(x, spec)`` is a no-op unless a
mesh has been installed with ``use_mesh``.  Param specs are derived from the
pytree path names, so one rule table covers every architecture family.

Axis roles (see DESIGN.md §2.1):
  pod    — multi-pod client/data parallelism (outermost)
  data   — client parallelism (fed) / batch (serve) / FSDP shard axis
  model  — tensor parallelism: heads, d_ff, vocab, experts, d_inner
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install `mesh` as the ambient mesh for constrain()/named_sharding().
    All shardings are explicit NamedShardings, so no jax-global context is
    required."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def constrain(x, spec: P):
    """with_sharding_constraint if a mesh is active, else identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _filter_spec(spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop axis names not present in the mesh (e.g. 'pod' on single-pod).

    Singleton tuples normalize to the bare axis name: ``('data',)`` and
    ``'data'`` describe the same layout but compare unequal in the jit
    cache key, so a committed array round-tripping through GSPMD (which
    emits the bare form) would otherwise recompile every consumer."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            if not kept:
                return None
            return kept[0] if len(kept) == 1 else kept
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(spec, mesh))


# Batch-like dims (clients, batch) shard over pod+data.
BATCH = ("pod", "data")


# ---------------------------------------------------------------------------
# Parameter partition rules, keyed by leaf path fragments.
# ---------------------------------------------------------------------------


def param_spec(path: str, ndim: int, *, fsdp: bool, stacked: bool) -> P:
    """PartitionSpec for a parameter leaf.

    path     -- '/'-joined pytree path, e.g. 'blocks/attn/wq'.
    stacked  -- leaf has a leading (n_layers,) scan dim.
    fsdp     -- additionally shard the non-TP big dim over ('data',) ('pod'
                included when present; filtered per-mesh at constrain time).
    """
    name = path.split("/")[-1]
    d_ax = ("pod", "data") if fsdp else None  # FSDP axis for the d_model dim

    def spec(*entries):
        entries = list(entries)
        # pad to ndim (minus stack dim) with None
        body = ndim - (1 if stacked else 0)
        while len(entries) < body:
            entries.append(None)
        if stacked:
            entries = [None] + entries
        return P(*entries)

    # --- attention (flattened (d, H*hd) projections) ---
    if name in ("wq", "wk", "wv"):
        return spec(d_ax, "model")
    if name == "wo":
        return spec("model", d_ax)
    if name in ("bq", "bk", "bv"):
        return spec("model")
    if name == "bo":
        return spec(None)
    # --- MLA (flattened (rank, H*dim) up-projections) ---
    if name in ("w_dq", "w_dkv"):
        return spec(d_ax, None)
    if name in ("w_uq", "w_uk", "w_uv"):
        return spec(None, "model")
    # --- MLP ---
    if name in ("w_gate", "w_up"):
        if ndim - (1 if stacked else 0) == 3:  # experts (E, d, f)
            return spec("model", d_ax, None)
        return spec(d_ax, "model")
    if name == "w_down":
        if ndim - (1 if stacked else 0) == 3:  # experts (E, f, d)
            return spec("model", None, d_ax)
        return spec("model", d_ax)
    if name == "b_up":
        return spec("model")
    if name == "b_down":
        return spec(None)
    if name in ("router", "router_bias"):
        return spec(None)
    # --- SSM ---
    if name in ("in_z", "in_x"):
        return spec(d_ax, "model")
    if name in ("in_B", "in_C", "in_dt"):
        return spec(d_ax, None)
    if name in ("conv_w", "conv_b"):
        return spec(None, "model") if ndim - (1 if stacked else 0) == 2 else spec("model")
    if name in ("A_log", "D", "dt_bias"):
        return spec(None)
    if name == "ssm_norm":
        return spec("model")
    if name == "out_proj":
        return spec("model", d_ax)
    # --- embeddings / heads ---
    if name == "embed":
        # (V, d) or (K, V, d) for audio codebooks
        if ndim == 3:
            return P(None, "model", None)
        return P("model", None)
    if name == "lm_head":
        # (d, V) or (K, d, V)
        if ndim == 3:
            return P(None, None, "model")
        return P(None, "model")
    if name == "patch_proj":
        return spec(d_ax, None)
    if name == "mtp_proj":
        return spec(d_ax, None)
    # --- norms, scalars, everything else: replicated ---
    return P(*([None] * ndim))


def tree_param_specs(params, *, fsdp: bool):
    """Build a pytree of PartitionSpec matching ``params``.

    Any subtree whose key ends with 'blocks' holds per-layer stacked leaves
    (leading (n_layers,) scan dim).
    """

    def walk(tree, prefix, stacked):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{prefix}/{k}" if prefix else k,
                        stacked or k.endswith("blocks"))
                for k, v in tree.items()
            }
        return param_spec(prefix, tree.ndim, fsdp=fsdp, stacked=stacked)

    return walk(params, "", False)
