"""Optimizers.  The paper's federated path uses vanilla SGD with the
staircase learning rate (local steps live in core.fed_step); AdamW is
provided for the non-federated training utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def staircase_lr(eta0: float, tau, tau0=0):
    return eta0 / jnp.maximum(jnp.asarray(tau - tau0, jnp.float32), 1.0)


def sgd_step(params, grads, eta, momentum_state=None, momentum: float = 0.0):
    if momentum and momentum_state is not None:
        momentum_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            momentum_state, grads)
        params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - eta * m).astype(p.dtype),
            params, momentum_state)
        return params, momentum_state
    params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - eta * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return params, momentum_state


def adamw_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.int32(0)}


def adamw_step(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
               wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return (p.astype(jnp.float32)
                - lr * (step + wd * p.astype(jnp.float32))).astype(p.dtype)

    return (jax.tree.map(upd, params, m, v),
            {"m": m, "v": v, "t": t})
