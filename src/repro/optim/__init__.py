from repro.optim.sgd import adamw_init, adamw_step, sgd_step, staircase_lr

__all__ = ["sgd_step", "staircase_lr", "adamw_init", "adamw_step"]
