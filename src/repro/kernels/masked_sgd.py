"""Pallas TPU kernel: fused masked local-SGD update (equivalent view).

w' = w - eta * alpha * g      (paper Eq. 1 with the A.1.1 alpha mask)

Fuses the mask/scale/subtract into one VMEM pass instead of three HBM
round-trips.  eta*alpha arrives as a (1,1) scalar tile."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _sgd_kernel(s_ref, w_ref, g_ref, o_ref):
    scale = s_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = (w - scale * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_sgd(w, g, eta_alpha, *, block: int = DEFAULT_BLOCK,
               interpret: bool = True):
    """w, g: (D,); eta_alpha: scalar (eta * alpha_t).  Returns updated w."""
    D = w.shape[0]
    pad = (-D) % block
    if pad:
        w = jnp.pad(w, (0, pad))
        g = jnp.pad(g, (0, pad))
    Dp = D + pad
    scale = jnp.reshape(eta_alpha.astype(jnp.float32), (1, 1))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(Dp // block,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), w.dtype),
        interpret=interpret,
    )(scale, w.reshape(1, Dp), g.reshape(1, Dp))
    return out[0, :D]
