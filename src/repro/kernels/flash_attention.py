"""Pallas TPU kernel: causal flash attention (forward).

Online-softmax blockwise attention: grid (B*H, nq, nk) with the kv axis
innermost; running max/denominator/accumulator live in VMEM scratch across
kv steps.  Block sizes are MXU-aligned (128).  This is the serving-path
hot spot (32k prefill); the pure-jnp chunked path in models/attention.py
is the baseline it replaces on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  blk_q: int, blk_k: int, n_k: int, seq_len: int,
                  causal: bool, scale: float):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (blk_q, hd)
    k = k_ref[0]                                   # (blk_k, hd)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 0)
    kpos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 1)
    valid = kpos < seq_len
    if causal:
        valid &= kpos <= qpos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                            # (blk_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = True):
    """q,k,v: (B,H,S,hd) (same H; GQA callers repeat kv heads upstream)."""
    B, H, S, hd = q.shape
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    pad = (-S) % max(blk_q, blk_k)
    if pad:
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(x, padw) for x in (q, k, v))
    Sp = S + pad
    n_q, n_k = Sp // blk_q, Sp // blk_k
    scale = 1.0 / float(hd) ** 0.5
    qf = q.reshape(B * H, Sp, hd)
    kf = k.reshape(B * H, Sp, hd)
    vf = v.reshape(B * H, Sp, hd)
    kern = functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                             n_k=n_k, seq_len=S, causal=causal, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :S].reshape(B, H, S, hd)
