"""Pallas TPU kernel: Scheme-C weighted client-delta aggregation.

out[d] = sum_k coeffs[k] * deltas[k, d]   (paper Eq. 2 hot loop)

The flattened parameter axis D is tiled into VMEM blocks; each grid step
loads a (K, BLK) tile of client deltas plus the (K,) coefficient vector and
reduces on-chip (one (1,K)x(K,BLK) MXU matmul per tile).  K (clients per
round) is small, so the tile streams at HBM bandwidth — this kernel turns
the aggregation from K separate scaled-add passes into one fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _agg_kernel(c_ref, d_ref, o_ref):
    c = c_ref[...].astype(jnp.float32)          # (1, K)
    d = d_ref[...].astype(jnp.float32)          # (K, BLK)
    o_ref[...] = jnp.dot(c, d,
                         preferred_element_type=jnp.float32)  # (1, BLK)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_agg(coeffs, deltas, *, block: int = DEFAULT_BLOCK,
                 interpret: bool = True):
    """coeffs: (K,) f32; deltas: (K, D) any float dtype -> (D,) f32."""
    K, D = deltas.shape
    pad = (-D) % block
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    Dp = D + pad
    out = pl.pallas_call(
        _agg_kernel,
        grid=(Dp // block,),
        in_specs=[
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(coeffs.reshape(1, K), deltas)
    return out[0, :D]
