"""Pallas TPU kernel: Scheme-C weighted client-delta aggregation.

out[d] = sum_k coeffs[k] * deltas[k, d]   (paper Eq. 2 hot loop)

The flattened parameter axis D is tiled into VMEM blocks; each grid step
loads a (K, BLK) tile of client deltas plus the (K,) coefficient vector and
reduces on-chip (one (1,K)x(K,BLK) MXU matmul per tile).  K (clients per
round) is small, so the tile streams at HBM bandwidth — this kernel turns
the aggregation from K separate scaled-add passes into one fused pass.

Two grid layouts:

  * single-block K (K <= MAX_SINGLE_K): grid (D/BLK,), the whole client
    axis is resident per tile — one matmul per output block.
  * tiled K (large federations): grid (D/BLK, K/KBLK); the client axis is
    streamed in KBLK slabs and accumulated into the revisited output block
    (init on k==0, += after), so VMEM stays bounded as K grows.

`interpret=None` auto-detects the backend: compiled Mosaic on TPU,
interpreter everywhere else (CPU CI containers).

For federations sharded over a mesh, `weighted_agg_sharded` runs one local
launch per device over its client slab and finishes with a cross-device
`psum` epilogue, so the reduced (D,) vector comes back replicated on every
device without a host round-trip.

For compressed client deltas (core/compression.py), `weighted_agg_quant`
fuses dequantization into the same reduction: each grid step loads a
(KBLK, BLK) int8 tile plus its per-chunk f32 scale slab, dequantizes in
VMEM and accumulates coeffs·deltas in f32 — the compressed payload never
materializes as an f32 (K, D) buffer in HBM.

The tile geometry (DEFAULT_BLOCK / MAX_SINGLE_K / DEFAULT_K_BLOCK) is
env-overridable via REPRO_AGG_BLOCK / REPRO_AGG_MAX_SINGLE_K /
REPRO_AGG_K_BLOCK for real-hardware re-tunes (see docs/engine.md).

Usage::

    out = weighted_agg(coeffs, deltas)                    # (K,),(K,D)->(D,)
    out = weighted_agg_sharded(coeffs, deltas, mesh=mesh) # client-sharded K
    out = weighted_agg_quant(coeffs, payload, scales, chunk=256)  # int8
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _env_int(name: str, default: int) -> int:
    """Tile-geometry override hook (REPRO_AGG_*): real-hardware re-tunes
    should not need code edits.  Read once at import."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        val = int(raw)
    except ValueError:
        raise ValueError(f"{name} must be an integer, got {raw!r}") from None
    if val < 1:
        raise ValueError(f"{name} must be >= 1, got {val}")
    return val


DEFAULT_BLOCK = _env_int("REPRO_AGG_BLOCK", 2048)
# Largest client axis kept fully resident per tile before switching to the
# streamed multi-block K layout.
MAX_SINGLE_K = _env_int("REPRO_AGG_MAX_SINGLE_K", 64)
DEFAULT_K_BLOCK = _env_int("REPRO_AGG_K_BLOCK", 32)


def resolve_interpret(interpret):
    """None -> interpret only off-TPU (compiled Mosaic on real hardware)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _agg_kernel(c_ref, d_ref, o_ref):
    c = c_ref[...].astype(jnp.float32)          # (1, K)
    d = d_ref[...].astype(jnp.float32)          # (K, BLK)
    o_ref[...] = jnp.dot(c, d,
                         preferred_element_type=jnp.float32)  # (1, BLK)


def _agg_kernel_ktiled(c_ref, d_ref, o_ref):
    k = pl.program_id(1)
    part = jnp.dot(c_ref[...].astype(jnp.float32),     # (1, KBLK)
                   d_ref[...].astype(jnp.float32),     # (KBLK, BLK)
                   preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k > 0)
    def _accumulate():
        o_ref[...] += part


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "k_block"))
def weighted_agg(coeffs, deltas, *, block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None,
                 k_block: int | None = None):
    """coeffs: (K,) f32; deltas: (K, D) any float dtype -> (D,) f32.

    k_block=None picks the layout automatically (single-block K up to
    MAX_SINGLE_K, then DEFAULT_K_BLOCK slabs); pass an explicit k_block to
    force the streamed path.
    """
    interpret = resolve_interpret(interpret)
    K, D = deltas.shape
    pad = (-D) % block
    if pad:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    Dp = D + pad
    coeffs = coeffs.astype(jnp.float32)

    if k_block is None and K > MAX_SINGLE_K:
        k_block = DEFAULT_K_BLOCK

    if k_block is None or k_block >= K:
        out = pl.pallas_call(
            _agg_kernel,
            grid=(Dp // block,),
            in_specs=[
                pl.BlockSpec((1, K), lambda i: (0, 0)),
                pl.BlockSpec((K, block), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
            interpret=interpret,
        )(coeffs.reshape(1, K), deltas)
        return out[0, :D]

    # streamed K: zero-pad the client axis (zero coeff rows contribute 0)
    kpad = (-K) % k_block
    if kpad:
        coeffs = jnp.pad(coeffs, (0, kpad))
        deltas = jnp.pad(deltas, ((0, kpad), (0, 0)))
    Kp = K + kpad
    out = pl.pallas_call(
        _agg_kernel_ktiled,
        grid=(Dp // block, Kp // k_block),
        in_specs=[
            pl.BlockSpec((1, k_block), lambda i, k: (0, k)),
            pl.BlockSpec((k_block, block), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(coeffs.reshape(1, Kp), deltas)
    return out[0, :D]


def _agg_kernel_quant(c_ref, p_ref, s_ref, o_ref, *, chunk):
    """Fused dequant-and-reduce tile: int8 codes and their scale slab are
    loaded into VMEM, dequantized on-chip, and reduced into the revisited
    f32 output block — the compressed payload never exists as an f32
    (K, D) buffer in HBM."""
    k = pl.program_id(1)
    codes = p_ref[...].astype(jnp.float32)       # (KBLK, BLK) from int8
    scales = s_ref[...]                          # (KBLK, BLK // chunk)
    kblk, blk = codes.shape
    d = (codes.reshape(kblk, blk // chunk, chunk)
         * scales[:, :, None]).reshape(kblk, blk)
    part = jnp.dot(c_ref[...].astype(jnp.float32),     # (1, KBLK)
                   d, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k > 0)
    def _accumulate():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("chunk", "block", "interpret",
                                             "k_block"))
def weighted_agg_quant(coeffs, payload, scales, *,
                       chunk: int, block: int = DEFAULT_BLOCK,
                       interpret: bool | None = None,
                       k_block: int | None = None):
    """Fused dequant-and-reduce: coeffs (K,) f32, payload (K, Dp) int8,
    scales (K, Dp/chunk) f32 -> (Dp,) f32.

    Dp must already be a multiple of ``chunk`` (quantize_chunked pads);
    the caller slices the result back to the un-padded D.  The grid is
    always the streamed multi-block-K layout of _agg_kernel_ktiled —
    each step loads a (KBLK, BLK) int8 tile plus its (KBLK, BLK/chunk)
    scale slab, dequantizes in VMEM and accumulates coeffs·deltas in f32.
    ``block`` is rounded down to a chunk multiple so scale groups never
    straddle tiles.
    """
    interpret = resolve_interpret(interpret)
    K, Dp0 = payload.shape
    if Dp0 % chunk:
        raise ValueError(f"payload width {Dp0} not a multiple of the "
                         f"scale chunk {chunk} (quantize_chunked pads)")
    if scales.shape != (K, Dp0 // chunk):
        raise ValueError(f"scales shape {scales.shape} != "
                         f"{(K, Dp0 // chunk)}")
    block = max(chunk, block - block % chunk)
    pad = (-Dp0) % block
    if pad:
        payload = jnp.pad(payload, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)))
    Dp = Dp0 + pad
    coeffs = coeffs.astype(jnp.float32)

    if k_block is None:
        k_block = K if K <= MAX_SINGLE_K else DEFAULT_K_BLOCK
    k_block = min(k_block, K)
    kpad = (-K) % k_block                # zero coeff rows contribute 0
    if kpad:
        coeffs = jnp.pad(coeffs, (0, kpad))
        payload = jnp.pad(payload, ((0, kpad), (0, 0)))
        scales = jnp.pad(scales, ((0, kpad), (0, 0)))
    Kp = K + kpad

    out = pl.pallas_call(
        functools.partial(_agg_kernel_quant, chunk=chunk),
        grid=(Dp // block, Kp // k_block),
        in_specs=[
            pl.BlockSpec((1, k_block), lambda i, k: (0, k)),
            pl.BlockSpec((k_block, block), lambda i, k: (k, i)),
            pl.BlockSpec((k_block, block // chunk), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(coeffs.reshape(1, Kp), payload, scales)
    return out[0, :Dp0]


def _local_quant_agg_psum(coeffs, payload, scales, *, chunk, axes, block,
                          interpret, k_block):
    """Per-shard body of the quantized sharded path: the compressed slab
    is dequant-reduced locally, and only the f32 (D,) partial crosses
    devices in the psum epilogue — the byte win lands on the wire."""
    out = weighted_agg_quant(coeffs, payload, scales, chunk=chunk,
                             block=block, interpret=interpret,
                             k_block=k_block)
    return jax.lax.psum(out, axes)


def weighted_agg_quant_sharded(coeffs, payload, scales, *, chunk, mesh,
                               axis="data", block: int = DEFAULT_BLOCK,
                               interpret: bool | None = None,
                               k_block: int | None = None):
    """Cross-device weighted_agg_quant: coeffs (K,), payload (K, Dp) int8
    and scales (K, Dp/chunk) sharded over the federation ``axis`` of
    ``mesh`` on the client dim -> (Dp,) f32, replicated.

    Same contract as weighted_agg_sharded (composite axes, K must divide
    the shard count), but each device launches the fused dequant-and-
    reduce kernel over its compressed client slab before the f32 psum.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    K = payload.shape[0]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if K % n:
        raise ValueError(
            f"client axis {K} not divisible by mesh axes {axes!r}={n}; "
            f"pad the client axis (FedSharding.pad_capacity)")
    entry = axes[0] if len(axes) == 1 else axes
    local = functools.partial(
        _local_quant_agg_psum, chunk=chunk, axes=axes, block=block,
        interpret=resolve_interpret(interpret), k_block=k_block)
    # check_rep=False: shard_map has no replication rule for pallas_call
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(entry), P(entry, None), P(entry, None)),
                   out_specs=P(), check_rep=False)
    return fn(coeffs, payload, scales)


def _local_agg_psum(coeffs, deltas, *, axes, block, interpret, k_block):
    """Per-shard body: reduce the local client slab with one (possibly
    K-tiled) launch, then all-reduce partial sums across the mesh."""
    out = weighted_agg(coeffs, deltas, block=block, interpret=interpret,
                       k_block=k_block)
    return jax.lax.psum(out, axes)


def weighted_agg_sharded(coeffs, deltas, *, mesh, axis="data",
                         block: int = DEFAULT_BLOCK,
                         interpret: bool | None = None,
                         k_block: int | None = None):
    """Cross-device weighted_agg: coeffs (K,) and deltas (K, D) sharded
    over ``axis`` of ``mesh`` on the client dim -> (D,) f32, replicated
    over the federation axes.

    ``axis`` names the federation axis — a single mesh axis (``'data'``)
    or a tuple of axes (``('pod', 'data')``) for composite multi-pod
    federations; the client dim then shards over their product.  Each
    device makes one local launch over its (K / n_shards, D) slab — the
    same single-block/K-tiled layout choice as weighted_agg, applied to
    the local K — followed by a ``psum`` epilogue over exactly the
    federation axes: the flat delta reduction produces global params with
    a single all-reduce and no host round-trip.  Mesh axes *not* named
    (e.g. ``'model'``) are untouched — each of their shard groups runs
    the same reduction, so downstream code may constrain the result back
    to a model-sharded layout.  K must divide evenly over the federation
    axes (the engine pads capacity so it always does).
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    K = deltas.shape[0]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if K % n:
        raise ValueError(
            f"client axis {K} not divisible by mesh axes {axes!r}={n}; "
            f"pad the client axis (FedSharding.pad_capacity)")
    entry = axes[0] if len(axes) == 1 else axes
    local = functools.partial(
        _local_agg_psum, axes=axes, block=block,
        interpret=resolve_interpret(interpret), k_block=k_block)
    # check_rep=False: shard_map has no replication rule for pallas_call
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(entry), P(entry, None)),
                   out_specs=P(), check_rep=False)
    return fn(coeffs, deltas)
