"""jit'd public wrappers over the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python per block — bit-exact semantics, no TPU).  On a real TPU
set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.masked_sgd import masked_sgd as _masked_sgd
from repro.kernels.ssd_chunk import ssd_intra_chunk as _ssd_intra
from repro.kernels.weighted_agg import weighted_agg as _weighted_agg

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def weighted_agg(coeffs, deltas, *, block=2048, interpret=None):
    return _weighted_agg(coeffs, deltas, block=block,
                         interpret=INTERPRET if interpret is None else interpret)


def weighted_agg_tree(params, deltas_tree, coeffs, *, interpret=None):
    """Aggregate a stacked-client pytree via the fused kernel:
    new_w = w + weighted_agg(coeffs, flatten(deltas))."""
    leaves, treedef = jax.tree.flatten(deltas_tree)
    p_leaves = jax.tree.leaves(params)
    outs = []
    for p, d in zip(p_leaves, leaves):
        K = d.shape[0]
        flat = d.reshape(K, -1)
        agg = weighted_agg(coeffs, flat, interpret=interpret)
        outs.append((p.astype(jnp.float32).reshape(-1) + agg)
                    .reshape(p.shape).astype(p.dtype))
    return jax.tree.unflatten(jax.tree.structure(params), outs)


def masked_sgd(w, g, eta_alpha, *, block=4096, interpret=None):
    return _masked_sgd(w, g, jnp.asarray(eta_alpha),
                       block=block,
                       interpret=INTERPRET if interpret is None else interpret)


def ssd_intra_chunk(cum, C, B, xdt, *, interpret=None):
    """Mamba2 SSD intra-chunk dual.  cum: (G,Q); C,B: (G,Q,N);
    xdt: (G,Q,P) -> (G,Q,P) f32."""
    return _ssd_intra(cum, C, B, xdt,
                      interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128,
                    interpret=None):
    """q: (B,H,S,hd); k,v: (B,KV,S,hd) — kv heads repeated to H if GQA."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _flash(q, k, v, causal=causal, blk_q=blk_q, blk_k=blk_k,
                  interpret=INTERPRET if interpret is None else interpret)
