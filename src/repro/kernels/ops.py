"""jit'd public wrappers over the Pallas kernels.

Backend auto-detection: with no override, kernels compile to Mosaic on TPU
and fall back to interpret mode everywhere else (the kernel body executes
via the Pallas interpreter — bit-exact semantics, no TPU required).
Set REPRO_PALLAS_INTERPRET=0/1 to force either mode globally, or pass
interpret= per call.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.masked_sgd import masked_sgd as _masked_sgd
from repro.kernels.ssd_chunk import ssd_intra_chunk as _ssd_intra
from repro.kernels.weighted_agg import resolve_interpret
from repro.kernels.weighted_agg import weighted_agg as _weighted_agg
from repro.kernels.weighted_agg import (weighted_agg_quant as
                                        _weighted_agg_quant)
from repro.kernels.weighted_agg import (weighted_agg_quant_sharded as
                                        _weighted_agg_quant_sharded)
from repro.kernels.weighted_agg import (weighted_agg_sharded as
                                        _weighted_agg_sharded)

_ENV = os.environ.get("REPRO_PALLAS_INTERPRET")
# None = auto (backend-aware); otherwise forced by the environment.
INTERPRET = None if _ENV is None else _ENV != "0"


def _interp(interpret):
    """Per-call override > env override > backend auto-detection."""
    if interpret is not None:
        return bool(interpret)
    return resolve_interpret(INTERPRET)


def weighted_agg(coeffs, deltas, *, block=2048, interpret=None,
                 k_block=None):
    return _weighted_agg(coeffs, deltas, block=block,
                         interpret=_interp(interpret), k_block=k_block)


def weighted_agg_sharded(coeffs, deltas, *, mesh, axis="data", block=2048,
                         interpret=None, k_block=None):
    """weighted_agg over a mesh-sharded client axis: one local launch per
    device + a psum epilogue -> (D,) replicated on every device."""
    return _weighted_agg_sharded(coeffs, deltas, mesh=mesh, axis=axis,
                                 block=block, interpret=_interp(interpret),
                                 k_block=k_block)


def weighted_agg_quant(coeffs, payload, scales, *, chunk, block=2048,
                       interpret=None, k_block=None):
    """Fused dequant-and-reduce over int8 payload + per-chunk f32 scales
    (core.compression.quantize_chunked layout) -> (Dp,) f32."""
    return _weighted_agg_quant(coeffs, payload, scales, chunk=chunk,
                               block=block, interpret=_interp(interpret),
                               k_block=k_block)


def weighted_agg_quant_sharded(coeffs, payload, scales, *, chunk, mesh,
                               axis="data", block=2048, interpret=None,
                               k_block=None):
    """weighted_agg_quant over a mesh-sharded client axis: one local
    dequant-and-reduce launch per device + an f32 psum epilogue."""
    return _weighted_agg_quant_sharded(
        coeffs, payload, scales, chunk=chunk, mesh=mesh, axis=axis,
        block=block, interpret=_interp(interpret), k_block=k_block)


def weighted_agg_tree(params, deltas_tree, coeffs, *, interpret=None):
    """Aggregate a stacked-client pytree leaf-by-leaf via the fused kernel
    (one launch per leaf).  The single-launch whole-model path is
    core.aggregation.aggregate_deltas_flat."""
    leaves, treedef = jax.tree.flatten(deltas_tree)
    p_leaves = jax.tree.leaves(params)
    outs = []
    for p, d in zip(p_leaves, leaves):
        K = d.shape[0]
        flat = d.reshape(K, -1)
        agg = weighted_agg(coeffs, flat, interpret=interpret)
        outs.append((p.astype(jnp.float32).reshape(-1) + agg)
                    .reshape(p.shape).astype(p.dtype))
    return jax.tree.unflatten(jax.tree.structure(params), outs)


def masked_sgd(w, g, eta_alpha, *, block=4096, interpret=None):
    return _masked_sgd(w, g, jnp.asarray(eta_alpha),
                       block=block, interpret=_interp(interpret))


def ssd_intra_chunk(cum, C, B, xdt, *, interpret=None):
    """Mamba2 SSD intra-chunk dual.  cum: (G,Q); C,B: (G,Q,N);
    xdt: (G,Q,P) -> (G,Q,P) f32."""
    return _ssd_intra(cum, C, B, xdt, interpret=_interp(interpret))


def flash_attention(q, k, v, *, causal=True, blk_q=128, blk_k=128,
                    interpret=None):
    """q: (B,H,S,hd); k,v: (B,KV,S,hd) — kv heads repeated to H if GQA."""
    H, KV = q.shape[1], k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _flash(q, k, v, causal=causal, blk_q=blk_q, blk_k=blk_k,
                  interpret=_interp(interpret))
