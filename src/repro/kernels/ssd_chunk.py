"""Pallas TPU kernel: SSD intra-chunk "attention dual" (Mamba2, arXiv:
2405.21060 §5).

For one chunk (per batch x chunk x head grid cell):

    L[i,j]   = exp(cum[i] - cum[j])        for j <= i, else 0
    scores   = (C @ B^T) * L               (Q, Q)
    Y_intra  = scores @ (x * dt)           (Q, P)

This is the MXU-heavy inner loop of the chunked SSD scan
(models/ssd.ssd_chunked).  One grid cell holds the full (Q,N), (Q,Q),
(Q,P) working set in VMEM (Q<=256, N<=128, P<=64 -> ~0.5 MB), with two
MXU matmuls per cell.  The inter-chunk state recurrence stays in jnp
(it is O(S/Q) sequential and tiny).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(cum_ref, c_ref, b_ref, x_ref, o_ref):
    cum = cum_ref[0]                                   # (Q,)
    C = c_ref[0]                                       # (Q, N)
    B = b_ref[0]                                       # (Q, N)
    xdt = x_ref[0]                                     # (Q, P)
    Q = cum.shape[0]
    s = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    diff = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iota_j <= iota_i, jnp.exp(diff), 0.0)
    s = s * L
    o_ref[0] = jnp.dot(s.astype(xdt.dtype), xdt,
                       preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(cum, C, B, xdt, *, interpret: bool = True):
    """cum: (G_cells, Q); C, B: (G_cells, Q, N); xdt: (G_cells, Q, P).

    G_cells = batch * n_chunks * n_heads (caller flattens; group-shared
    B/C are expanded to per-head).  Returns (G_cells, Q, P) float32.
    """
    G_cells, Q, N = C.shape
    P = xdt.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(G_cells,),
        in_specs=[
            pl.BlockSpec((1, Q), lambda g: (g, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G_cells, Q, P), jnp.float32),
        interpret=interpret,
    )(cum, C, B, xdt)
