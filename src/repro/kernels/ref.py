"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg_ref(coeffs, deltas):
    """(K,), (K,D) -> (D,) f32."""
    return jnp.einsum("k,kd->d", coeffs.astype(jnp.float32),
                      deltas.astype(jnp.float32))


def weighted_agg_quant_ref(coeffs, payload, scales, *, chunk):
    """(K,), (K,Dp) int8, (K,Dp/chunk) f32 -> (Dp,) f32: dequantize then
    reduce — the allclose target for the fused dequant-and-reduce kernel."""
    K, Dp = payload.shape
    deltas = (payload.astype(jnp.float32).reshape(K, Dp // chunk, chunk)
              * scales[..., None]).reshape(K, Dp)
    return weighted_agg_ref(coeffs, deltas)


def masked_sgd_ref(w, g, eta_alpha):
    return (w.astype(jnp.float32)
            - eta_alpha.astype(jnp.float32) * g.astype(jnp.float32)
            ).astype(w.dtype)


def ssd_intra_chunk_ref(cum, C, B, xdt):
    """(G,Q), (G,Q,N), (G,Q,N), (G,Q,P) -> (G,Q,P) f32."""
    cum = cum.astype(jnp.float32)
    Q = cum.shape[-1]
    diff = cum[:, :, None] - cum[:, None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    s = jnp.einsum("gqn,gsn->gqs", C.astype(jnp.float32),
                   B.astype(jnp.float32)) * L
    return jnp.einsum("gqs,gsp->gqp", s, xdt.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B,H,S,hd) -> (B,H,S,hd); plain softmax attention."""
    S = q.shape[2]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)
