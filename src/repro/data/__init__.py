from repro.data.images import (iid_partition, label_sorted_partition,
                               make_class_dataset)
from repro.data.synthetic import synthetic_federation
from repro.data.tokens import fed_lm_batches

__all__ = [
    "iid_partition",
    "label_sorted_partition",
    "make_class_dataset",
    "synthetic_federation",
    "fed_lm_batches",
]
