"""Synthetic non-IID token streams for the LM architectures.

Each federated client draws from a Zipf distribution over the vocab through
a client-specific permutation seeded by its "domain" — clients in the same
domain share token statistics (IID within, non-IID across), mirroring the
label-sorted image partition at LM scale.
"""
from __future__ import annotations

import numpy as np


def client_token_stream(rng: np.random.Generator, vocab: int, domain: int,
                        n_tokens: int, zipf_a: float = 1.2):
    perm_rng = np.random.default_rng(domain)
    perm = perm_rng.permutation(vocab)
    raw = rng.zipf(zipf_a, size=n_tokens)
    return perm[np.clip(raw, 1, vocab) - 1].astype(np.int32)


def fed_lm_batches(rng: np.random.Generator, *, vocab: int, n_clients: int,
                   local_epochs: int, batch: int, seq: int,
                   n_domains: int = 4, codebooks: int = 0):
    """One round of batches: tokens/labels (C, E, b, S[, K])."""
    shape_tail = (codebooks,) if codebooks else ()
    toks = np.empty((n_clients, local_epochs, batch, seq + 1) + shape_tail,
                    np.int32)
    for c in range(n_clients):
        dom = c % n_domains
        n_tok = local_epochs * batch * (seq + 1) * max(1, codebooks)
        stream = client_token_stream(rng, vocab, dom, n_tok)
        toks[c] = stream.reshape((local_epochs, batch, seq + 1) + shape_tail)
    return {"tokens": toks[:, :, :, :-1], "labels": toks[:, :, :, 1:]}
