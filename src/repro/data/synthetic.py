"""SYNTHETIC(alpha, beta) dataset (Li et al. 2018, as used in paper §5.1).

alpha controls how much local models differ; beta controls how much local
data distributions differ.  (0,0) ~ IID; (1,1) ~ strongly non-IID.
"""
from __future__ import annotations

import numpy as np

N_FEATURES = 60
N_CLASSES = 10


def synthetic_client(rng: np.random.Generator, alpha: float, beta: float,
                     n_samples: int):
    """One client's (x, y)."""
    u = rng.normal(0.0, alpha)
    Bk = rng.normal(0.0, beta)
    W = rng.normal(u, 1.0, size=(N_FEATURES, N_CLASSES))
    b = rng.normal(u, 1.0, size=(N_CLASSES,))
    v = rng.normal(Bk, 1.0, size=(N_FEATURES,))
    sigma = np.diag(np.arange(1, N_FEATURES + 1, dtype=np.float64) ** -1.2)
    x = rng.multivariate_normal(v, sigma, size=n_samples)
    logits = x @ W + b
    y = np.argmax(logits, axis=1)
    return x.astype(np.float32), y.astype(np.int32)


def synthetic_federation(alpha: float, beta: float, n_clients: int,
                         seed: int = 0, pareto_index: float = 0.5,
                         min_samples: int = 40, max_samples: int = 500):
    """Per-client datasets with Type-I-Pareto sample counts (paper §5.1)."""
    rng = np.random.default_rng(seed)
    raw = rng.pareto(pareto_index, size=n_clients) + 1.0
    counts = np.clip((raw * min_samples).astype(int), min_samples,
                     max_samples)
    clients = [synthetic_client(rng, alpha, beta, int(c) + 20)
               for c in counts]
    # split train/holdout (last 20 samples are the holdout)
    train = [(x[:-20], y[:-20]) for x, y in clients]
    test = [(x[-20:], y[-20:]) for x, y in clients]
    return train, test
