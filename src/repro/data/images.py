"""Pseudo-MNIST / pseudo-EMNIST.

Real MNIST/EMNIST are not available in this offline container; we substitute
seeded class-prototype images (28x28, one prototype per class + Gaussian
pixel noise + random affine-ish jitter via prototype mixing).  The federated
structure (label-sorted non-IID partition, Pareto sample counts) follows the
paper exactly; absolute accuracies are not comparable to the paper but the
*relative* scheme orderings are (EXPERIMENTS.md §Paper).
"""
from __future__ import annotations

import numpy as np


def make_class_dataset(n_classes: int, n_per_class: int, shape=(28, 28),
                       noise: float = 0.35, seed: int = 0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, size=(n_classes, *shape)).astype(np.float32)
    # low-pass the prototypes a little so classes are learnable but not trivial
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, axis=1)
                  + np.roll(protos, 1, axis=2)) / 3.0
    xs, ys = [], []
    for c in range(n_classes):
        base = protos[c]
        mix = protos[(c + 1) % n_classes]
        lam = rng.uniform(0.0, 0.25, size=(n_per_class, 1, 1)).astype(np.float32)
        x = (1 - lam) * base + lam * mix
        x = x + rng.normal(0.0, noise, size=(n_per_class, *shape)).astype(np.float32)
        xs.append(x)
        ys.append(np.full(n_per_class, c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    idx = rng.permutation(len(x))
    return x[idx], y[idx]


def label_sorted_partition(x, y, n_clients: int, labels_per_client: int = 1,
                           seed: int = 0, pareto_index: float = 0.5,
                           min_samples: int = 50, holdout: int = 20):
    """Paper §5.1: sort by label; each device gets data from
    `labels_per_client` labels chosen uniformly at random; sample counts
    follow Type-I Pareto(0.5)."""
    rng = np.random.default_rng(seed)
    by_label = {c: np.nonzero(y == c)[0].tolist() for c in np.unique(y)}
    raw = rng.pareto(pareto_index, size=n_clients) + 1.0
    counts = np.clip((raw * min_samples).astype(int), min_samples, 400)
    train, test = [], []
    classes = list(by_label.keys())
    for k in range(n_clients):
        labs = rng.choice(classes, size=labels_per_client, replace=False)
        idxs = []
        need = counts[k] + holdout
        per = -(-need // labels_per_client)
        for lab in labs:
            pool = by_label[int(lab)]
            take = [pool[i % len(pool)] for i in
                    rng.integers(0, len(pool), size=per)]
            idxs.extend(take)
        idxs = np.array(idxs[:need])
        train.append((x[idxs[:-holdout]], y[idxs[:-holdout]]))
        test.append((x[idxs[-holdout:]], y[idxs[-holdout:]]))
    return train, test


def iid_partition(x, y, n_clients: int, seed: int = 0,
                  pareto_index: float = 0.5, min_samples: int = 50,
                  holdout: int = 20):
    rng = np.random.default_rng(seed)
    raw = rng.pareto(pareto_index, size=n_clients) + 1.0
    counts = np.clip((raw * min_samples).astype(int), min_samples, 400)
    train, test = [], []
    for k in range(n_clients):
        idxs = rng.integers(0, len(x), size=counts[k] + holdout)
        train.append((x[idxs[:-holdout]], y[idxs[:-holdout]]))
        test.append((x[idxs[-holdout:]], y[idxs[-holdout:]]))
    return train, test
