from repro.checkpoint.io import (CorruptCheckpointError, load_checkpoint,
                                 load_fed_checkpoint, save_checkpoint,
                                 save_fed_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint",
           "save_fed_checkpoint", "load_fed_checkpoint",
           "CorruptCheckpointError"]
