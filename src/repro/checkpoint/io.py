"""Pytree + control-plane checkpointing: flattened-key npz + json manifest.

Two layers:

  * ``save_checkpoint``/``load_checkpoint`` — the original params-only
    format (flattened dict-pytree npz + manifest), unchanged;
  * ``save_fed_checkpoint``/``load_fed_checkpoint`` — a federation run's
    full restart state: params plus the event-sourced ``FedState`` dict
    (fed/state.py), the RoundRecord history and the engine geometry, so a
    killed streamed run resumes round-for-round
    (``StreamScheduler.save``/``restore``).  Plain-data structures are
    split by ``jsonify_tree`` into a JSON skeleton (manifest) and the
    numpy arrays it referenced (stored in the npz under ``blob/...``
    keys) — ``dejsonify_tree`` reassembles them exactly.

Durability contract (the robustness layer, see docs/robustness.md):

  * every write is atomic — payloads land in a ``*.tmp`` sibling, are
    fsynced, then ``os.replace``d over the canonical name, so a mid-write
    kill leaves either the previous checkpoint or the new one, never a
    truncated npz;
  * the manifest is the commit record: it is written (atomically) *after*
    the npz and carries that file's SHA-256, which ``load_fed_checkpoint``
    verifies — torn or bit-rotted checkpoints raise a clear
    ``CorruptCheckpointError`` instead of an opaque numpy/zip failure;
  * non-native leaf dtypes (bfloat16 &co. from ml_dtypes) are stored as
    unsigned-int views with their dtype name recorded in the manifest and
    restored bit-exactly on load (npz would silently return raw void).

Sharded arrays are gathered to host before save (fine for the simulation
scale; a production deployment would swap in per-shard writes keyed by
device index — the manifest format already records the spec strings)."""
from __future__ import annotations

import hashlib
import json
import os

import jax
import numpy as np

from repro.obs.telemetry import resolve as resolve_telemetry

_ARRAY_KEY = "__npz__"
_TUPLE_KEY = "__tuple__"


class CorruptCheckpointError(RuntimeError):
    """The on-disk checkpoint is unreadable or fails its manifest
    checksum (torn write, bitrot, truncation)."""


# -- durability helpers --------------------------------------------------------

def _fsync_dir(path: str) -> None:
    """Best-effort fsync of the containing directory so the rename itself
    is durable (not available on every platform/filesystem)."""
    try:
        fd = os.open(os.path.dirname(os.path.abspath(path)) or ".",
                     os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_savez(path: str, arrays: dict, injector=None) -> str:
    """Write an npz atomically (tmp + fsync + os.replace) and return its
    SHA-256.  ``injector`` is the fault-injection hook (fed/faults.py):
    an injected write failure raises after the payload was staged but
    before the rename — the canonical file is never torn."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
            if injector is not None:
                injector.fire("ckpt_save", path=path)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _fsync_dir(path)
    return _sha256_file(path)


def _atomic_write_text(path: str, text: str) -> None:
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _fsync_dir(path)


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


# -- non-native dtypes (bfloat16 &co.) ----------------------------------------

_UINT_BY_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode_arrays(arrays: dict):
    """npz round-trips native numpy dtypes only; ml_dtypes leaves (e.g.
    bfloat16 params) come back as raw void.  Store them as unsigned-int
    views and record the true dtype name for bit-exact decoding."""
    out, dtypes = {}, {}
    for k, a in arrays.items():
        if a.dtype.kind in "biufcSU":
            out[k] = a
        else:
            out[k] = a.view(_UINT_BY_ITEMSIZE[a.dtype.itemsize])
            dtypes[k] = str(a.dtype)
    return out, dtypes


def _decode_arrays(arrays: dict, dtypes: dict) -> dict:
    for k, name in (dtypes or {}).items():
        if k in arrays:
            try:
                dt = np.dtype(name)
            except TypeError:
                import ml_dtypes  # noqa: F401  (registers bfloat16 &co.)
                dt = np.dtype(name)
            arrays[k] = arrays[k].view(dt)
    return arrays


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat):
    tree = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def jsonify_tree(obj, arrays: dict, prefix: str = "blob"):
    """Split a plain-data structure (dicts/lists/tuples/scalars/ndarrays)
    into a JSON-able skeleton + extracted arrays.  Each ndarray leaf is
    replaced by ``{"__npz__": key}`` and stored in ``arrays`` under that
    key; tuples are tagged so they round-trip as tuples."""
    if isinstance(obj, np.ndarray):
        key = f"{prefix}/{len(arrays)}"
        arrays[key] = obj
        return {_ARRAY_KEY: key}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, dict):
        for k in obj:
            if not isinstance(k, str):
                # str(k) would silently come back str-keyed from the
                # round trip; reject so callers encode (FedState stores
                # int-keyed maps as sorted item lists for this reason)
                raise TypeError(f"jsonify_tree: dict keys must be str, "
                                f"got {k!r}")
        return {k: jsonify_tree(v, arrays, prefix)
                for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUPLE_KEY: [jsonify_tree(v, arrays, prefix) for v in obj]}
    if isinstance(obj, list):
        return [jsonify_tree(v, arrays, prefix) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"jsonify_tree: unsupported type {type(obj)!r}")


def dejsonify_tree(obj, arrays: dict):
    """Inverse of jsonify_tree: re-inline the extracted arrays."""
    if isinstance(obj, dict):
        if set(obj) == {_ARRAY_KEY}:
            return arrays[obj[_ARRAY_KEY]]
        if set(obj) == {_TUPLE_KEY}:
            return tuple(dejsonify_tree(v, arrays)
                         for v in obj[_TUPLE_KEY])
        return {k: dejsonify_tree(v, arrays) for k, v in obj.items()}
    if isinstance(obj, list):
        return [dejsonify_tree(v, arrays) for v in obj]
    return obj


def save_checkpoint(path: str, params, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "extra": extra or {},
    }
    enc, dtypes = _encode_arrays(arrays)
    sha = _atomic_savez(os.path.join(path, "params.npz"), enc)
    manifest["array_dtypes"] = dtypes
    manifest["npz_sha256"] = sha
    _atomic_write_text(os.path.join(path, "manifest.json"),
                       json.dumps(manifest, indent=2))


def load_checkpoint(path: str, verify: bool = True):
    manifest = _read_manifest(os.path.join(path, "manifest.json"))
    npz = os.path.join(path, "params.npz")
    if verify:
        _verify_npz(npz, manifest)
    flat = _decode_arrays(_read_npz(npz), manifest.get("array_dtypes"))
    return _unflatten(flat), manifest


def _read_manifest(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        raise CorruptCheckpointError(
            f"unreadable checkpoint manifest {path!r}: {e}") from e


def _read_npz(path: str) -> dict:
    try:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise
    except Exception as e:   # zipfile/npy format errors on torn files
        raise CorruptCheckpointError(
            f"unreadable checkpoint payload {path!r}: {e}") from e


def _verify_npz(path: str, manifest: dict) -> None:
    """Checksum gate: a checkpoint whose npz bytes do not match the
    manifest's recorded SHA-256 is corrupt (manifests written before the
    checksum era carry no hash and skip the check)."""
    want = manifest.get("npz_sha256")
    if want is None:
        return
    try:
        got = _sha256_file(path)
    except OSError as e:
        raise CorruptCheckpointError(
            f"unreadable checkpoint payload {path!r}: {e}") from e
    if got != want:
        raise CorruptCheckpointError(
            f"checkpoint payload {path!r} fails its manifest checksum "
            f"(expected sha256 {want[:12]}…, got {got[:12]}…): torn "
            f"write or bitrot — restore from an older snapshot")


# -- federation-run checkpoints (params + FedState + history) ------------------

def save_fed_checkpoint(path: str, params, state: dict, *,
                        history: dict = None, config: dict = None,
                        extra: dict = None, injector=None,
                        telemetry=None,
                        client_chunks: bool = False) -> None:
    """Persist a federation run's complete restart state.

    ``state`` is FedState.to_dict() (plain data + ndarrays; the pending
    event queue — including brand-new Arrival client payloads — and the
    RNG/key state ride along); ``history`` is the columnar RoundRecord
    dict (fed/stream.history_to_dict); ``config`` the engine geometry
    (StreamScheduler.engine_config).  One npz carries the param leaves
    (``params/...``) plus every extracted state/history array
    (``blob/...``); the manifest holds the JSON skeletons, the npz
    SHA-256 and the true dtype of every non-native (bf16) leaf.

    ``client_chunks=True`` (the bank-scale format, fed-checkpoint-v2)
    writes each client's payload as its own ``clients/client-<id>.npz``
    — streamed one client at a time, so a >=GB fleet never materializes
    twice — with a per-chunk SHA-256 recorded in the manifest.  The
    commit order is unchanged: chunks, then the main npz, then the
    manifest, each atomic — a kill at any byte leaves the previous
    checkpoint loadable, and every chunk is checksummed on load.

    Both files are written atomically (tmp + fsync + rename), npz first —
    the manifest is the commit record, so a kill at any byte leaves the
    previous checkpoint loadable.  ``injector`` is the fault hook
    (fed/faults.py): injected write failures raise before the rename,
    injected corruption flips bytes after it (caught at load time)."""
    tel = resolve_telemetry(telemetry)
    with tel.span("ckpt.save", path=path):
        os.makedirs(path, exist_ok=True)
        chunk_recs = None
        chunk_bytes = 0
        if client_chunks:
            state = dict(state)
            clients = state.pop("clients")
            chunk_dir = os.path.join(path, "clients")
            os.makedirs(chunk_dir, exist_ok=True)
            chunk_recs = []
            for idx, cdict in enumerate(clients):
                c_arrays: dict = {}
                skel = jsonify_tree(cdict, c_arrays, prefix="c")
                enc, dtypes = _encode_arrays(c_arrays)
                fname = f"client-{idx:08d}.npz"
                fpath = os.path.join(chunk_dir, fname)
                sha = _atomic_savez(fpath, enc, injector=injector)
                chunk_recs.append({"file": f"clients/{fname}",
                                   "skeleton": skel,
                                   "array_dtypes": dtypes,
                                   "sha256": sha})
                chunk_bytes += os.path.getsize(fpath)
            state["clients"] = []       # stored chunked; see manifest
        flat = _flatten(params)
        arrays = {f"params/{k}": np.asarray(jax.device_get(v))
                  for k, v in flat.items()}
        manifest = {
            "format": ("fed-checkpoint-v2" if client_chunks
                       else "fed-checkpoint-v1"),
            "state": jsonify_tree(state, arrays, prefix="blob/state"),
            "history": (jsonify_tree(history, arrays,
                                     prefix="blob/history")
                        if history is not None else None),
            "config": config or {},
            "extra": extra or {},
            "param_keys": sorted(flat),
        }
        if chunk_recs is not None:
            manifest["client_chunks"] = chunk_recs
        enc, dtypes = _encode_arrays(arrays)
        npz_path = os.path.join(path, "fed_checkpoint.npz")
        sha = _atomic_savez(npz_path, enc, injector=injector)
        manifest["array_dtypes"] = dtypes
        manifest["npz_sha256"] = sha
        _atomic_write_text(os.path.join(path, "fed_manifest.json"),
                           json.dumps(manifest, indent=2))
        if chunk_recs is not None:
            _prune_stale_chunks(os.path.join(path, "clients"),
                                len(chunk_recs))
        tel.counter("ckpt_saves_total",
                    "fed checkpoints written").inc()
        tel.counter("ckpt_save_bytes_total",
                    "npz bytes written by fed checkpoint saves").inc(
            os.path.getsize(npz_path) + chunk_bytes)
        if injector is not None:
            injector.fire("ckpt_written", path=npz_path)


def _prune_stale_chunks(chunk_dir: str, n_live: int) -> None:
    """Best-effort removal of chunk files beyond the committed count —
    left behind when a checkpoint is overwritten in place by a save with
    fewer clients (the loader only reads manifest-listed files, so this
    is hygiene, not correctness)."""
    try:
        names = os.listdir(chunk_dir)
    except OSError:
        return
    for name in names:
        if not (name.startswith("client-") and name.endswith(".npz")):
            continue
        try:
            idx = int(name[len("client-"):-len(".npz")])
        except ValueError:
            continue
        if idx >= n_live:
            try:
                os.unlink(os.path.join(chunk_dir, name))
            except OSError:
                pass


def load_fed_checkpoint(path: str, verify: bool = True, telemetry=None):
    """Returns (params, state_dict, history_dict, config, extra).

    Raises CorruptCheckpointError when the manifest is unreadable, the
    npz fails its recorded checksum, or the payload cannot be parsed —
    callers (the service supervisor) roll back to an older snapshot."""
    tel = resolve_telemetry(telemetry)
    npz_path = os.path.join(path, "fed_checkpoint.npz")
    with tel.span("ckpt.load", path=path):
        try:
            manifest = _read_manifest(
                os.path.join(path, "fed_manifest.json"))
            if manifest.get("format") not in ("fed-checkpoint-v1",
                                              "fed-checkpoint-v2"):
                raise CorruptCheckpointError(
                    f"not a fed checkpoint: {path!r} "
                    f"({manifest.get('format')!r})")
            if verify:
                _verify_npz(npz_path, manifest)
            arrays = _decode_arrays(_read_npz(npz_path),
                                    manifest.get("array_dtypes"))
            clients = None
            if manifest.get("format") == "fed-checkpoint-v2":
                # chunked fleet (bank-scale): one npz per client,
                # checksummed individually, streamed back one at a time
                clients = []
                for rec in manifest["client_chunks"]:
                    fpath = os.path.join(path, rec["file"])
                    if verify:
                        try:
                            got = _sha256_file(fpath)
                        except OSError as e:
                            raise CorruptCheckpointError(
                                f"unreadable client chunk {fpath!r}: "
                                f"{e}") from e
                        if got != rec["sha256"]:
                            raise CorruptCheckpointError(
                                f"client chunk {fpath!r} fails its "
                                f"manifest checksum: torn write or "
                                f"bitrot — restore an older snapshot")
                    c_arrays = _decode_arrays(_read_npz(fpath),
                                              rec.get("array_dtypes"))
                    clients.append(dejsonify_tree(rec["skeleton"],
                                                  c_arrays))
        except CorruptCheckpointError:
            tel.counter("ckpt_checksum_failures_total",
                        "fed checkpoint loads rejected as corrupt "
                        "(bad checksum / unreadable payload)").inc()
            raise
        params = _unflatten({k[len("params/"):]: v
                             for k, v in arrays.items()
                             if k.startswith("params/")})
        state = dejsonify_tree(manifest["state"], arrays)
        if clients is not None:
            state["clients"] = clients
        history = (dejsonify_tree(manifest["history"], arrays)
                   if manifest["history"] is not None else None)
        tel.counter("ckpt_loads_total",
                    "fed checkpoints loaded").inc()
        tel.counter("ckpt_load_bytes_total",
                    "npz bytes read by fed checkpoint loads").inc(
            os.path.getsize(npz_path))
    return params, state, history, manifest["config"], manifest["extra"]
