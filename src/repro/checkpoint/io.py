"""Pytree checkpointing: flattened-key npz + json manifest.

Sharded arrays are gathered to host before save (fine for the simulation
scale; a production deployment would swap in per-shard writes keyed by
device index — the manifest format already records the spec strings)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat):
    tree = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(path: str, params, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(path, "params.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "params.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat), manifest
