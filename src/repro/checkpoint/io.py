"""Pytree + control-plane checkpointing: flattened-key npz + json manifest.

Two layers:

  * ``save_checkpoint``/``load_checkpoint`` — the original params-only
    format (flattened dict-pytree npz + manifest), unchanged;
  * ``save_fed_checkpoint``/``load_fed_checkpoint`` — a federation run's
    full restart state: params plus the event-sourced ``FedState`` dict
    (fed/state.py), the RoundRecord history and the engine geometry, so a
    killed streamed run resumes round-for-round
    (``StreamScheduler.save``/``restore``).  Plain-data structures are
    split by ``jsonify_tree`` into a JSON skeleton (manifest) and the
    numpy arrays it referenced (stored in the npz under ``blob/...``
    keys) — ``dejsonify_tree`` reassembles them exactly.

Sharded arrays are gathered to host before save (fine for the simulation
scale; a production deployment would swap in per-shard writes keyed by
device index — the manifest format already records the spec strings)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np

_ARRAY_KEY = "__npz__"
_TUPLE_KEY = "__tuple__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat):
    tree = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def jsonify_tree(obj, arrays: dict, prefix: str = "blob"):
    """Split a plain-data structure (dicts/lists/tuples/scalars/ndarrays)
    into a JSON-able skeleton + extracted arrays.  Each ndarray leaf is
    replaced by ``{"__npz__": key}`` and stored in ``arrays`` under that
    key; tuples are tagged so they round-trip as tuples."""
    if isinstance(obj, np.ndarray):
        key = f"{prefix}/{len(arrays)}"
        arrays[key] = obj
        return {_ARRAY_KEY: key}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, dict):
        for k in obj:
            if not isinstance(k, str):
                # str(k) would silently come back str-keyed from the
                # round trip; reject so callers encode (FedState stores
                # int-keyed maps as sorted item lists for this reason)
                raise TypeError(f"jsonify_tree: dict keys must be str, "
                                f"got {k!r}")
        return {k: jsonify_tree(v, arrays, prefix)
                for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUPLE_KEY: [jsonify_tree(v, arrays, prefix) for v in obj]}
    if isinstance(obj, list):
        return [jsonify_tree(v, arrays, prefix) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"jsonify_tree: unsupported type {type(obj)!r}")


def dejsonify_tree(obj, arrays: dict):
    """Inverse of jsonify_tree: re-inline the extracted arrays."""
    if isinstance(obj, dict):
        if set(obj) == {_ARRAY_KEY}:
            return arrays[obj[_ARRAY_KEY]]
        if set(obj) == {_TUPLE_KEY}:
            return tuple(dejsonify_tree(v, arrays)
                         for v in obj[_TUPLE_KEY])
        return {k: dejsonify_tree(v, arrays) for k, v in obj.items()}
    if isinstance(obj, list):
        return [dejsonify_tree(v, arrays) for v in obj]
    return obj


def save_checkpoint(path: str, params, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(path, "params.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "params.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat), manifest


# -- federation-run checkpoints (params + FedState + history) ------------------

def save_fed_checkpoint(path: str, params, state: dict, *,
                        history: dict = None, config: dict = None,
                        extra: dict = None) -> None:
    """Persist a federation run's complete restart state.

    ``state`` is FedState.to_dict() (plain data + ndarrays; the pending
    event queue — including brand-new Arrival client payloads — and the
    RNG/key state ride along); ``history`` is the columnar RoundRecord
    dict (fed/stream.history_to_dict); ``config`` the engine geometry
    (StreamScheduler.engine_config).  One npz carries the param leaves
    (``params/...``) plus every extracted state/history array
    (``blob/...``); the manifest holds the JSON skeletons."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {f"params/{k}": np.asarray(jax.device_get(v))
              for k, v in flat.items()}
    manifest = {
        "format": "fed-checkpoint-v1",
        "state": jsonify_tree(state, arrays, prefix="blob/state"),
        "history": (jsonify_tree(history, arrays, prefix="blob/history")
                    if history is not None else None),
        "config": config or {},
        "extra": extra or {},
        "param_keys": sorted(flat),
    }
    np.savez(os.path.join(path, "fed_checkpoint.npz"), **arrays)
    with open(os.path.join(path, "fed_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_fed_checkpoint(path: str):
    """Returns (params, state_dict, history_dict, config, extra)."""
    with open(os.path.join(path, "fed_manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != "fed-checkpoint-v1":
        raise ValueError(f"not a fed checkpoint: {path!r} "
                         f"({manifest.get('format')!r})")
    with np.load(os.path.join(path, "fed_checkpoint.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    params = _unflatten({k[len("params/"):]: v
                         for k, v in arrays.items()
                         if k.startswith("params/")})
    state = dejsonify_tree(manifest["state"], arrays)
    history = (dejsonify_tree(manifest["history"], arrays)
               if manifest["history"] is not None else None)
    return params, state, history, manifest["config"], manifest["extra"]
