"""End-to-end serving driver: batched requests against a small model with
the production cache machinery (prefill + streaming decode).

Runs the REAL mamba2-130m configuration (130M params, attention-free SSD:
the O(1)-state decode makes CPU serving practical), plus a reduced GQA
model to exercise the ring-buffer path.

  PYTHONPATH=src python examples/serve_batched.py [--quick]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.models.params import init_params, param_count


def serve(cfg, batch, prompt_len, gen, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed)
    shp = ((batch, prompt_len, cfg.n_codebooks) if cfg.n_codebooks
           else (batch, prompt_len))
    prompts = jax.random.randint(key, shp, 0, cfg.vocab)
    decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(
        p, cfg, c, t, pos))

    t0 = time.time()
    cache = transformer.init_cache(cfg, batch, prompt_len + gen)
    logits, cache = transformer.prefill(params, cfg, prompts, cache)
    t_prefill = time.time() - t0

    tok_shape = ((batch, 1, cfg.n_codebooks) if cfg.n_codebooks
                 else (batch, 1))
    t0 = time.time()
    for i in range(gen):
        key, sk = jax.random.split(key)
        nxt = jax.random.categorical(sk, logits, axis=-1)
        nxt = nxt.reshape(tok_shape).astype(jnp.int32)
        logits, cache = decode(params, cache, nxt, jnp.int32(prompt_len + i))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    return param_count(params), t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    runs = [
        # (the paper's kind is training, but the serving substrate is a
        #  first-class deliverable: real 130M model, batched requests)
        ("mamba2-130m", False, 4, 32, 16) if args.quick else
        ("mamba2-130m", False, 8, 128, 64),
        ("starcoder2-3b", True, 4, 64, 32),   # reduced: ring-buffer SWA
        ("deepseek-v2-lite-16b", True, 4, 64, 32),  # reduced: MLA cache
    ]
    for arch, reduced, B, S, G in runs:
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        n, tp, td = serve(cfg, B, S, G)
        print(f"{arch:24s} ({'reduced' if reduced else 'FULL'}) "
              f"params={n:>12,}  prefill {B}x{S}: {tp:6.2f}s  "
              f"decode {B}x{G}: {td:6.2f}s "
              f"({B * G / td:7.1f} tok/s)")


if __name__ == "__main__":
    main()
