"""Streaming participation quickstart: feed events as they happen.

Unlike examples/flexible_participation.py — where every arrival/departure
is declared up front — this drives training through the StreamScheduler
and pushes participation events *between* spans, the way a real serving
stack learns about devices: nothing about the second half of the run is
known when training starts.

  PYTHONPATH=src python examples/streaming_quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import (Arrival, Client, Departure, InactivityBurst,
                       StreamScheduler, TraceShift)
from repro.fed.scenarios import summarize_history
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(ll, y[:, None].astype(jnp.int32), 1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def make_clients(n, seed):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 5)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def main():
    founding = make_clients(6, seed=0)
    sch = StreamScheduler(
        clients=founding,
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn,
        capacity=10,              # room for 4 devices we don't know yet
        max_samples=600,          # their datasets may be bigger than ours
        local_epochs=5, batch_size=10, scheme="C", eta0=1.0, seed=0)

    # span 1: just the founding fleet
    sch.run(8, eval_every=4)

    # news arrives: two brand-new devices want in (their data was never
    # seen by the engine — they are admitted into free capacity slots)
    for cl in make_clients(2, seed=100):
        sch.push(Arrival(tau=8, client=cl))
    sch.run(8, eval_every=4)

    # more news: a regional outage masks half the founding fleet for 3
    # rounds, and device 1's availability law degrades
    sch.push(InactivityBurst(tau=16, duration=3, client_ids=(0, 2, 4)))
    sch.push(TraceShift(tau=16, client_id=1, trace=TRACES[6]))
    sch.run(8, eval_every=4)

    # finally one of the newcomers churns out (Corollary 4.0.3 decides)
    sch.push(Departure(tau=24, client_id=6, policy="auto"))
    sch.run(8, eval_every=4)

    print("tau,loss,acc,eta,n_active,event")
    for h in sch.history:
        if h.event or np.isfinite(h.loss):
            print(f"{h.tau},{h.loss:.4f},{h.acc:.3f},{h.eta:.4f},"
                  f"{h.n_active},{h.event}")
    print()
    for k, v in summarize_history(sch.history).items():
        if k != "events":
            print(f"{k}: {v}")
    print(f"objective at end: {sorted(sch.objective)}; "
          f"free slots: {sorted(sch.free_slots)}")


if __name__ == "__main__":
    main()
