"""The paper's full event repertoire in one run (Figures 4-5 analogue):

  * rounds 0-29 : 8 founding devices, heterogeneous traces, Scheme C
  * round 30    : a new device ARRIVES -> objective shift + fast-reboot
                  (coefficient boost 3x decaying O(tau^-2), LR restart)
  * round 60    : a device DEPARTS -> Corollary 4.0.3 decides
                  include vs exclude from the remaining-time criterion

  PYTHONPATH=src python examples/flexible_participation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import SYNTHETIC_LR
from repro.core.departures import BoundTerms, crossing_round, should_exclude
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR
T_TOTAL = 120
TAU_ARRIVE = 30
TAU_DEPART = 60


def eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(ll, y[:, None].astype(jnp.int32), 1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def main():
    train, test = synthetic_federation(1.0, 1.0, 10, seed=1)
    rng = np.random.default_rng(1)
    clients = [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 5)],
                      x_test=te[0], y_test=te[1])
               for tr, te in zip(train, test)]
    clients[8].active_from = TAU_ARRIVE          # late arrival
    clients[3].departs_at = TAU_DEPART           # early departure

    # Corollary 4.0.3: decide include/exclude from the bound terms.
    terms = BoundTerms(D=5.0, V=20.0, gamma=10.0, E=5)
    gamma_l = 1.0  # non-IID contribution of the departing device (est.)
    exclude = should_exclude(T_TOTAL, TAU_DEPART, terms, gamma_l)
    clients[3].departure_policy = "exclude" if exclude else "include"
    print(f"departure policy by Cor. 4.0.3: "
          f"{clients[3].departure_policy} "
          f"(predicted crossing at +"
          f"{crossing_round(T_TOTAL, TAU_DEPART, terms, gamma_l)} rounds)")

    trainer = FederatedTrainer(
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn,
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        clients=clients, local_epochs=5, batch_size=20, scheme="C",
        eta0=1.0, reboot_boost=3.0, fast_reboot=True)
    hist = trainer.run(T_TOTAL, eval_every=2)

    print("\nround,loss,acc,eta,n_active,event")
    for h in hist:
        if h.event or h.tau % 10 == 0:
            print(f"{h.tau},{h.loss:.4f},{h.acc:.3f},{h.eta:.4f},"
                  f"{h.n_active},{h.event}")
    print(f"\nobjective set at end: {sorted(trainer.objective)}")
    print(f"LR restarts happened at tau={trainer.lr_shift_tau} (last)")


if __name__ == "__main__":
    main()
