"""Quickstart: federated training with flexible device participation.

Reproduces the paper's core loop in ~30 lines of user code: 20 clients
with heterogeneous participation traces, non-IID SYNTHETIC(1,1) data,
Scheme-C debiased aggregation.  Rounds run on the device-resident engine
(engine="device": datasets live on device, participation is sampled on
device, and many rounds run per host dispatch — see docs/engine.md).

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer
from repro.models.small import init_small, logits_small, make_loss_fn


def eval_fn(params, x, y):
    lg = logits_small(params, SYNTHETIC_LR, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(ll, y[:, None].astype(jnp.int32), 1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def main():
    train, test = synthetic_federation(alpha=1.0, beta=1.0, n_clients=20,
                                       seed=0)
    rng = np.random.default_rng(0)
    clients = [
        Client(x=tr[0], y=tr[1],
               trace=TRACES[rng.integers(0, 8)],  # all 8 device classes
               x_test=te[0], y_test=te[1])
        for tr, te in zip(train, test)
    ]
    trainer = FederatedTrainer(
        loss_fn=make_loss_fn(SYNTHETIC_LR),
        eval_fn=eval_fn,
        init_params=init_small(jax.random.PRNGKey(0), SYNTHETIC_LR),
        clients=clients,
        local_epochs=5, batch_size=20,
        scheme="C",          # the paper's debiased aggregation
        eta0=1.0,
        engine="device",     # fused on-device sampling + chunked rounds
        chunk_size=16,
    )
    t0 = time.perf_counter()
    hist = trainer.run(n_rounds=50, eval_every=5)
    dt = time.perf_counter() - t0
    for h in hist[::5]:   # eval rounds; others record loss/acc = NaN
        print(f"round {h.tau:3d}  loss {h.loss:.4f}  acc {h.acc:.3f}  "
              f"active {h.n_active}/20")
    loss, acc = trainer.evaluate()
    print(f"\nfinal accuracy: {acc:.3f}   ({50 / dt:.0f} rounds/sec "
          f"incl. compile)")


if __name__ == "__main__":
    main()
