"""Streaming-participation benchmark: events/sec absorbed and rounds/sec
under churn, vs an event-free baseline on the same capacity-slotted
engine.

Two costs matter for the streaming subsystem:

  * event absorption — admit(slot)/evict(slot) are one host->device
    transfer + dynamic-update-slice each; measured as µs per event and
    events/sec;
  * sustained churn — rounds/sec while a continuous stream of arrivals,
    departures, trace shifts and inactivity bursts keeps splitting spans
    and re-staging membership state, vs the same fleet with no events
    (span splitting is the only difference: the engine never rebuilds or
    recompiles across events).

Timing is best-of-k on a warm scheduler (compile excluded); emits
BENCH_stream.json next to BENCH_engine.json so the perf trajectory stays
machine-readable.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core.participation import TRACES
from repro.fed.scenarios import (build_scheduler, make_scenario,
                                 summarize_history, _make_clients)
from repro.fed.stream import Arrival, Departure, InactivityBurst, TraceShift

NO_EVAL = 10 ** 9


def _admit_evict_us(engine, client, iters: int = 30):
    """µs per admit / evict slot write (synchronous host cost)."""
    slot = engine.capacity - 1
    engine.admit(slot, client)            # warmup: compile the slot write
    engine.evict(slot)
    jax.block_until_ready(engine.s_cdf)
    t0 = time.perf_counter()
    for _ in range(iters):
        engine.admit(slot, client)
    jax.block_until_ready(engine.s_cdf)
    admit_us = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        engine.evict(slot)
    jax.block_until_ready(engine.s_cdf)
    evict_us = (time.perf_counter() - t0) / iters * 1e6
    return admit_us, evict_us


def _sync(engine):
    """Fence ALL buffers an admit mutates (data stacks + n + s_cdf).
    Fencing only s_cdf lets the data-buffer scatters of iteration i
    overlap iteration i+1's host staging, which flattered the
    single-admit path (its k dispatches pipeline against each other)."""
    jax.block_until_ready((engine.data, engine.n, engine.s_cdf))


def _admit_burst_us(engine, clients, iters: int = 10):
    """µs per admitted row when an arrival burst coalesces into one
    admit_many (ONE fused stacked device_put + multi-buffer scatter) vs
    the same rows via k single admits.  Each timed iteration is fenced
    on every mutated buffer and the median is reported, so async
    dispatch overlap can't fake a speedup in either direction."""
    k = len(clients)
    slots = list(range(engine.capacity - k, engine.capacity))
    pairs = list(zip(slots, clients))
    engine.admit_many(pairs)              # warmup: compile the scatter
    for slot, c in pairs:                 # warmup the single-admit path
        engine.admit(slot, c)
    _sync(engine)
    burst, single = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        engine.admit_many(pairs)
        _sync(engine)
        burst.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for slot, c in pairs:
            engine.admit(slot, c)
        _sync(engine)
        single.append(time.perf_counter() - t0)
    for slot, _ in pairs:
        engine.evict(slot)
    burst_us = float(np.median(burst)) / k * 1e6
    single_us = float(np.median(single)) / k * 1e6
    return burst_us, single_us


def _churn_events(tau0: int, span: int, next_id: int, rep: int):
    """One rep's worth of sustained churn: two brand-new arrivals that
    depart again inside the span (net slot balance zero), a trace shift
    and a cohort burst."""
    fresh = _make_clients(2, seed=5000 + rep)
    events = [
        Arrival(tau0 + 2, client=fresh[0]),
        Arrival(tau0 + 3, client=fresh[1]),
        Departure(tau0 + span - 4, client_id=next_id, policy="exclude"),
        Departure(tau0 + span - 3, client_id=next_id + 1,
                  policy="exclude"),
        TraceShift(tau0 + 5, client_id=0, trace=TRACES[(rep + 1) % 8]),
        InactivityBurst(tau0 + 8, 3, (1, 2)),
    ]
    return events, next_id + 2


def _rounds_per_sec(sch, span, reps, *, churn: bool):
    # warmup absorbs the scenario's own events and compiles the chunks;
    # the churned leg warms up with one full churn rep as well, because
    # churn splits spans into lengths the event-free warmup never
    # compiles — without it the first timed rep measures XLA, not churn
    sch.run(span, eval_every=NO_EVAL)
    next_id = len(sch.clients)
    if churn:
        events, next_id = _churn_events(sch._next_tau, span, next_id, 0)
        sch.push(*events)
        sch.run(span, eval_every=NO_EVAL)
    best = float("inf")
    for rep in range(1, reps + 1):
        if churn:
            events, next_id = _churn_events(sch._next_tau, span, next_id,
                                            rep)
            sch.push(*events)
        t0 = time.perf_counter()
        sch.run(span, eval_every=NO_EVAL)
        best = min(best, time.perf_counter() - t0)
    return span / best


def run(span=24, reps=10, seed=0, mode="device", chunk=16,
        compression=None):
    sc = make_scenario("flash-crowd", seed=seed)

    # event-free baseline: same fleet/capacity, no events ever.  Both
    # rounds/sec legs run eval-free: the scheduler force-evaluates every
    # event boundary (honest records), so leaving eval on would charge
    # evaluation — eval-set reconcat + a forward pass per event — to
    # "churn overhead" while the static leg never pays it.  The
    # scenario_replay section below keeps the real eval cadence.
    static = build_scheduler(
        make_scenario("flash-crowd", seed=seed), mode=mode,
        chunk_size=chunk, compression=compression)
    static.eval_fn = None
    static._queue.clear()
    rps_static = _rounds_per_sec(static, span, reps, churn=False)

    churned = build_scheduler(sc, mode=mode, chunk_size=chunk,
                              compression=compression)
    churned.eval_fn = None
    rps_churn = _rounds_per_sec(churned, span, reps, churn=True)

    admit_us, evict_us = _admit_evict_us(
        static.engine, _make_clients(1, seed=seed + 1)[0])
    cycle_us = admit_us + evict_us
    burst_k = min(4, static.engine.capacity)
    burst_us, burst_single_us = _admit_burst_us(
        static.engine, _make_clients(burst_k, seed=seed + 2))

    # one full scenario replay for the record (honest NaN-filtered summary)
    sch, summary = None, None
    t0 = time.perf_counter()
    sch = build_scheduler(make_scenario("flash-crowd", seed=seed),
                          mode=mode, chunk_size=chunk,
                          compression=compression)
    sch.run(sc.n_rounds, eval_every=sc.eval_every)
    scenario_wall = time.perf_counter() - t0
    summary = summarize_history(sch.history)
    summary.pop("events", None)

    out = {
        "config": {"scenario": "flash-crowd", "mode": mode, "span": span,
                   "reps": reps, "chunk_size": chunk,
                   "capacity": churned.engine.capacity,
                   "compression": churned.engine.compression.name,
                   "backend": jax.default_backend()},
        "rounds_per_sec": {"static": round(rps_static, 2),
                           "churn": round(rps_churn, 2)},
        "churn_overhead_fraction": round(
            max(0.0, 1.0 - rps_churn / rps_static), 4),
        "admit_us": round(admit_us, 1),
        "evict_us": round(evict_us, 1),
        "admit_burst_k": burst_k,
        "admit_burst_us_per_row": round(burst_us, 1),
        "admit_burst_single_us_per_row": round(burst_single_us, 1),
        "admit_burst_speedup": round(burst_single_us / burst_us, 2),
        "events_per_sec_absorbed": round(2e6 / cycle_us, 1),
        "scenario_replay": {"wall_s": round(scenario_wall, 3),
                            **summary},
    }
    return out


def main(path="BENCH_stream.json", **kw):
    out = run(**kw)
    # other benches own sections of the same file (bank_bench → "bank",
    # service_bench → "service", telemetry_bench → "telemetry",
    # fuzz_bench → "fuzz"/"chaos"/"validate") — carry them over instead
    # of clobbering them when only this bench reran
    try:
        with open(path) as f:
            prev = json.load(f)
        for key in ("bank", "service", "telemetry", "fuzz", "chaos",
                    "validate"):
            if key in prev and key not in out:
                out[key] = prev[key]
    except (OSError, ValueError):
        pass
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
