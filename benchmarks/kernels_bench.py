"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python per
block), so their wall time is NOT meaningful; the jnp reference path is the
timed CPU number and the kernel is timed separately for completeness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    # single warmup call (compile + first run); jax.block_until_ready
    # handles tuples and other pytrees directly
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_weighted_agg(K=16, D=1_000_000):
    key = jax.random.PRNGKey(0)
    c = jax.random.uniform(key, (K,))
    d = jax.random.normal(key, (K, D), jnp.float32)
    ref_jit = jax.jit(ref.weighted_agg_ref)
    us_ref = _time(ref_jit, c, d)
    us_kern = _time(lambda c, d: ops.weighted_agg(c, d), c, d)
    return [("weighted_agg_ref_jnp", us_ref, f"K={K},D={D}"),
            ("weighted_agg_pallas_interp", us_kern, "interpret=True")]


def bench_weighted_agg_quant(K=16, D=1_048_576, chunk=256):
    # D must be a chunk multiple: the kernel consumes already-padded
    # payloads (quantize_chunked pads), so the bench feeds aligned ones
    key = jax.random.PRNGKey(0)
    c = jax.random.uniform(key, (K,))
    payload = jax.random.randint(key, (K, D), -127, 128, jnp.int8)
    scales = jax.random.uniform(key, (K, D // chunk), jnp.float32,
                                1e-4, 1e-2)
    ref_jit = jax.jit(lambda c, p, s: ref.weighted_agg_quant_ref(
        c, p, s, chunk=chunk))
    us_ref = _time(ref_jit, c, payload, scales)
    us_kern = _time(lambda c, p, s: ops.weighted_agg_quant(
        c, p, s, chunk=chunk), c, payload, scales)
    return [("weighted_agg_quant_ref_jnp", us_ref,
             f"K={K},D={D},chunk={chunk}"),
            ("weighted_agg_quant_pallas_interp", us_kern,
             "interpret=True")]


def bench_masked_sgd(D=1_000_000):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (D,))
    g = jax.random.normal(key, (D,))
    ea = jnp.float32(0.01)
    ref_jit = jax.jit(ref.masked_sgd_ref)
    us_ref = _time(ref_jit, w, g, ea)
    us_kern = _time(lambda w, g: ops.masked_sgd(w, g, ea), w, g)
    return [("masked_sgd_ref_jnp", us_ref, f"D={D}"),
            ("masked_sgd_pallas_interp", us_kern, "interpret=True")]


def bench_flash(B=1, H=4, S=1024, hd=64):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, hd))
    k = jax.random.normal(key, (B, H, S, hd))
    v = jax.random.normal(key, (B, H, S, hd))
    ref_jit = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us_ref = _time(ref_jit, q, k, v)
    us_kern = _time(lambda q, k, v: ops.flash_attention(q, k, v), q, k, v)
    return [("attention_ref_jnp", us_ref, f"B{B}H{H}S{S}d{hd}"),
            ("flash_attention_pallas_interp", us_kern, "interpret=True")]


def bench_ssd_chunk(G=48, Q=128, N=64, P=64):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    cum = jnp.cumsum(-jax.random.uniform(ks[0], (G, Q)) * 0.1, axis=-1)
    C = jax.random.normal(ks[1], (G, Q, N))
    B = jax.random.normal(ks[2], (G, Q, N))
    x = jax.random.normal(ks[3], (G, Q, P))
    ref_jit = jax.jit(ref.ssd_intra_chunk_ref)
    us_ref = _time(ref_jit, cum, C, B, x)
    us_kern = _time(lambda *a: ops.ssd_intra_chunk(*a), cum, C, B, x)
    return [("ssd_intra_chunk_ref_jnp", us_ref, f"G{G}Q{Q}N{N}P{P}"),
            ("ssd_intra_chunk_pallas_interp", us_kern, "interpret=True")]


def run_all():
    rows = []
    rows += bench_weighted_agg()
    rows += bench_weighted_agg_quant()
    rows += bench_masked_sgd()
    rows += bench_flash()
    rows += bench_ssd_chunk()
    return rows
