"""Benchmark harness: one function per paper table + kernel micro-bench +
roofline summary.  Prints ``name,us_per_call,derived`` style CSV blocks.

  PYTHONPATH=src python -m benchmarks.run            # quick set
  PYTHONPATH=src python -m benchmarks.run --full     # full paper tables
"""
from __future__ import annotations

import argparse
import sys
import time


def scenario_smoke(name: str, *, rounds: int = 8, seed: int = 0) -> dict:
    """Tiny end-to-end streaming scenario (the --scenario smoke path):
    replays the named event stream for a handful of rounds so the tier-1
    suite / CI can exercise the subsystem without the full benchmark."""
    from repro.fed.scenarios import make_scenario, run_scenario

    sc = make_scenario(name, seed=seed)
    t0 = time.perf_counter()
    _, summary = run_scenario(sc, mode="device", n_rounds=rounds,
                              eval_every=max(1, rounds // 2))
    summary["wall_s"] = round(time.perf_counter() - t0, 3)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size paper tables (slower)")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-engine", action="store_true",
                    help="skip the rounds/sec engine benchmark")
    ap.add_argument("--skip-stream", action="store_true",
                    help="skip the streaming-participation benchmark")
    ap.add_argument("--skip-bank", action="store_true",
                    help="skip the client-bank / cohort-prefetch benchmark")
    ap.add_argument("--skip-service", action="store_true",
                    help="skip the concurrent-ingestion service benchmark")
    ap.add_argument("--skip-fuzz", action="store_true",
                    help="skip the invariant-fuzzer + chaos-soak benchmark")
    ap.add_argument("--fuzz-seeds", type=int, default=None, metavar="N",
                    help="fuzz corpus size (default: 48, or 128 with "
                         "--full; the validator/backend/chaos corpora "
                         "scale down from it)")
    ap.add_argument("--skip-telemetry", action="store_true",
                    help="skip the telemetry-overhead benchmark")
    ap.add_argument("--skip-sharded", action="store_true",
                    help="skip the sharded-vs-single engine benchmark")
    ap.add_argument("--skip-compression", action="store_true",
                    help="skip the compressed-delta aggregation benchmark")
    ap.add_argument("--skip-fedmodel", action="store_true",
                    help="skip the transformer-federation benchmark")
    ap.add_argument("--check-docs", action="store_true",
                    help="execute the fenced python snippets in README.md "
                         "and docs/*.md, then exit (CI docs-rot gate)")
    ap.add_argument("--bench-json", default="BENCH_engine.json",
                    help="where to write the machine-readable engine "
                         "benchmark (default: BENCH_engine.json)")
    ap.add_argument("--stream-json", default="BENCH_stream.json",
                    help="where to write the streaming benchmark "
                         "(default: BENCH_stream.json)")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="smoke mode: run only a tiny named streaming "
                         "scenario end-to-end and exit (no benchmarks)")
    args = ap.parse_args()

    if args.check_docs:
        from benchmarks.check_docs import main as check_docs_main
        sys.exit(check_docs_main())

    if args.scenario is not None:
        summary = scenario_smoke(args.scenario)
        print("# scenario smoke: key,value")
        for k, v in summary.items():
            if k != "events":
                print(f"{k},{v}")
        return

    print("# kernels: name,us_per_call,config")
    from benchmarks.kernels_bench import run_all as kern_all
    for name, us, cfg in kern_all():
        print(f"{name},{us:.1f},{cfg}")
    sys.stdout.flush()

    if not args.skip_engine:
        from benchmarks.engine_bench import main as engine_main
        span = 64 if args.full else 32
        res = engine_main(args.bench_json, span=span)
        print("\n# engine: mode,rounds_per_sec")
        for mode, rps in res["rounds_per_sec"].items():
            print(f"{mode},{rps}")
        print(f"engine_speedup_vs_seed,{res['speedup_engine_vs_seed']}")
        print(f"host_overhead_fraction_seed_loop,"
              f"{res['host_overhead_fraction_seed_loop']}")
        print(f"weighted_agg_single_launch_us,"
              f"{res['weighted_agg_single_launch_us']}")
        print(f"# wrote {args.bench_json}")
        sys.stdout.flush()

    if not args.skip_compression:
        from benchmarks.engine_bench import compression_main
        res = compression_main(args.bench_json)
        print("\n# compression: kind,bytes_per_round,reduction_vs_f32")
        for kind, nbytes in res["bytes_per_round"].items():
            red = res["bytes_reduction_vs_f32"].get(kind, 1.0)
            print(f"{kind},{nbytes},{red}")
        print("# compression: wire,rounds_per_sec")
        for wire, rps in res["rounds_per_sec"].items():
            print(f"{wire},{rps}")
        print(f"slowdown_int8_vs_f32,{res['slowdown_int8_vs_f32']}")
        print(f"# merged into {args.bench_json}")
        sys.stdout.flush()

    if not args.skip_sharded:
        from benchmarks.sharded_bench import main as sharded_main
        res = sharded_main(args.bench_json)
        print("\n# sharded engine: mode,rounds_per_sec")
        for mode, rps in res["rounds_per_sec"].items():
            print(f"{mode},{rps}")
        print(f"speedup_sharded_vs_single,"
              f"{res['speedup_sharded_vs_single']}")
        print(f"admit_us_sharded,{res['admit_us_sharded']}")
        print(f"# merged into {args.bench_json}")
        sys.stdout.flush()

    if not args.skip_fedmodel:
        from benchmarks.fedmodel_bench import main as fedmodel_main
        res = fedmodel_main(args.bench_json)
        print("\n# fedmodel: mode,rounds_per_sec")
        for mode, rps in res["rounds_per_sec"].items():
            print(f"{mode},{rps}")
        print(f"# merged into {args.bench_json}")
        sys.stdout.flush()

    if not args.skip_stream:
        from benchmarks.stream_bench import main as stream_main
        res = stream_main(args.stream_json)
        print("\n# stream: mode,rounds_per_sec")
        for mode, rps in res["rounds_per_sec"].items():
            print(f"{mode},{rps}")
        print(f"churn_overhead_fraction,{res['churn_overhead_fraction']}")
        print(f"events_per_sec_absorbed,{res['events_per_sec_absorbed']}")
        print(f"admit_us,{res['admit_us']}")
        print(f"evict_us,{res['evict_us']}")
        print(f"# wrote {args.stream_json}")
        sys.stdout.flush()

    if not args.skip_bank:
        from benchmarks.bank_bench import main as bank_main
        res = bank_main(args.stream_json)
        print("\n# bank: metric,value")
        for mode, rps in res["rounds_per_sec"].items():
            print(f"{mode},{rps}")
        print(f"speedup_prefetch_vs_sync,{res['speedup_prefetch_vs_sync']}")
        print(f"staging_overlap_fraction,{res['staging_overlap_fraction']}")
        print("# bank sweep: fleet,hot_slots,rounds_per_sec")
        for row in res["fleet_sweep"]:
            print(f"{row['fleet']},{row['hot_slots']},"
                  f"{row['rounds_per_sec']}")
        print(f"# merged into {args.stream_json}")
        sys.stdout.flush()

    if not args.skip_service:
        from benchmarks.service_bench import main as service_main
        res = service_main(args.stream_json)
        print("\n# service: metric,value")
        for k in ("ingest_events_per_sec", "rounds_per_sec_under_traffic",
                  "rounds_per_sec_blocking", "service_overhead_fraction",
                  "snapshot_ms", "snapshot_to_disk_ms"):
            print(f"{k},{res[k]}")
        print(f"# merged into {args.stream_json}")
        sys.stdout.flush()

    if not args.skip_telemetry:
        from benchmarks.telemetry_bench import main as telemetry_main
        res = telemetry_main(args.stream_json)
        print("\n# telemetry: metric,value")
        for k in ("rounds_per_sec_disabled", "rounds_per_sec_enabled",
                  "rounds_overhead_fraction", "events_per_sec_disabled",
                  "events_per_sec_enabled", "events_overhead_fraction"):
            print(f"{k},{res[k]}")
        print(f"# merged into {args.stream_json}")
        sys.stdout.flush()

    if not args.skip_fuzz:
        from benchmarks.fuzz_bench import main as fuzz_main
        n_seeds = args.fuzz_seeds if args.fuzz_seeds is not None \
            else (128 if args.full else 48)
        res = fuzz_main(args.stream_json, n_seeds=n_seeds)
        print("\n# fuzz: metric,value")
        for k in ("n_seeds", "cases_per_sec", "total_rounds",
                  "total_kills", "violations"):
            print(f"{k},{res['fuzz'][k]}")
        print("# fuzz.validator: metric,value")
        for k in ("n_seeds", "runs_per_sec", "rounds_per_sec",
                  "max_margin", "violations"):
            print(f"{k},{res['fuzz']['validator'][k]}")
        print("# fuzz.backends: metric,value")
        for k in ("n_seeds", "cases_per_sec", "max_param_err",
                  "violations"):
            print(f"{k},{res['fuzz']['backends'][k]}")
        print("# fuzz.fuzzed_chaos: metric,value")
        for k in ("n_seeds", "cases_per_sec", "recoveries",
                  "events_merged", "mttr_mean_s", "mttr_max_s",
                  "violations"):
            print(f"{k},{res['fuzz']['fuzzed_chaos'][k]}")
        print("# chaos: metric,value")
        for k in ("n_recoveries", "mttr_mean_s", "mttr_max_s",
                  "recovered_rounds", "snapshot_failures",
                  "events_merged", "bitexact"):
            print(f"{k},{res['chaos'][k]}")
        print(f"# merged into {args.stream_json}")
        sys.stdout.flush()

    if not args.skip_tables:
        from benchmarks.paper_tables import (table3_scheme_comparison,
                                             table4_fast_reboot,
                                             table5_departure_crossing)
        rounds = 100 if args.full else 40
        print("\n# table3: dataset,iid,|T|,acc_A,acc_B,acc_C,B-A,C-B")
        for row in table3_scheme_comparison(rounds=rounds):
            print(",".join(f"{x:.4f}" if isinstance(x, float) else str(x)
                           for x in row))
        sys.stdout.flush()

        print("\n# table4: tau0,recover_epochs_fast,recover_epochs_vanilla")
        for row in table4_fast_reboot(rounds_after=60 if args.full else 40):
            print(",".join(str(x) for x in row))
        sys.stdout.flush()

        print("\n# table5: alpha,beta,tau0,crossing_epochs")
        for row in table5_departure_crossing():
            print(",".join(str(x) for x in row))
        sys.stdout.flush()

    if not args.skip_tables:
        from benchmarks.bound_check import run as bound_run
        print("\n# thm3.1 envelope: tau,measured_err2,bound,within")
        for tau, err, bound in bound_run(rounds=100):
            print(f"{tau},{err:.6f},{bound:.4f},{err <= bound}")
        sys.stdout.flush()

    # roofline summary from dry-run artifacts (if present)
    try:
        from benchmarks.roofline import load_results
        rows = load_results()
        ok = [r for r in rows if r["status"] == "ok"]
        if ok:
            print("\n# roofline: arch,shape,dominant,compute_s,memory_s,"
                  "collective_s,useful_ratio")
            for r in ok:
                print(f"{r['arch']},{r['shape']},{r['dominant']},"
                      f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                      f"{r['t_collective_s']:.4g},{r['useful_ratio']:.2f}")
    except Exception as e:  # artifacts absent: not an error for the bench
        print(f"\n# roofline: skipped ({e})")


if __name__ == "__main__":
    main()
