"""Transformer-federation rounds/sec: the large-model engine path.

Runs the device-mode RoundEngine over an LMTask (reduced mamba2-130m —
the zoo's cheapest CPU-runnable architecture) in both execution modes:

  * client_parallel   — vmapped client axis (per-client param copies);
  * client_sequential — lax.scan over clients streaming deltas into one
    accumulator (the memory-bounded >=30B layout).

Best-of-k wall-clock rounds/sec per mode merges into BENCH_engine.json
under the ``"fedmodel"`` key (and headline series
``rounds_per_sec.fedmodel_{parallel,sequential}``), extending the perf
trajectory the engine/sharded benches started.  On this CPU container the
numbers are a small-scale correctness/trajectory record; on real TPU
meshes the same series measures the production path.

  PYTHONPATH=src python -m benchmarks.fedmodel_bench       # merges json
"""
from __future__ import annotations

import argparse
import json
import os
import time

SEQ = 32
SAMPLES = 12
E, B = 2, 2
N_CLIENTS = 4


def _make_engine(mode: str, *, chunk: int, seed: int = 0):
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.fed import RoundEngine
    from repro.fed.task import LMTask
    from repro.launch.fed_train import build_fleet

    cfg = get_config("mamba2-130m").reduced()
    task = LMTask(cfg, seq_len=SEQ)
    clients = build_fleet(task, n_clients=N_CLIENTS, samples=SAMPLES,
                          seed=seed)
    eng = RoundEngine(task=task, clients=clients, local_epochs=E,
                      batch_size=B, scheme="C", eta0=0.05,
                      chunk_size=chunk, agg="auto", mode=mode)
    params = task.init_params(jax.random.PRNGKey(seed))
    cap = eng.capacity
    kwargs = dict(p=np.full(cap, 1.0 / N_CLIENTS),
                  active=np.ones(cap, np.float32), lr_shift_tau=0,
                  reboot_tau0=np.zeros(cap, np.int32),
                  reboot_boost=np.ones(cap, np.float32))
    return eng, params, kwargs


def _rps(eng, params, kwargs, *, span: int, reps: int):
    import jax

    key = jax.random.PRNGKey(1)
    params, _ = eng.run_span(params, 0, span, key=key, **kwargs)  # warm
    best, tau = float("inf"), span
    for _ in range(reps):
        t0 = time.perf_counter()
        params, _ = eng.run_span(params, tau, span, key=key, **kwargs)
        jax.block_until_ready(params)
        best = min(best, time.perf_counter() - t0)
        tau += span
    return span / best


def run(span: int = 4, reps: int = 2, chunk: int = 4) -> dict:
    import jax

    res = {}
    for mode in ("client_parallel", "client_sequential"):
        eng, params, kwargs = _make_engine(mode, chunk=chunk)
        res[mode] = round(_rps(eng, params, kwargs, span=span, reps=reps), 3)
    return {
        "config": {"arch": "mamba2-130m (reduced)", "clients": N_CLIENTS,
                   "local_epochs": E, "batch": B, "seq": SEQ,
                   "span": span, "reps": reps, "chunk_size": chunk,
                   "backend": jax.default_backend()},
        "rounds_per_sec": {"parallel": res["client_parallel"],
                           "sequential": res["client_sequential"]},
    }


def main(path: str = "BENCH_engine.json", **kw) -> dict:
    out = run(**kw)
    blob = {}
    if os.path.exists(path):
        with open(path) as f:
            blob = json.load(f)
    blob["fedmodel"] = out
    blob.setdefault("rounds_per_sec", {})
    blob["rounds_per_sec"]["fedmodel_parallel"] = \
        out["rounds_per_sec"]["parallel"]
    blob["rounds_per_sec"]["fedmodel_sequential"] = \
        out["rounds_per_sec"]["sequential"]
    with open(path, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_engine.json")
    ap.add_argument("--span", type=int, default=4)
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()
    print(json.dumps(main(args.json, span=args.span, reps=args.reps),
                     indent=2))
