"""Tiered client-bank benchmark: churn rounds/sec with the
double-buffered cohort prefetch on vs off, staging overlap fraction,
and a fleet-size sweep past device capacity.

Three questions, matching fed/bank.py's design goals:

  * does overlapping cohort staging with span compute buy back the
    churn overhead? — same sustained-churn workload as stream_bench,
    once with synchronous admits and once with the bank + prefetch
    (the staging thread gathers the next boundary's cohort while the
    current span runs, so the boundary pays only the fused scatter);
  * how much of the staging cost actually hides behind compute? —
    the stager's overlap fraction (1 - wait/stage seconds);
  * does throughput survive fleets much larger than the hot set? —
    the rotation scenario cycles ``fleet`` clients through ``hot``
    capacity slots (evict-to-bank + rejoin-from-bank every round),
    swept well past device capacity.

Results merge into BENCH_stream.json under the "bank" key (the other
sections are owned by stream/service/telemetry/fuzz benches).
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.stream_bench import NO_EVAL, _churn_events
from repro.fed.scenarios import build_scheduler, make_scenario

ROTATION_DWELL = 1          # one evict+rejoin boundary every round


def _interleaved_rps(legs, span, reps):
    """Best-of-reps rounds/sec per leg, reps interleaved round-robin.

    legs maps name -> (scheduler, churn: bool).  Interleaving matters on
    a shared box: timing each leg's reps back to back lets slow drift
    (thermal, page cache, a noisy neighbour) land entirely on one leg
    and fake a sync-vs-prefetch gap in either direction.  The warmup
    runs one full churned rep, not just the scenario's own events:
    churn splits the span into lengths the event-free warmup never
    compiles, and those compiles would land in the first timed rep (a
    ~30ms span measured as ~1s)."""
    next_ids = {}
    for name, (sch, churn) in legs.items():
        sch.run(span, eval_every=NO_EVAL)   # compile + scenario's events
        nid = len(sch.clients)
        if churn:
            events, nid = _churn_events(sch._next_tau, span, nid, 0)
            sch.push(*events)
            sch.run(span, eval_every=NO_EVAL)   # churned span lengths
        next_ids[name] = nid
    best = {name: float("inf") for name in legs}
    for rep in range(1, reps + 1):
        for name, (sch, churn) in legs.items():
            if churn:
                events, next_ids[name] = _churn_events(
                    sch._next_tau, span, next_ids[name], rep)
                sch.push(*events)
            t0 = time.perf_counter()
            sch.run(span, eval_every=NO_EVAL)
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: span / b for name, b in best.items()}


def _rotation_rps(fleet, hot, rounds, *, seed, mode, chunk):
    """End-to-end rounds/sec with ``fleet`` bank clients rotating
    through ``hot`` device slots, prefetch on.  Runs at least ``fleet``
    boundaries so every client actually cycles through the bank."""
    rounds = max(rounds, fleet * ROTATION_DWELL + 8)
    sc = make_scenario("rotation", seed=seed, fleet=fleet, hot=hot,
                       dwell=ROTATION_DWELL, n_rounds=rounds + 8)
    sch = build_scheduler(sc, mode=mode, chunk_size=chunk, prefetch=True)
    # measure churn, not evaluation: with a boundary every round the
    # event-round eval rule would evaluate each round on an eval set
    # whose shape grows with every arrival — an XLA recompile per round
    # that has nothing to do with the bank
    sch.eval_fn = None
    sch.run(8, eval_every=NO_EVAL)        # warmup: compile + first evicts
    t0 = time.perf_counter()
    sch.run(rounds, eval_every=NO_EVAL)
    wall = time.perf_counter() - t0
    stats = sch.prefetch_stats()
    sch.close()
    return {"fleet": fleet, "hot_slots": hot, "rounds": rounds,
            "rounds_per_sec": round(rounds / wall, 2),
            "bank_clients": stats["bank"]["clients"],
            "prefetch_hits": stats["hits"],
            "prefetch_misses": stats["misses"]}


def run(span=24, reps=10, seed=0, mode="device", chunk=16,
        fleets=(64, 256), rotation_hot=12, rotation_rounds=32):
    # three legs over the identical event diet, reps interleaved:
    # event-free baseline, sustained churn with synchronous admits (no
    # bank, no prefetch), and the same churn with the bank + cohort
    # prefetch.  All eval-free like stream_bench's rps legs: the
    # event-boundary eval rule would otherwise charge evaluation to
    # churn while the static leg never pays it.
    legs = {}
    static = build_scheduler(make_scenario("flash-crowd", seed=seed),
                             mode=mode, chunk_size=chunk)
    static.eval_fn = None
    static._queue.clear()
    legs["static"] = (static, False)
    sync = build_scheduler(make_scenario("flash-crowd", seed=seed),
                           mode=mode, chunk_size=chunk)
    sync.eval_fn = None
    legs["sync"] = (sync, True)
    pre = build_scheduler(make_scenario("flash-crowd", seed=seed),
                          mode=mode, chunk_size=chunk, prefetch=True)
    pre.eval_fn = None
    legs["prefetch"] = (pre, True)
    rps = _interleaved_rps(legs, span, reps)
    rps_static, rps_sync, rps_pre = (rps["static"], rps["sync"],
                                     rps["prefetch"])
    stats = pre.prefetch_stats()
    pre.close()

    # leg 3: fleet sweep past device capacity (rotation churns one
    # evict-to-bank + rejoin-from-bank boundary every round)
    sweep = [_rotation_rps(f, rotation_hot, rotation_rounds, seed=seed,
                           mode=mode, chunk=chunk) for f in fleets]

    return {
        "config": {"scenario": "flash-crowd", "mode": mode, "span": span,
                   "reps": reps, "chunk_size": chunk,
                   "rotation_dwell": ROTATION_DWELL,
                   "backend": jax.default_backend()},
        "rounds_per_sec": {"static": round(rps_static, 2),
                           "churn_sync": round(rps_sync, 2),
                           "churn_prefetch": round(rps_pre, 2)},
        "churn_overhead_fraction": round(
            max(0.0, 1.0 - rps_pre / rps_static), 4),
        "speedup_prefetch_vs_sync": round(rps_pre / rps_sync, 2),
        "staging_overlap_fraction": round(
            stats["stager"]["overlap_fraction"], 4),
        "prefetch_hits": stats["hits"],
        "prefetch_misses": stats["misses"],
        "fleet_sweep": sweep,
    }


def main(path="BENCH_stream.json", **kw):
    res = run(**kw)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged["bank"] = res
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
