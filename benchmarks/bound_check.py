"""Theorem 3.1 envelope vs measured convergence on quadratics.

Emits (tau, measured ||w - w*||^2, bound) rows: the measured trajectory of
a Scheme-C federated run with heterogeneous Bernoulli participation must
stay under the Theorem-3.1 bound built from the same problem's constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (expected_coeff_stats,
                                    scheme_coefficients, theta_bound)
from repro.core.fed_step import make_fed_round
from repro.core.theory import (convergence_bound, quadratic_problem_constants,
                               theorem31_terms)

E = 4
N = 4
DIM = 6


def run(rounds=200, seed=0):
    rng = np.random.default_rng(seed)
    A_list = [np.diag(rng.uniform(0.5, 2.0, DIM)) for _ in range(N)]
    c_list = [rng.normal(0, 1.5, DIM) for _ in range(N)]
    n_k = rng.integers(50, 200, N).astype(float)
    p = n_k / n_k.sum()
    pc, w_star = quadratic_problem_constants(A_list, c_list, p)

    # heterogeneous participation: client k completes Bin(E, q_k), >=1
    qs = rng.uniform(0.3, 1.0, N)

    def sampler(r):
        return np.maximum(r.binomial(E, qs), 1)

    stats = expected_coeff_stats("C", p, sampler, E, n_rounds=1000,
                                 seed=seed)
    # G^2 estimate: max_k sup ||grad|| over the trajectory region
    G2 = max(float(np.linalg.norm(A @ (w_star - c)) ** 2) * 4
             for A, c in zip(A_list, c_list)) + 1.0
    pc = type(pc)(L=pc.L, mu=pc.mu, G2=G2, sigma2=np.zeros(N),
                  gamma_k=pc.gamma_k)
    terms = theorem31_terms(pc, p, E, theta_bound("C", N, E),
                            np.asarray(stats["E_ps"]))

    A = jnp.asarray(np.stack(A_list))
    c = jnp.asarray(np.stack(c_list))

    def loss_fn(params, batch):
        k = batch["client"][0]
        d = params["w"] - c[k]
        return 0.5 * d @ A[k] @ d

    round_fn = jax.jit(make_fed_round(loss_fn, "client_parallel"))
    params = {"w": jnp.zeros(DIM)}
    batches = {"client": jnp.asarray(
        np.tile(np.arange(N)[:, None, None], (1, E, 1)))}
    eta_scale = 16 * E / (pc.mu * stats["E_sum_ps"])
    rows = []
    for tau in range(rounds):
        s = sampler(rng).astype(np.float32)
        alpha = (np.arange(E)[None, :] < s[:, None]).astype(np.float32)
        coeffs = scheme_coefficients("C", jnp.asarray(p), jnp.asarray(s), E)
        eta = min(eta_scale / (tau * E + terms.gamma), 0.5)
        params, _ = round_fn(params, batches, jnp.asarray(alpha), coeffs,
                             jnp.float32(eta))
        if tau % 10 == 0:
            err = float(np.sum((np.asarray(params["w"]) - w_star) ** 2))
            bound = convergence_bound(max(tau, 1), terms, M_tau=0.0)
            rows.append((tau, err, bound))
    return rows


if __name__ == "__main__":
    print("tau,measured_err2,thm31_bound,within")
    for tau, err, bound in run():
        print(f"{tau},{err:.6f},{bound:.6f},{err <= bound}")
