"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch x shape) from the dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_traffic_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_link_bw

HLO quantities come from the scan-aware static analysis of the compiled
SPMD module (launch/hlo_analysis.py) — per-device by construction.
MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference), giving the
useful-compute ratio that exposes remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HBM_PER_CHIP = 16e9  # v5e


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: routed top-k only)."""
    from repro.launch.steps import param_bytes
    total = param_bytes(cfg) / np.dtype(cfg.dtype).itemsize
    if not cfg.n_experts:
        return total
    # subtract inactive routed experts
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * cfg.moe_layers
    return total - inactive


def model_flops(cfg, shape, meta) -> float:
    """Global useful FLOPs for one step of this shape."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        C = meta.get("clients", 16)
        E = meta.get("local_epochs", 2)
        b = meta.get("client_batch", shape.global_batch // C)
        tokens = C * E * b * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: one token


def load_results(art_dir="experiments/artifacts", mesh="pod"):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            path = os.path.join(art_dir, f"dryrun_{arch}_{sname}_{mesh}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                r = json.load(f)
            if r["status"] == "skipped":
                rows.append({"arch": arch, "shape": sname,
                             "status": "skipped",
                             "reason": r.get("reason", "")})
                continue
            if r["status"] != "ok":
                rows.append({"arch": arch, "shape": sname,
                             "status": "error",
                             "reason": r.get("error", "")[:120]})
                continue
            a = r["hlo_analysis"]
            n_dev = r["devices"]
            t_comp = a["flops"] / PEAK_FLOPS_BF16
            t_mem = a["traffic_bytes"] / HBM_BW
            t_coll = a["collective_bytes"] / ICI_BW
            terms = {"compute": t_comp, "memory": t_mem,
                     "collective": t_coll}
            dom = max(terms, key=terms.get)
            mf = model_flops(cfg, INPUT_SHAPES[sname], r.get("meta") or {})
            mf_dev = mf / n_dev
            rows.append({
                "arch": arch, "shape": sname, "status": "ok",
                "devices": n_dev,
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "dominant": dom,
                "model_flops_per_dev": mf_dev,
                "hlo_flops_per_dev": a["flops"],
                "useful_ratio": mf_dev / a["flops"] if a["flops"] else 0.0,
                "mem_per_dev_bytes": (r.get("memory") or {}).get(
                    "bytes_per_device", -1),
                "fits_hbm": ((r.get("memory") or {}).get(
                    "bytes_per_device", 0) or 0) < HBM_PER_CHIP,
                "collectives_per_op": a.get("collectives_per_op", {}),
            })
    return rows


FIXES = {
    ("compute", "train"): "raise per-chip batch / cut remat recompute",
    ("compute", "prefill"): "flash-attention kernel (skip masked blocks)",
    ("compute", "decode"): "batch more requests per chip",
    ("memory", "train"): "reduce delta/accumulator copies; fuse SGD update",
    ("memory", "prefill"): "blockwise attention to cut score traffic",
    ("memory", "decode"): "shrink KV reads: MLA/window cache, quantize kv",
    ("collective", "train"): "overlap grad psum with compute; shard embed",
    ("collective", "prefill"): "reshard activations once, not per layer",
    ("collective", "decode"): "kv-head-aligned sharding to kill resharding",
}


def markdown_table(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | mem/dev GB | fits 16GB | suggested fix |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | — | {r['reason'][:60]} |")
            continue
        kind = INPUT_SHAPES[r["shape"]].kind
        fix = FIXES.get((r["dominant"], kind), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mem_per_dev_bytes'] / 1e9:.1f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | {fix} |")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--art", default="experiments/artifacts")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_results(args.art, args.mesh)
    if args.csv:
        keys = ["arch", "shape", "status", "t_compute_s", "t_memory_s",
                "t_collective_s", "dominant", "useful_ratio",
                "mem_per_dev_bytes"]
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    else:
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
