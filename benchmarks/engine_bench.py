"""End-to-end rounds/sec benchmark: seed host loop vs device-resident engine.

Measures FederatedTrainer.run throughput on the paper's small-model config
(SYNTHETIC logreg, E=5, B=20) in four configurations:

  seed_host   the seed per-round host loop with the seed's original
              take_along_axis loss formulation (faithful baseline),
  host        the same host loop with the current (one-hot) loss,
  engine_plan host-RNG sampling, device-resident chunked rounds,
  engine      fully fused on-device sampling + pytree-flat Pallas
              aggregation (the fast path).

Timing is best-of-k over repeated spans (the CI box is a shared 2-core
container; mean timings are dominated by scheduler noise).  Emits
BENCH_engine.json with rounds/sec per mode, the engine speedup over the
seed loop, the host-overhead fraction of the seed loop (instrumented
round_fn device time vs wall), and the weighted_agg single-launch µs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def _seed_loss_fn(cfg):
    """The seed's loss formulation (take_along_axis NLL), kept here so the
    benchmark baseline stays faithful to the seed host loop even after the
    repo's loss moved to the one-hot form."""
    def loss_fn(params, batch):
        x, y = batch["x"], batch["y"]
        lg = logits_small(params, cfg, x)
        ll = jax.nn.log_softmax(lg)
        return -jnp.mean(jnp.take_along_axis(
            ll, y[:, None].astype(jnp.int32), axis=1))
    return loss_fn


def _null_eval(params, x, y):
    return 0.0, 0.0


def _make_trainer(engine, *, loss_fn, n_clients, seed=0, chunk=32,
                  agg="auto", compression=None):
    train, test = synthetic_federation(0.5, 0.5, n_clients, seed=seed)
    rng = np.random.default_rng(seed)
    clients = [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 5)],
                      x_test=te[0], y_test=te[1])
               for tr, te in zip(train, test)]
    return FederatedTrainer(
        loss_fn=loss_fn, eval_fn=_null_eval,
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        clients=clients, local_epochs=5, batch_size=20, scheme="C",
        eta0=1.0, seed=seed, engine=engine, chunk_size=chunk, agg=agg,
        compression=compression)


def _rounds_per_sec(tr, span, reps):
    tr.run(2 * span, eval_every=10 ** 9)          # warmup + compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        tr.run(span, eval_every=10 ** 9)
        best = min(best, time.perf_counter() - t0)
    return span / best


def _host_overhead_fraction(tr, span):
    """Instrument the legacy loop: fraction of wall time NOT spent inside
    the jitted round step (sampling, batch build, transfers, coeff sync)."""
    tr.run(4, eval_every=10 ** 9)   # warmup: keep compile out of the split
    orig = tr.round_fn
    dev = [0.0]

    def timed(*a, **k):
        t0 = time.perf_counter()
        out = orig(*a, **k)
        jax.block_until_ready(out)
        dev[0] += time.perf_counter() - t0
        return out

    tr.round_fn = timed
    t0 = time.perf_counter()
    tr.run(span, eval_every=10 ** 9)
    total = time.perf_counter() - t0
    tr.round_fn = orig
    return max(0.0, 1.0 - dev[0] / total)


def _agg_us(n_clients):
    """Single-launch weighted_agg time at the benchmark model size."""
    from benchmarks.kernels_bench import _time
    from repro.kernels import ops
    params = init_small(jax.random.PRNGKey(0), CFG)
    D = sum(p.size for p in jax.tree.leaves(params))
    key = jax.random.PRNGKey(0)
    c = jax.random.uniform(key, (n_clients,))
    d = jax.random.normal(key, (n_clients, D), jnp.float32)
    return _time(lambda: ops.weighted_agg(c, d, block=1024)), D


def run(span=32, reps=7, n_clients=12, chunk=32):
    seed_loss = _seed_loss_fn(CFG)
    cur_loss = make_loss_fn(CFG)

    rps = {}
    rps["seed_host"] = _rounds_per_sec(
        _make_trainer("host", loss_fn=seed_loss, n_clients=n_clients),
        span, reps)
    rps["host"] = _rounds_per_sec(
        _make_trainer("host", loss_fn=cur_loss, n_clients=n_clients),
        span, reps)
    rps["engine_plan"] = _rounds_per_sec(
        _make_trainer("plan", loss_fn=cur_loss, n_clients=n_clients,
                      chunk=chunk), span, reps)
    rps["engine"] = _rounds_per_sec(
        _make_trainer("device", loss_fn=cur_loss, n_clients=n_clients,
                      chunk=chunk), span, reps)
    # the fused Pallas aggregation layout, explicitly (on CPU this runs the
    # interpreter, so agg="auto" prefers the jnp tree; on TPU they coincide)
    rps["engine_flat_agg"] = _rounds_per_sec(
        _make_trainer("device", loss_fn=cur_loss, n_clients=n_clients,
                      chunk=chunk, agg="flat"), span, reps)

    overhead = _host_overhead_fraction(
        _make_trainer("host", loss_fn=seed_loss, n_clients=n_clients),
        span)
    agg_us, D = _agg_us(n_clients)

    out = {
        "config": {"dataset": "synthetic", "model": "logreg",
                   "n_clients": n_clients, "local_epochs": 5,
                   "batch_size": 20, "scheme": "C", "span": span,
                   "reps": reps, "chunk_size": chunk, "d_total": D,
                   "backend": jax.default_backend()},
        "rounds_per_sec": {k: round(v, 2) for k, v in rps.items()},
        "speedup_engine_vs_seed": round(rps["engine"] / rps["seed_host"], 3),
        "speedup_plan_vs_seed": round(
            rps["engine_plan"] / rps["seed_host"], 3),
        "host_overhead_fraction_seed_loop": round(overhead, 4),
        "weighted_agg_single_launch_us": round(agg_us, 1),
    }
    return out


def compression_run(span=32, reps=7, n_clients=12, chunk=32):
    """Compressed-delta aggregation series: wire bytes moved per round for
    each payload format (analytic, from the format's exact layout) and
    quantized-vs-f32 rounds/sec through the same device engine."""
    from repro.core.compression import resolve_compression, wire_bytes

    cur_loss = make_loss_fn(CFG)
    params = init_small(jax.random.PRNGKey(0), CFG)
    D = sum(p.size for p in jax.tree.leaves(params))

    kinds = ["none", "bf16", "int8", "int8-topk"]
    bytes_per_round = {
        k: wire_bytes(D, resolve_compression(k), n_clients=n_clients)
        for k in kinds}

    rps = {}
    for label, comp in [("f32", None), ("bf16", "bf16"), ("int8", "int8")]:
        rps[label] = _rounds_per_sec(
            _make_trainer("device", loss_fn=cur_loss, n_clients=n_clients,
                          chunk=chunk, compression=comp), span, reps)

    out = {
        "config": {"dataset": "synthetic", "model": "logreg",
                   "n_clients": n_clients, "span": span, "reps": reps,
                   "chunk_size": chunk, "d_total": D,
                   "quant_chunk": resolve_compression("int8").chunk,
                   "backend": jax.default_backend()},
        "bytes_per_round": bytes_per_round,
        "bytes_reduction_vs_f32": {
            k: round(bytes_per_round["none"] / bytes_per_round[k], 2)
            for k in kinds if k != "none"},
        "rounds_per_sec": {k: round(v, 2) for k, v in rps.items()},
        "slowdown_int8_vs_f32": round(
            max(0.0, 1.0 - rps["int8"] / rps["f32"]), 4),
    }
    return out


def compression_main(path="BENCH_engine.json", **kw):
    """Merge the compression series into BENCH_engine.json under the
    "compression" key (same merge pattern as sharded_bench)."""
    import os
    res = compression_run(**kw)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["compression"] = res
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    return res


def main(path="BENCH_engine.json", **kw):
    out = run(**kw)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
