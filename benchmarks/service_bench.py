"""FederationService benchmark: concurrent-ingestion throughput and
snapshot latency while spans run.

Three costs matter for the live-serving layer (fed/service.py):

  * ingestion throughput — events/sec a producer thread can submit into
    the bounded inbox WHILE the worker thread runs training spans (the
    serve.py-gap workload: membership traffic concurrent with compute);
  * rounds/sec under that concurrent traffic, vs the same scheduler
    driven by blocking run() calls with no service in front — the
    lock/queue overhead of the service layer itself;
  * snapshot latency — pause at a span boundary, serialize the full
    FedState (queue + membership + RNG/key), resume: the cost of a
    mid-stream checkpoint a production deployment takes periodically.

Merged into BENCH_stream.json (under "service") so the streaming perf
trajectory stays in one machine-readable file.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

from repro.core.participation import TRACES
from repro.fed.scenarios import _make_clients, build_scheduler, make_scenario
from repro.fed.service import FederationService
from repro.fed.stream import InactivityBurst, TraceShift

NO_EVAL = 10 ** 9


def _fresh_scheduler(seed=0, mode="device", chunk=8):
    sc = make_scenario("flash-crowd", seed=seed)
    sch = build_scheduler(sc, mode=mode, chunk_size=chunk)
    sch._queue.clear()                    # event-free fleet; we drive traffic
    return sch


def _warm_chunks(sch, chunk=8):
    """Compile every pow2 chunk length once (event boundaries split spans
    into arbitrary pow2 pieces, and a mid-measurement compile would
    swamp the numbers)."""
    r = 1
    while r <= chunk:
        sch.run(r, eval_every=NO_EVAL)
        r *= 2


def _traffic(j: int, n_clients: int):
    """Steady-state control traffic: trace shifts and short bursts (slot-
    balance-neutral, so the stream can run indefinitely)."""
    if j % 5 == 4:
        return InactivityBurst(0, 1, (j % n_clients,))
    return TraceShift(0, client_id=j % n_clients, trace=TRACES[j % 8])


def bench_ingestion(n_events=400, span_rounds=4, seed=0):
    """Submit n_events from a producer thread while the worker trains;
    returns (events_per_sec_ingested, rounds_per_sec_under_traffic)."""
    sch = _fresh_scheduler(seed)
    n_clients = len(sch.clients)
    _warm_chunks(sch)
    svc = FederationService(sch, span_rounds=span_rounds,
                            eval_every=NO_EVAL, max_rounds=None,
                            max_pending=128)
    done = threading.Event()
    submitted_wall = [0.0]

    def producer():
        t0 = time.perf_counter()
        for j in range(n_events):
            svc.submit(_traffic(j, n_clients))
        svc.drain(timeout=120)
        submitted_wall[0] = time.perf_counter() - t0
        done.set()

    rounds0 = sch._next_tau
    t0 = time.perf_counter()
    with svc:
        t = threading.Thread(target=producer)
        t.start()
        done.wait(timeout=180)
        t.join()
        wall = time.perf_counter() - t0
        rounds = sch._next_tau - rounds0
    ev_per_sec = n_events / submitted_wall[0]
    rps = rounds / wall if wall > 0 else float("nan")
    return ev_per_sec, rps, svc.stats()


def bench_baseline_rps(span=24, reps=3, seed=0):
    """The same scheduler driven by blocking run() calls, no service."""
    sch = _fresh_scheduler(seed)
    sch.run(span, eval_every=NO_EVAL)     # warmup
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        sch.run(span, eval_every=NO_EVAL)
        best = min(best, time.perf_counter() - t0)
    return span / best


def bench_service_rps(rounds=96, span_rounds=8, seed=0):
    """Event-free rounds/sec THROUGH the service (worker thread + lock +
    condition-variable parking, zero traffic) — against
    bench_baseline_rps this isolates the service layer's own overhead."""
    sch = _fresh_scheduler(seed)
    _warm_chunks(sch)
    base = sch._next_tau
    svc = FederationService(sch, span_rounds=span_rounds,
                            eval_every=NO_EVAL, max_rounds=base + rounds)
    t0 = time.perf_counter()
    with svc:
        ok = svc.wait_rounds(base + rounds, timeout=300)
    wall = time.perf_counter() - t0
    return rounds / wall if ok else float("nan")


def bench_span_attribution(rounds=96, span_rounds=8, seed=0):
    """Span-timer evidence for the overhead number: the same event-free
    service run with telemetry on, attributed by the worker's own
    monotonic timers into busy (inside sch.run) / idle (parked) /
    overhead (everything else per iteration)."""
    from repro.obs import Telemetry
    sch = _fresh_scheduler(seed)
    _warm_chunks(sch)
    base = sch._next_tau
    svc = FederationService(sch, span_rounds=span_rounds,
                            eval_every=NO_EVAL, max_rounds=base + rounds,
                            telemetry=Telemetry())
    with svc:
        ok = svc.wait_rounds(base + rounds, timeout=300)
    reg = svc.telemetry.registry
    busy = reg.counter("svc_busy_seconds_total").value
    idle = reg.counter("svc_idle_seconds_total").value
    over = reg.counter("svc_overhead_seconds_total").value
    total = busy + idle + over
    return {
        "busy_s": round(busy, 4), "idle_s": round(idle, 4),
        "overhead_s": round(over, 4),
        "overhead_fraction_of_worker": (round(over / total, 4)
                                        if total > 0 and ok else None),
    }


def bench_snapshot(tmpdir=None, iters=5, seed=0):
    """Latency of a span-boundary-consistent snapshot, in-memory (state
    dict only) and persisted (full resumable checkpoint)."""
    sch = _fresh_scheduler(seed)
    _warm_chunks(sch)
    sch.push(*make_scenario("flash-crowd", seed=seed).events)  # real queue
    svc = FederationService(sch, span_rounds=4, eval_every=NO_EVAL,
                            max_rounds=None)
    with svc:
        svc.snapshot()                    # warmup (span compiles settle)
        t0 = time.perf_counter()
        for _ in range(iters):
            svc.snapshot()
        mem_ms = (time.perf_counter() - t0) / iters * 1e3
        disk_ms = float("nan")
        if tmpdir is not None:
            svc.snapshot(os.path.join(tmpdir, "bench_ckpt"))
            t0 = time.perf_counter()
            for _ in range(iters):
                svc.snapshot(os.path.join(tmpdir, "bench_ckpt"))
            disk_ms = (time.perf_counter() - t0) / iters * 1e3
    return mem_ms, disk_ms


def run(n_events=400, seed=0):
    import tempfile
    ev_per_sec, rps_traffic, stats = bench_ingestion(n_events, seed=seed)
    rps_blocking = bench_baseline_rps(seed=seed)
    rps_service = bench_service_rps(seed=seed)
    attribution = bench_span_attribution(seed=seed)
    with tempfile.TemporaryDirectory() as td:
        snap_mem_ms, snap_disk_ms = bench_snapshot(td, seed=seed)
    return {
        "config": {"n_events": n_events, "span_rounds": 4,
                   "scenario": "flash-crowd",
                   "backend": jax.default_backend()},
        "ingest_events_per_sec": round(ev_per_sec, 1),
        # every-boundary event traffic splits spans to R=1 and restages
        # membership each round — an event-rate-dominated number, NOT the
        # service layer's own cost (see service_overhead_fraction)
        "rounds_per_sec_under_traffic": round(rps_traffic, 2),
        "rounds_per_sec_blocking": round(rps_blocking, 2),
        "rounds_per_sec_service_idle": round(rps_service, 2),
        "service_overhead_fraction": round(
            max(0.0, 1.0 - rps_service / rps_blocking), 4),
        # sleep-polling worker/drain loops before the condition-variable
        # rewrite measured 0.2512 here — kept for the before/after record
        "service_overhead_fraction_pre_cv": 0.2512,
        # worker-side span-timer attribution of the same idle run
        "span_attribution": attribution,
        "snapshot_ms": round(snap_mem_ms, 2),
        "snapshot_to_disk_ms": round(snap_disk_ms, 2),
        "events_applied": stats["events_applied"],
    }


def main(path="BENCH_stream.json", **kw):
    res = run(**kw)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged["service"] = res
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
