"""Execute the fenced Python snippets in README.md and docs/*.md.

Documentation rots silently: an API rename leaves every prose example
behind, and nobody notices until a reader pastes one.  This checker makes
the docs executable — every fenced block tagged ``python`` is run, in
file order, inside one shared namespace per file (so a later snippet may
use an earlier snippet's imports, the way a reader would paste them).

Blocks that cannot run on a 1-device CI container (multi-device meshes,
TPU-only paths) or that are deliberately illustrative are tagged
``python no-run`` and are counted but skipped.  Plain ```` ``` ```` blocks
(shell transcripts, ascii diagrams) are ignored entirely.

  PYTHONPATH=src python -m benchmarks.check_docs          # whole doc set
  PYTHONPATH=src python -m benchmarks.run --check-docs    # same, CI gate
  PYTHONPATH=src python -m benchmarks.check_docs docs/scaling.md

Exit status is nonzero on the first failing snippet, with its file and
line range in the report.
"""
from __future__ import annotations

import re
import sys
import time
import traceback
from pathlib import Path

_FENCE = re.compile(r"^```(.*)$")
ROOT = Path(__file__).resolve().parent.parent
DEFAULT_DOCS = ("README.md", "docs")


def extract_blocks(path: Path):
    """Yield (start_line, info_words, code) for every fenced block."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        info = m.group(1).strip().split()
        start = i + 1
        body = []
        i += 1
        while i < len(lines) and not _FENCE.match(lines[i]):
            body.append(lines[i])
            i += 1
        i += 1                               # closing fence
        yield start + 1, info, "\n".join(body)


def doc_files(targets=None):
    if targets:
        return [Path(t) for t in targets]
    out = []
    for t in DEFAULT_DOCS:
        p = ROOT / t
        if p.is_dir():
            out.extend(sorted(p.glob("*.md")))
        elif p.exists():
            out.append(p)
    return out


def run_file(path: Path, *, verbose: bool = True):
    """Execute the runnable python blocks of one file; returns
    (ran, skipped, error) — error is a (lineno, traceback) tuple."""
    ns = {"__name__": f"__docsnippet_{path.stem}__"}
    ran = skipped = 0
    for lineno, info, code in extract_blocks(path):
        if not info or info[0] != "python":
            continue
        if "no-run" in info:
            skipped += 1
            continue
        t0 = time.perf_counter()
        try:
            exec(compile(code, f"{path}:{lineno}", "exec"), ns)
        except Exception:
            return ran, skipped, (lineno, traceback.format_exc())
        ran += 1
        if verbose:
            rel = path.relative_to(ROOT) if path.is_absolute() else path
            print(f"  ok {rel}:{lineno} "
                  f"({time.perf_counter() - t0:.2f}s)")
    return ran, skipped, None


def main(argv=None) -> int:
    targets = list(argv) if argv else None
    total_ran = total_skipped = 0
    for path in doc_files(targets):
        if not path.exists():
            print(f"MISSING {path}")
            return 1
        ran, skipped, err = run_file(path)
        total_ran += ran
        total_skipped += skipped
        if err is not None:
            lineno, tb = err
            print(f"FAIL {path}:{lineno}\n{tb}")
            return 1
    print(f"# check-docs: {total_ran} snippets executed, "
          f"{total_skipped} tagged no-run")
    if total_ran == 0:
        print("FAIL: no runnable snippets found — fence tags broken?")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
