"""Sharded-vs-single rounds/sec: the federation axis over a device mesh.

Runs the device-mode RoundEngine twice in the same process — once
unsharded (all slots on one device) and once with the client axis sharded
over a 1-D 'data' mesh — and records best-of-k rounds/sec for each, plus
the admit() slot-write cost under sharding.  Results merge into
BENCH_engine.json under the ``"sharded"`` key (and the headline series
``rounds_per_sec.engine_sharded_{n}dev``) so the perf trajectory stays in
one machine-readable file.

Multi-device CPU needs ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax initializes; when the calling process has a single device,
``main()`` transparently re-executes this module in a subprocess with a
4-virtual-device CPU mesh and merges the child's JSON.  On real TPU/GPU
fleets the in-process path runs directly over the local devices.

  PYTHONPATH=src python -m benchmarks.sharded_bench          # writes json
  PYTHONPATH=src python -m benchmarks.sharded_bench --emit   # raw JSON only

On this CPU container the sharded numbers are a *correctness* series, not
a speed win — 4 virtual devices share the same cores and the per-round
all-reduce is pure overhead at logreg size.  The series exists to keep the
cross-device path benchmarked so real-mesh runs have a trajectory to
extend.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

N_SHARDS = 4


def _make_engine(sharding, *, n_clients, chunk, seed=0):
    import jax
    import numpy as np

    from repro.configs.paper import SYNTHETIC_LR
    from repro.core.participation import TRACES
    from repro.data import synthetic_federation
    from repro.fed import Client, RoundEngine
    from repro.models.small import init_small, make_loss_fn

    train, _ = synthetic_federation(0.5, 0.5, n_clients, seed=seed)
    rng = np.random.default_rng(seed)
    clients = [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 5)])
               for tr in train]
    eng = RoundEngine(loss_fn=make_loss_fn(SYNTHETIC_LR), clients=clients,
                      local_epochs=5, batch_size=20, scheme="C", eta0=0.5,
                      chunk_size=chunk, agg="auto", sharding=sharding)
    params = init_small(jax.random.PRNGKey(0), SYNTHETIC_LR)
    C, cap = len(clients), eng.capacity
    p = np.zeros(cap)
    p[:C] = np.array([c.n for c in clients]) / sum(c.n for c in clients)
    active = np.zeros(cap, np.float32)
    active[:C] = 1.0
    kwargs = dict(p=p, active=active, lr_shift_tau=0,
                  reboot_tau0=np.zeros(cap, np.int32),
                  reboot_boost=np.ones(cap, np.float32))
    return eng, params, kwargs


def _rps(eng, params, kwargs, *, span, reps):
    import jax

    key = jax.random.PRNGKey(1)
    params, _ = eng.run_span(params, 0, 2 * span, key=key, **kwargs)
    best = float("inf")
    tau = 2 * span
    for _ in range(reps):
        t0 = time.perf_counter()
        params, _ = eng.run_span(params, tau, span, key=key, **kwargs)
        jax.block_until_ready(params)
        best = min(best, time.perf_counter() - t0)
        tau += span
    return span / best


def _admit_us(eng, reps=30):
    import jax

    from repro.core.participation import TRACES
    from repro.data import synthetic_federation
    from repro.fed import Client

    train, _ = synthetic_federation(0.5, 0.5, 1, seed=77)
    cl = Client(x=train[0][0], y=train[0][1], trace=TRACES[0])
    slot = eng.capacity - 1
    eng.admit(slot, cl)                      # warm the slot-write jits
    jax.block_until_ready(eng.s_cdf)
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.admit(slot, cl)
    jax.block_until_ready(eng.s_cdf)
    return (time.perf_counter() - t0) / reps * 1e6


def run(*, n_clients=32, span=32, reps=5, chunk=32):
    """In-process sharded-vs-single series; needs >= N_SHARDS devices."""
    import jax

    from repro.fed import make_fed_sharding

    n_dev = len(jax.devices())
    if n_dev < N_SHARDS:
        raise RuntimeError(f"need {N_SHARDS} devices, have {n_dev}; "
                           f"run via main() for the subprocess path")
    fs = make_fed_sharding(N_SHARDS)
    single = _make_engine(None, n_clients=n_clients, chunk=chunk)
    sharded = _make_engine(fs, n_clients=n_clients, chunk=chunk)
    rps_single = _rps(*single, span=span, reps=reps)
    rps_sharded = _rps(*sharded, span=span, reps=reps)
    return {
        "config": {"n_clients": n_clients, "local_epochs": 5,
                   "batch_size": 20, "span": span, "reps": reps,
                   "chunk_size": chunk, "n_shards": N_SHARDS,
                   "backend": jax.default_backend(),
                   "slots_per_shard": sharded[0].capacity // N_SHARDS},
        "rounds_per_sec": {
            "single_device": round(rps_single, 2),
            f"sharded_{N_SHARDS}dev": round(rps_sharded, 2),
        },
        "speedup_sharded_vs_single": round(rps_sharded / rps_single, 3),
        "admit_us_sharded": round(_admit_us(sharded[0]), 1),
    }


def _run_or_respawn(**kw):
    import jax

    if len(jax.devices()) >= N_SHARDS:
        return run(**kw)
    # single-device parent (the usual CPU CI case): re-exec under a
    # virtual 4-device mesh — XLA_FLAGS must precede jax initialization;
    # the caller's config rides along as JSON
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{N_SHARDS}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_bench", "--emit",
         "--kw", json.dumps(kw)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.splitlines()[-1])


def main(path="BENCH_engine.json", **kw):
    """Merge the sharded series into BENCH_engine.json under the
    "sharded" key.  The matched sharded-vs-single pair lives only there
    (its own config block): the top-level rounds_per_sec series is
    measured at a different config and through the trainer, so the two
    are not comparable side by side."""
    res = _run_or_respawn(**kw)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["sharded"] = res
    data.get("rounds_per_sec", {}).pop(
        f"engine_sharded_{N_SHARDS}dev", None)   # drop a stale pre-fix key
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit", action="store_true",
                    help="run in-process and print raw JSON (subprocess "
                         "mode; expects the device count already set)")
    ap.add_argument("--kw", default="{}",
                    help="JSON dict of run() kwargs (subprocess mode)")
    ap.add_argument("--json", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.emit:
        print(json.dumps(run(**json.loads(args.kw))))
    else:
        print(json.dumps(main(args.json), indent=2))
