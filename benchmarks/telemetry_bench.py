"""Telemetry overhead benchmark: what instrumentation costs when on,
and that it costs (almost) nothing when off.

The observability plane (src/repro/obs/) is threaded through every hot
path — engine spans, scheduler event application, service iterations —
behind a null-object default.  Two questions decide whether that design
holds up:

  * disabled: rounds/sec and events/sec with the default NullTelemetry
    must match an uninstrumented scheduler (the null path is a handful
    of attribute loads and ``enabled`` checks per round);
  * enabled: the full plane (span ring buffer, histogram observes, the
    per-round FedObserver numpy work) should cost a bounded fraction of
    a round — it runs on the host while the device does the real work.

Plus primitive micro-rates (counter inc, histogram observe, span
enter/exit) so a regression can be localized to one primitive.

Merged into BENCH_stream.json (under "telemetry").
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core.participation import TRACES
from repro.fed.scenarios import build_scheduler, make_scenario
from repro.obs import Telemetry

NO_EVAL = 10 ** 9


def _scheduler(telemetry=None, seed=0, chunk=8):
    sc = make_scenario("flash-crowd", seed=seed)
    sch = build_scheduler(sc, chunk_size=chunk, telemetry=telemetry)
    sch._queue.clear()
    return sch


def _warm(sch, chunk=8):
    r = 1
    while r <= chunk:
        sch.run(r, eval_every=NO_EVAL)
        r *= 2


def bench_rounds(telemetry, rounds=96, reps=3, seed=0):
    """Best-of-reps rounds/sec for blocking event-free spans."""
    sch = _scheduler(telemetry, seed=seed)
    _warm(sch)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        sch.run(rounds, eval_every=NO_EVAL)
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def bench_events(telemetry, n_events=240, seed=0):
    """Events/sec absorbed at span boundaries: a trace-shift per round
    forces the R=1 apply/restage path where observe_event and the
    staleness histogram sit."""
    from repro.fed.stream import TraceShift
    sch = _scheduler(telemetry, seed=seed)
    _warm(sch)
    n_clients = len(sch.clients)
    base = sch._next_tau
    sch.push(*[TraceShift(base + j, client_id=j % n_clients,
                          trace=TRACES[j % 8])
               for j in range(n_events)])
    t0 = time.perf_counter()
    sch.run(n_events, eval_every=NO_EVAL)
    wall = time.perf_counter() - t0
    return n_events / wall


def bench_primitives(n=100_000):
    """Micro-rates of the registry/tracer primitives (ops/sec)."""
    tel = Telemetry()
    c = tel.counter("bench_counter_total")
    h = tel.histogram("bench_hist_seconds")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    counter_rate = n / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for _ in range(n):
        h.observe(1e-3)
    hist_rate = n / (time.perf_counter() - t0)
    m = n // 10
    t0 = time.perf_counter()
    for _ in range(m):
        with tel.span("bench.span"):
            pass
    span_rate = m / (time.perf_counter() - t0)
    return {"counter_inc_per_sec": round(counter_rate),
            "histogram_observe_per_sec": round(hist_rate),
            "span_per_sec": round(span_rate)}


def run(seed=0):
    rps_off = bench_rounds(None, seed=seed)
    rps_on = bench_rounds(Telemetry(), seed=seed)
    eps_off = bench_events(None, seed=seed)
    eps_on = bench_events(Telemetry(), seed=seed)
    return {
        "config": {"scenario": "flash-crowd",
                   "backend": jax.default_backend()},
        "rounds_per_sec_disabled": round(rps_off, 2),
        "rounds_per_sec_enabled": round(rps_on, 2),
        "rounds_overhead_fraction": round(
            max(0.0, 1.0 - rps_on / rps_off), 4),
        "events_per_sec_disabled": round(eps_off, 1),
        "events_per_sec_enabled": round(eps_on, 1),
        "events_overhead_fraction": round(
            max(0.0, 1.0 - eps_on / eps_off), 4),
        "primitives": bench_primitives(),
    }


def main(path="BENCH_stream.json", **kw):
    res = run(**kw)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged["telemetry"] = res
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
