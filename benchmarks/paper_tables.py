"""Paper-table benchmarks (one function per table/figure).

Table 1 (analytic): scheme convergence on closed-form quadratics.
Table 3: scheme accuracy deltas vs heterogeneity |T| on SYNTHETIC + images.
Table 4: fast-reboot recovery epochs vs arrival time tau0.
Table 5: include/exclude crossing epochs vs tau0 and (alpha, beta).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import MNIST_MLP, SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import (label_sorted_partition, make_class_dataset,
                        synthetic_federation)
from repro.fed import Client, FederatedTrainer
from repro.models.small import init_small, logits_small, make_loss_fn


def _eval_fn(cfg):
    def f(params, x, y):
        lg = logits_small(params, cfg, x)
        ll = jax.nn.log_softmax(lg)
        loss = -jnp.mean(jnp.take_along_axis(
            ll, y[:, None].astype(jnp.int32), axis=1))
        acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
        return float(loss), float(acc)
    return f


def _clients_synthetic(n, alpha, beta, n_traces, seed=0):
    train, test = synthetic_federation(alpha, beta, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, n_traces)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def _clients_images(n, n_traces, noniid, seed=0):
    x, y = make_class_dataset(10, 400, seed=seed)
    if noniid:
        train, test = label_sorted_partition(x, y, n, seed=seed)
    else:
        from repro.data import iid_partition
        train, test = iid_partition(x, y, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, n_traces)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def _run(cfg, clients, scheme, rounds, eta0, seed=0):
    tr = FederatedTrainer(
        loss_fn=make_loss_fn(cfg), eval_fn=_eval_fn(cfg),
        init_params=init_small(jax.random.PRNGKey(seed), cfg),
        clients=clients, local_epochs=5, batch_size=cfg.batch_size,
        scheme=scheme, eta0=eta0, seed=seed)
    hist = tr.run(rounds, eval_every=5)
    # non-eval rounds record NaN (honest records): average the last
    # three *evaluated* rounds
    accs = [h.acc for h in hist if np.isfinite(h.acc)]
    return float(np.mean(accs[-3:])), tr


def table3_scheme_comparison(rounds=60, n_clients=24, dataset="synthetic"):
    """CSV rows: dataset,iid,|T|,acc_A,acc_B,acc_C,B-A,C-B."""
    rows = []
    for noniid in (False, True):
        for n_traces in (1, 4, 8):
            accs = {}
            for scheme in "ABC":
                if dataset == "synthetic":
                    ab = (1.0, 1.0) if noniid else (0.0, 0.0)
                    clients = _clients_synthetic(n_clients, *ab, n_traces)
                    cfg, eta0 = SYNTHETIC_LR, 1.0
                else:
                    clients = _clients_images(n_clients, n_traces, noniid)
                    cfg, eta0 = MNIST_MLP, 0.05
                accs[scheme], _ = _run(cfg, clients, scheme, rounds, eta0)
            rows.append((dataset, "niid" if noniid else "iid", n_traces,
                         accs["A"], accs["B"], accs["C"],
                         accs["B"] - accs["A"], accs["C"] - accs["B"]))
    return rows


def table4_fast_reboot(rounds_after=60, taus=(10, 30, 50)):
    """Recovery epochs (accuracy back to pre-arrival level) fast vs vanilla
    reboot.  CSV rows: tau0, recover_fast, recover_vanilla."""
    rows = []
    for tau0 in taus:
        rec = {}
        for fast in (True, False):
            clients = _clients_synthetic(9, 1.0, 1.0, 5, seed=4)
            extra = _clients_synthetic(1, 1.0, 1.0, 5, seed=99)[0]
            extra.active_from = tau0
            clients.append(extra)
            cfg = SYNTHETIC_LR
            tr = FederatedTrainer(
                loss_fn=make_loss_fn(cfg), eval_fn=_eval_fn(cfg),
                init_params=init_small(jax.random.PRNGKey(0), cfg),
                clients=clients, local_epochs=5, batch_size=20,
                scheme="C", eta0=1.0, seed=0, fast_reboot=fast)
            hist = tr.run(tau0 + rounds_after)
            acc_before = hist[tau0 - 1].acc
            rec[fast] = next(
                (h.tau - tau0 for h in hist[tau0 + 1:]
                 if h.acc >= acc_before), rounds_after)
        rows.append((tau0, rec[True], rec[False]))
    return rows


def table5_departure_crossing(taus=(10, 25, 40), abs_=((0.1, 0.1),
                                                       (1.0, 1.0))):
    """Crossing epochs between include/exclude test-loss curves."""
    rows = []
    for (a, b) in abs_:
        for tau0 in taus:
            losses = {}
            for policy in ("include", "exclude"):
                clients = _clients_synthetic(10, a, b, 5, seed=7)
                clients[0].departs_at = tau0
                clients[0].departure_policy = policy
                cfg = SYNTHETIC_LR
                tr = FederatedTrainer(
                    loss_fn=make_loss_fn(cfg), eval_fn=_eval_fn(cfg),
                    init_params=init_small(jax.random.PRNGKey(0), cfg),
                    clients=clients, local_epochs=5, batch_size=20,
                    scheme="C", eta0=1.0, seed=0)
                hist = tr.run(tau0 + 60)
                # evaluate both on the *post-departure* objective of the run
                losses[policy] = np.array([h.loss for h in hist[tau0:]])
            diff = losses["exclude"] - losses["include"]
            cross = next((i for i, d in enumerate(diff) if d <= 0), -1)
            rows.append((a, b, tau0, cross))
    return rows
