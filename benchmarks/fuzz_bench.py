"""Robustness benchmark: event-stream fuzz corpus + supervised chaos soak
+ the theory-scored validation harness.

The numbers that matter for the chaos-hardened service layer:

  * fuzz throughput — seeded interleavings/sec the invariant fuzzer
    (fed/fuzz.py) can execute against a pooled warm engine, and whether
    any seed in the nightly corpus violates an invariant (exact resume,
    zero recompile, scheme-weight sanity, plan-vs-device parity);
  * chaos MTTR — a supervised FederationService is run under a fault
    plan that fires every injector site in ONE run (worker crash, worker
    hang caught by the watchdog, mid-span scheduler crash, checkpoint
    write failure, checkpoint corruption, a 256-event stale flood) and
    must auto-recover with RoundRecord history and final params
    bit-identical to a fault-free run.  Reported: recoveries, mean/max
    time-to-recover, rounds recomputed, snapshot failures absorbed;
  * validator throughput — fuzzed participation schedules executed on
    closed-form quadratic federations under all three schemes and
    scored against the Theorem 3.1 envelope + Table-1 ordering
    (fed/validate.py);
  * backend matrix — the same seeded op schedules cross-checked across
    the client_parallel and client_sequential engines (the sharded
    third backend needs a multi-device mesh; tests run it in a
    subprocess);
  * fuzzed chaos — generated fault plans against generated event
    schedules through a real supervised service, bit-exact vs the
    fault-free service run (fed.fuzz.run_chaos_corpus).

Merged into BENCH_stream.json (under "fuzz" — with "validator",
"backends" and "fuzzed_chaos" sub-blocks — and "chaos") so the
robustness trajectory lives next to the streaming numbers.

  PYTHONPATH=src python -m benchmarks.fuzz_bench             # all
  PYTHONPATH=src python -m benchmarks.run --fuzz-seeds 16    # via run.py
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

NO_EVAL = 10 ** 9

# The acceptance fault plan: every site fires once, ordered so that the
# corrupted snapshot is the newest on disk when the next crash recovers
# (span k: worker fault -> 4 rounds -> save k+1; save 0 is the gen-0 base).
ACCEPTANCE_FAULTS = [
    ("worker", 1, "crash", 0, 0.0),
    ("worker", 4, "hang", 0, 30.0),
    ("sched_span", 6, "crash", 0, 0.0),
    ("ckpt_save", 3, "io-error", 0, 0.0),
    ("ckpt_written", 5, "corrupt", 16, 0.0),
    ("flood", 2, "flood", 256, 0.0),
]


def _make_clients(n=4, seed=0):
    from repro.core.participation import TRACES
    from repro.data import synthetic_federation
    from repro.fed import Client
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[0],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def _make_scheduler(**kw):
    from repro.configs.paper import SYNTHETIC_LR
    from repro.fed import StreamScheduler
    from repro.models.small import init_small, make_loss_fn
    return StreamScheduler(
        clients=_make_clients(), init_params=init_small(
            jax.random.PRNGKey(0), SYNTHETIC_LR),
        loss_fn=make_loss_fn(SYNTHETIC_LR), capacity=6, max_samples=600,
        local_epochs=5, batch_size=6, scheme="C", eta0=1.0, seed=0,
        mode="device", chunk_size=4, **kw)


def bench_fuzz(n_seeds=64, seed0=0, check_plan_parity=True):
    """Run the corpus against one pooled harness; returns timing plus the
    aggregate from fed.fuzz.run_corpus (raises InvariantViolation on the
    first seed that breaks an invariant — a red nightly is the point)."""
    from repro.fed import FuzzHarness, run_corpus
    t0 = time.perf_counter()
    harness = FuzzHarness()
    setup_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    agg = run_corpus(range(seed0, seed0 + n_seeds), harness=harness,
                     check_plan_parity=check_plan_parity)
    wall = time.perf_counter() - t0
    return {
        "n_seeds": n_seeds,
        "seed_range": [seed0, seed0 + n_seeds],
        "check_plan_parity": check_plan_parity,
        "harness_setup_s": round(setup_s, 2),
        "wall_s": round(wall, 2),
        "cases_per_sec": round(n_seeds / wall, 2),
        "total_rounds": agg["rounds"],
        "total_kills": agg["kills"],
        "total_resumes": agg["resumes"],
        "events_applied": agg["events_applied"],
        "violations": 0,                  # run_corpus raises otherwise
    }


def bench_chaos(plan_seed=7, rounds=32, verify=True):
    """The acceptance soak: every fault site fires in one supervised run;
    optionally verify history + params bit-exact against a clean run."""
    from repro.fed import Fault, FaultPlan, FederationService
    from repro.models.small import make_loss_fn
    from repro.configs.paper import SYNTHETIC_LR

    plan = FaultPlan([Fault(site, at, kind, size=size, seconds=secs)
                      for site, at, kind, size, secs in ACCEPTANCE_FAULTS],
                     seed=plan_seed)
    sch = _make_scheduler(injector=plan)
    eng = sch.engine
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d:
        svc = FederationService(
            sch, span_rounds=4, max_rounds=rounds, supervise=True,
            snapshot_dir=d, snapshot_every=1, keep_snapshots=4,
            backoff0=0.01, span_timeout=2.0, join_timeout=10.0,
            queue_policy="merge-stale", max_queue=64,
            engine_factory=lambda: eng,
            restore_kwargs=dict(loss_fn=make_loss_fn(SYNTHETIC_LR)))
        with svc:
            ok = svc.wait_rounds(rounds, timeout=300)
        report = svc.chaos_report()
        live = svc.scheduler
    wall = time.perf_counter() - t0
    if not ok:
        raise RuntimeError(f"chaos soak stalled: {report}")

    bitexact = None
    if verify:
        ref = _make_scheduler()
        ref.run(rounds, eval_every=NO_EVAL)
        bitexact = len(ref.history) == len(live.history)
        for r1, r2 in zip(ref.history, live.history):
            bitexact = bitexact and (r1.tau == r2.tau
                                     and r1.event == r2.event
                                     and r1.eta == r2.eta
                                     and np.array_equal(r1.s, r2.s))
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(live.params)):
            bitexact = bitexact and np.array_equal(np.asarray(a),
                                                   np.asarray(b))
    report.update(plan_seed=plan_seed, rounds=rounds,
                  wall_s=round(wall, 2), bitexact=bitexact)
    report["recoveries"] = [
        {k: (v if k != "cause" else v[:80]) for k, v in r.items()}
        for r in report["recoveries"]]
    return report


def bench_validator(n_seeds=4, rounds=64):
    """Theory-scored validation throughput: each seed fuzzes a
    participation schedule, runs it under schemes A/B/C on the quadratic
    federation and scores every run against the Thm 3.1 envelope plus
    the Table-1 ordering (raises on the first violating seed)."""
    from repro.fed import QuadraticRunner, validate_corpus
    t0 = time.perf_counter()
    runner = QuadraticRunner()
    runner.run("A", rounds=2)          # compile all three scheme engines
    runner.run("B", rounds=2)
    runner.run("C", rounds=2)
    setup_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    agg = validate_corpus(range(n_seeds), runner=runner, rounds=rounds)
    wall = time.perf_counter() - t0
    return {
        "n_seeds": n_seeds,
        "rounds_per_run": rounds,
        "setup_s": round(setup_s, 2),
        "wall_s": round(wall, 2),
        "runs_per_sec": round(3 * n_seeds / wall, 2),
        "rounds_per_sec": round(agg["rounds"] / wall, 1),
        "max_margin": agg["max_margin"],
        "violations": 0,               # validate_corpus raises otherwise
    }


def bench_backends(n_seeds=6):
    """Cross-backend parity throughput over the in-process backends."""
    from repro.fed import run_backend_matrix
    t0 = time.perf_counter()
    agg = run_backend_matrix(range(n_seeds))
    wall = time.perf_counter() - t0
    return {
        "n_seeds": n_seeds,
        "backends": agg["backends"],
        "wall_s": round(wall, 2),
        "cases_per_sec": round(n_seeds / wall, 2),
        "total_rounds": agg["rounds"],
        "max_param_err": agg["max_param_err"],
        "violations": 0,               # the matrix raises otherwise
    }


def bench_fuzzed_chaos(n_seeds=6):
    """Generated fault plans x generated event schedules through a real
    supervised service; every recovered run verified bit-exact against
    the fault-free service run."""
    from repro.fed import FuzzHarness, run_chaos_corpus
    t0 = time.perf_counter()
    harness = FuzzHarness()
    setup_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    agg = run_chaos_corpus(range(n_seeds), harness=harness)
    wall = time.perf_counter() - t0
    return {
        "n_seeds": n_seeds,
        "harness_setup_s": round(setup_s, 2),
        "wall_s": round(wall, 2),
        "cases_per_sec": round(n_seeds / wall, 2),
        "total_rounds": agg["rounds"],
        "recoveries": agg["recoveries"],
        "events_merged": agg["events_merged"],
        "mttr_mean_s": round(agg["mttr_mean_s"], 3),
        "mttr_max_s": round(agg["mttr_max_s"], 3),
        "violations": 0,               # run_chaos_corpus raises otherwise
    }


def run(n_seeds=64, plan_seed=7, rounds=32):
    # the auxiliary corpora scale down from the main fuzz corpus: each
    # validator seed costs 3 x 64 engine rounds, each chaos seed a full
    # supervised service lifecycle
    fuzz = bench_fuzz(n_seeds=n_seeds)
    fuzz["validator"] = bench_validator(n_seeds=max(2, n_seeds // 16))
    fuzz["backends"] = bench_backends(n_seeds=max(4, n_seeds // 8))
    fuzz["fuzzed_chaos"] = bench_fuzzed_chaos(n_seeds=max(4, n_seeds // 8))
    return {
        "config": {"backend": jax.default_backend()},
        "fuzz": fuzz,
        "chaos": bench_chaos(plan_seed=plan_seed, rounds=rounds),
    }


def main(path="BENCH_stream.json", **kw):
    res = run(**kw)
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged["fuzz"] = res["fuzz"]
    merged["chaos"] = res["chaos"]
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
