"""Scheme A/B/C coefficient math + the paper's debiasing property."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (expected_coeff_stats,
                                    scheme_coefficients, theta_bound)

E = 5


def test_scheme_a_only_complete_devices():
    p = jnp.asarray([0.5, 0.3, 0.2])
    s = jnp.asarray([5.0, 3.0, 5.0])
    c = np.asarray(scheme_coefficients("A", p, s, E))
    assert c[1] == 0.0
    # N p^k / K for the two complete devices
    np.testing.assert_allclose(c[0], 3 * 0.5 / 2)
    np.testing.assert_allclose(c[2], 3 * 0.2 / 2)


def test_scheme_a_no_complete_devices_drops_round():
    p = jnp.asarray([0.5, 0.5])
    s = jnp.asarray([3.0, 0.0])
    c = np.asarray(scheme_coefficients("A", p, s, E))
    np.testing.assert_allclose(c, 0.0)


def test_scheme_b_fixed_coefficients():
    p = jnp.asarray([0.6, 0.4])
    s = jnp.asarray([2.0, 5.0])
    c = np.asarray(scheme_coefficients("B", p, s, E))
    np.testing.assert_allclose(c, [0.6, 0.4])


def test_scheme_c_rescales_incomplete():
    p = jnp.asarray([0.5, 0.25, 0.25])
    s = jnp.asarray([5.0, 1.0, 0.0])
    c = np.asarray(scheme_coefficients("C", p, s, E))
    np.testing.assert_allclose(c, [0.5, E * 0.25, 0.0])


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 10**6))
def test_scheme_c_satisfies_theta_bound(n, seed):
    """Assumption 3.5: p_tau^k / p^k <= theta for every scheme."""
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(n))
    s = rng.integers(0, E + 1, n).astype(float)
    for scheme in "ABC":
        c = np.asarray(scheme_coefficients(scheme, jnp.asarray(p),
                                           jnp.asarray(s), E))
        th = theta_bound(scheme, n, E)
        assert np.all(c <= th * p + 1e-6), (scheme, c, p)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_scheme_c_unbiased_ratio_heterogeneous(seed):
    """The paper's key property (App. A.4.3): under Scheme C,
    E[p_tau^k s_tau^k] / p^k == E for every ACTIVE client regardless of its
    participation distribution => z_tau = 0 when no client is fully
    inactive.  Schemes A/B break this under heterogeneity."""
    rng = np.random.default_rng(seed)
    n = 4
    p = rng.dirichlet(np.ones(n))
    # heterogeneous, never-inactive distributions per client
    probs = rng.uniform(0.2, 1.0, size=n)

    def sampler(r):
        return np.maximum(r.binomial(E, probs), 1)

    stats_c = expected_coeff_stats("C", p, sampler, E, n_rounds=400,
                                   seed=seed)
    np.testing.assert_allclose(stats_c["ratio"], E, rtol=1e-6)
    assert stats_c["z"] == 0.0

    stats_b = expected_coeff_stats("B", p, sampler, E, n_rounds=400,
                                   seed=seed)
    # heterogeneous means E[s^k] differ across clients -> biased
    if np.std(probs) > 0.1:
        assert stats_b["z"] == 1.0


def test_scheme_b_homogeneous_unbiased():
    rng = np.random.default_rng(0)
    p = np.array([0.25, 0.25, 0.25, 0.25])

    def sampler(r):
        return np.maximum(r.binomial(E, 0.6, size=4), 1)

    stats = expected_coeff_stats("B", p, sampler, E, n_rounds=3000)
    assert stats["z"] == 0.0 or np.std(stats["ratio"]) < 0.1
