"""Observability plane: metrics registry, span tracing, paper gauges,
and the fed_top view.

The acceptance-critical properties pinned here:

  * with the default NullTelemetry the training path is bit-identical —
    same history, same params, same jit trace count — so observability
    can never perturb the science;
  * with telemetry enabled the overhead stays bounded (the plane is
    host-side numpy/dict work, far off the jit path);
  * histogram bucket math and the Prometheus exposition agree with the
    cumulative-``le`` semantics scrapers expect;
  * fed_top renders a frame headlessly against a live FederationService.
"""
import json
import math
import time

import jax
import numpy as np
import pytest

from repro.obs import (DEFAULT_BUCKETS, MetricsRegistry, NullTelemetry,
                       Telemetry, Tracer, resolve, scheme_mass)
from repro.obs.telemetry import NULL

from test_stream import make_clients, make_scheduler

NO_EVAL = 10 ** 9


# -- metrics registry ----------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g", "a gauge")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_histogram_le_inclusive_bucket_math():
    reg = MetricsRegistry()
    h = reg.histogram("h_seconds", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 8.0):
        h.observe(v)
    # cumulative form, le-inclusive: 1.0 lands in le="1"
    assert h.buckets() == [(1.0, 2), (2.0, 4), (4.0, 4), (math.inf, 5)]
    assert h.count == 5
    assert h.sum == pytest.approx(13.0)


def test_observe_many_matches_scalar_observe():
    reg = MetricsRegistry()
    a = reg.histogram("a_seconds")
    b = reg.histogram("b_seconds")
    vals = np.abs(np.random.default_rng(0).normal(0.01, 0.05, 500))
    for v in vals:
        a.observe(float(v))
    b.observe_many(vals)
    assert a.buckets() == b.buckets()
    assert a.sum == pytest.approx(b.sum)


def test_registry_idempotent_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total")
    c2 = reg.counter("x_total")
    assert c1 is c2
    with pytest.raises(ValueError):
        reg.gauge("x_total")
    fam = reg.counter("y_total", labelnames=("site",))
    assert fam.labels("a") is fam.labels("a")
    assert fam.labels("a") is not fam.labels("b")


def test_prom_rendering_roundtrip():
    reg = MetricsRegistry()
    reg.counter("ev_total", "events").inc(3)
    h = reg.histogram("lat_seconds", "latency", labelnames=("name",),
                      buckets=(0.1, 1.0))
    h.labels("run").observe(0.05)
    h.labels("run").observe(0.5)
    h.labels("run").observe(5.0)
    text = reg.render_prom()
    lines = text.splitlines()
    assert "# TYPE ev_total counter" in lines
    assert "ev_total 3" in lines
    assert 'lat_seconds_bucket{name="run",le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{name="run",le="1"} 2' in lines
    assert 'lat_seconds_bucket{name="run",le="+Inf"} 3' in lines
    assert 'lat_seconds_count{name="run"} 3' in lines
    # snapshot mirrors the same numbers as plain data (JSONL sink path)
    snap = reg.snapshot()
    assert snap["ev_total"]["samples"][0]["value"] == 3
    s = snap["lat_seconds"]["samples"][0]
    assert s["labels"] == {"name": "run"} and s["count"] == 3
    json.dumps(snap)                      # JSON-serializable throughout


# -- tracing -------------------------------------------------------------------

def test_span_nesting_and_jsonl_export(tmp_path):
    tr = Tracer(capacity=16)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    spans = tr.peek(10)
    by_name = {s["name"]: s for s in spans}
    assert by_name["inner"]["parent"] == by_name["outer"]["id"]
    assert by_name["outer"]["parent"] is None
    assert by_name["outer"]["attrs"] == {"k": 1}
    assert all(s["dur_s"] >= 0 for s in spans)
    path = tmp_path / "spans.jsonl"
    n = tr.export_jsonl(str(path))
    assert n == 2
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert {x["name"] for x in lines} == {"outer", "inner"}
    assert tr.peek(10) == []              # export drained the ring


def test_tracer_ring_drops_oldest():
    tr = Tracer(capacity=2)
    for j in range(5):
        with tr.span(f"s{j}"):
            pass
    assert tr.recorded == 5
    assert tr.dropped == 3
    assert [s["name"] for s in tr.peek(10)] == ["s3", "s4"]


def test_telemetry_span_feeds_latency_histogram():
    tel = Telemetry()
    with tel.span("work"):
        pass
    h = tel.registry.histogram("span_seconds",
                               labelnames=("name",)).labels("work")
    assert h.count == 1


# -- null telemetry ------------------------------------------------------------

def test_null_telemetry_is_inert():
    tel = resolve(None)
    assert tel is NULL and not tel.enabled
    assert isinstance(tel, NullTelemetry)
    c = tel.counter("whatever")
    c.inc()
    assert c.value == 0.0
    with tel.span("x", a=1):
        pass
    assert tel.render_prom() == ""


def test_null_telemetry_history_bit_identical_and_no_recompiles():
    """The tentpole invariant: instrumentation off the jit path, null by
    default — identical history, identical params, identical number of
    scan traces."""
    from repro.fed.stream import Arrival, TraceShift
    from repro.core.participation import TRACES

    def run_one(telemetry):
        clients = make_clients(6, seed=2)
        late = make_clients(8, seed=2)[7]
        sch = make_scheduler(clients, capacity=8, seed=2,
                             telemetry=telemetry,
                             events=[TraceShift(3, client_id=1,
                                                trace=TRACES[0]),
                                     Arrival(5, client=late, client_id=9)])
        sch.run(10, eval_every=4)
        return sch

    a = run_one(None)
    b = run_one(Telemetry())
    assert a.engine.trace_count == b.engine.trace_count
    assert len(a.history) == len(b.history)
    for ra, rb in zip(a.history, b.history):
        assert ra.tau == rb.tau and ra.event == rb.event
        assert ra.n_active == rb.n_active
        np.testing.assert_array_equal(np.asarray(ra.s), np.asarray(rb.s))
        assert (ra.loss == rb.loss or
                (ra.loss != ra.loss and rb.loss != rb.loss))
    for la, lb in zip(jax.tree.leaves(a.params),
                      jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_enabled_overhead_bounded():
    """Telemetry-on rounds/sec stays within a pinned fraction of
    telemetry-off (generous pin: the plane is host-side accounting)."""
    def rps(telemetry, rounds=48, reps=3):
        sch = make_scheduler(make_clients(6, seed=0), seed=0,
                             telemetry=telemetry)
        sch.run(4, eval_every=NO_EVAL)    # compile warmup
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            sch.run(rounds, eval_every=NO_EVAL)
            best = min(best, time.perf_counter() - t0)
        return rounds / best

    off, on = rps(None), rps(Telemetry())
    assert on >= 0.4 * off, (off, on)


# -- paper gauges (fedmetrics) -------------------------------------------------

def test_scheme_mass_matches_core_coefficients():
    from repro.core.aggregation import scheme_coefficients
    rng = np.random.default_rng(0)
    p = rng.random(8)
    p /= p.sum()
    s = rng.integers(0, 6, 8).astype(float)
    for scheme in ("A", "B", "C"):
        want = float(np.sum(np.asarray(
            scheme_coefficients(scheme, p, s, E=5))))
        assert scheme_mass(scheme, p, s, 5) == pytest.approx(
            want, rel=1e-5)


def test_fed_observer_gauges_from_live_run():
    from repro.fed.stream import TraceShift
    from repro.core.participation import TRACES
    tel = Telemetry()
    sch = make_scheduler(make_clients(6, seed=1), seed=1, telemetry=tel,
                         events=[TraceShift(2, client_id=0,
                                            trace=TRACES[1])])
    sch.run(8, eval_every=NO_EVAL)
    reg = tel.registry

    assert reg.counter("fed_rounds_total").value == 8
    assert reg.counter("sched_events_applied_total",
                       labelnames=("kind",)).labels(
        "TraceShift").value == 1
    assert reg.histogram("fed_event_staleness_rounds").count == 1
    n_obj = reg.gauge("fed_objective_clients").value
    active = reg.gauge("fed_active_clients").value
    inactive = reg.gauge("fed_inactive_clients").value
    assert n_obj == 6 and 0 <= active <= 6
    assert inactive == max(0.0, n_obj - active)
    assert reg.gauge("fed_scheme_weight_mass").value > 0
    fam = reg.gauge("fed_participation_rate", labelnames=("stat",))
    lo, mid, hi = (fam.labels("min").value, fam.labels("mean").value,
                   fam.labels("max").value)
    assert 0.0 <= lo <= mid <= hi <= 1.0
    # observer exposes the per-client view fed_top prints
    part = sch.observer.participation()
    assert set(part) == set(range(6))
    assert all(0 <= k <= n for k, n in part.values())


def test_bound_gauges_with_tractable_problem():
    from repro.core.aggregation import theta_bound
    from repro.core.theory import quadratic_problem_constants
    tel = Telemetry()
    sch = make_scheduler(make_clients(4, seed=3), seed=3, telemetry=tel)
    rng = np.random.default_rng(3)
    A_list = [np.diag(rng.uniform(0.5, 2.0, 2)) for _ in range(4)]
    c_list = [rng.normal(size=2) for _ in range(4)]
    p = np.full(4, 0.25)
    pc, _ = quadratic_problem_constants(A_list, c_list, p)
    sch.observer.set_problem(pc, theta=theta_bound("C", 4, 5))
    sch.run(6, eval_every=NO_EVAL)
    fam = tel.registry.gauge("fed_bound", labelnames=("term",))
    value = fam.labels("value").value
    assert value > 0 and math.isfinite(value)
    assert fam.labels("D").value >= 0
    assert fam.labels("gamma").value > 0


# -- service + fed_top ---------------------------------------------------------

def test_service_counters_work_without_telemetry():
    """drain()/stats() rely on functional counters even when the shared
    telemetry is the null object — the service keeps a private
    registry."""
    from repro.fed.service import FederationService
    from repro.fed.stream import TraceShift
    from repro.core.participation import TRACES
    sch = make_scheduler(make_clients(4, seed=0), seed=0)
    svc = FederationService(sch, span_rounds=2, eval_every=NO_EVAL,
                            max_rounds=8)
    assert not svc.telemetry.enabled
    with svc:
        assert svc.submit(TraceShift(0, client_id=0, trace=TRACES[2]))
        assert svc.drain(timeout=30)
        assert svc.wait_rounds(8, timeout=60)
    st = svc.stats()
    assert st["events_submitted"] == st["events_ingested"] == 1
    rep = svc.chaos_report()
    assert rep["detect_latency_mean_s"] == 0.0
    assert rep["n_recoveries"] == 0


def test_fed_top_renders_headlessly_against_live_service():
    from repro.fed.service import FederationService
    from repro.launch.fed_top import FedTop
    tel = Telemetry()
    sch = make_scheduler(make_clients(4, seed=0), seed=0, telemetry=tel)
    svc = FederationService(sch, span_rounds=2, eval_every=NO_EVAL,
                            max_rounds=8)
    with svc:
        svc.wait_rounds(8, timeout=60)
        top = FedTop(svc)
        frame1 = top.frame()
        frame2 = top.frame()              # second frame: rate available
    for needle in ("fed_top", "rounds", "events", "service", "paper",
                   "tau=8"):
        assert needle in frame2, frame2
    assert "r/s" in frame2                # rate needs two frames
    assert frame1.count("\n") >= 6

    # null-telemetry service still renders (registry-backed counters)
    sch2 = make_scheduler(make_clients(4, seed=0), seed=0)
    svc2 = FederationService(sch2, span_rounds=2, eval_every=NO_EVAL,
                             max_rounds=4)
    with svc2:
        svc2.wait_rounds(4, timeout=60)
        frame = FedTop(svc2).frame()
    assert "fed_top" in frame and "paper" not in frame
