"""Serving-path correctness: prefill + step-by-step decode must reproduce
the full-forward logits for every cache type (GQA, SWA ring buffer, MLA
absorbed, SSM state, hybrid)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)

DECODE_ARCHS = ["gemma-7b", "starcoder2-3b", "mamba2-130m", "hymba-1.5b",
                "deepseek-v2-lite-16b", "deepseek-v3-671b",
                "musicgen-medium", "command-r-plus-104b", "nemotron-4-15b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    B, S = 2, 32
    shp = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tokens = jax.random.randint(KEY, shp, 0, cfg.vocab)
    h, _, _ = transformer.model_forward(params, cfg, tokens)
    full_lg = transformer.logits_fn(params, cfg, h)[..., : cfg.vocab]
    Sp = S - 4
    cache = transformer.init_cache(cfg, B, S)
    lg, cache = transformer.prefill(params, cfg, tokens[:, :Sp], cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_lg[:, Sp - 1]),
                               rtol=1e-4, atol=1e-4)
    for t in range(Sp, S):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            tokens[:, t:t + 1],
                                            jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_lg[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_ring_buffer_cache_is_window_sized():
    cfg = get_config("starcoder2-3b").reduced()
    assert cfg.sliding_window == 64
    cache = transformer.init_cache(cfg, batch=1, max_len=4096)
    k = cache["blocks"]["attn"]["k"]
    assert k.shape[2] == cfg.sliding_window  # slots == window, not seq


def test_sliding_window_decode_past_window():
    """Decode far beyond the window: ring buffer must keep matching the
    full forward (which masks beyond the window too)."""
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(KEY, cfg)
    B, S = 1, 160  # window is 64 -> wraps the ring 2.5x
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    h, _, _ = transformer.model_forward(params, cfg, tokens)
    full_lg = transformer.logits_fn(params, cfg, h)[..., : cfg.vocab]
    cache = transformer.init_cache(cfg, B, S)
    lg, cache = transformer.prefill(params, cfg, tokens[:, :8], cache)
    for t in range(8, S):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            tokens[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_lg[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_mla_cache_is_compressed():
    cfg = get_config("deepseek-v3-671b").reduced()
    cache = transformer.init_cache(cfg, batch=1, max_len=128)
    moe = cache["moe_blocks"]["attn"]
    assert set(moe) == {"ckv", "krope", "pos_map"}
    assert moe["ckv"].shape[-1] == cfg.kv_lora_rank  # latent, not per-head


def test_vlm_prefill_with_patches_then_decode():
    """LLaVA path: patch embeddings prepended at prefill; decode continues
    from the mixed-modality cache and matches the full forward."""
    cfg = get_config("llava-next-34b").reduced()
    params = init_params(KEY, cfg)
    B, S_text = 2, 24
    Pn = cfg.n_patches
    tokens = jax.random.randint(KEY, (B, S_text), 0, cfg.vocab)
    patch = 0.02 * jax.random.normal(KEY, (B, Pn, cfg.d_model), jnp.float32)
    h, _, _ = transformer.model_forward(params, cfg, tokens,
                                        patch_emb=patch)
    full_lg = transformer.logits_fn(params, cfg, h)[..., : cfg.vocab]
    total = Pn + S_text
    cache = transformer.init_cache(cfg, B, total + 4)
    lg, cache = transformer.prefill(params, cfg, tokens[:, : S_text - 4],
                                    cache, patch_emb=patch)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_lg[:, Pn + S_text - 5]),
                               rtol=1e-4, atol=1e-4)
    for t in range(S_text - 4, S_text):
        pos = Pn + t
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            tokens[:, t:t + 1],
                                            jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_lg[:, pos]),
                                   rtol=1e-4, atol=1e-4)


def test_flash_attn_impl_matches_chunked_prefill():
    """attn_impl='flash' (Pallas kernel, interpret on CPU) reproduces the
    chunked-jnp prefill logits."""
    import dataclasses
    cfg = get_config("gemma-7b").reduced()
    params = init_params(KEY, cfg)
    B, S = 1, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    h1, _, _ = transformer.model_forward(params, cfg, tokens)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash")
    h2, _, _ = transformer.model_forward(params, cfg_f, tokens)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=1e-3, atol=1e-3)
