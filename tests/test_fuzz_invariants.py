"""Event-stream fuzzer (fed/fuzz.py): a seeded corpus of adversarial
interleavings — arrivals, departures, rejoins, trace shifts, bursts,
duplicate deliveries, kill/restore — each checked against the control
plane's invariants (exact resume, zero recompile, scheme-weight sanity,
plan-vs-device parity), plus the two fuzz dimensions layered on top:
cross-backend parity (the same op schedule on the parallel / sequential
/ sharded engines walks one trajectory) and fuzzed supervised chaos
(generated fault plans through a real FederationService, bit-exact vs
the fault-free run).  Plus the meta-tests: deliberately break each
invariant source and assert the fuzzer actually catches it."""
import os

import numpy as np
import pytest

import _subproc
from repro.fed import (FedState, FuzzHarness, InvariantViolation,
                       generate_case, make_backend_pool, run_backend_matrix,
                       run_chaos_corpus, run_corpus, run_fuzz_case)

# The tier-1 corpus: recorded so a violating seed reproduces exactly
# (`run_fuzz_case(FuzzHarness(), seed)` replays one).  Size is
# env-tunable (REPRO_FUZZ_SEEDS); nightly scale (128 seeds + the full
# backend matrix) lives in benchmarks/fuzz_bench.py / run.py --full.
CORPUS_SEEDS = range(int(os.environ.get("REPRO_FUZZ_SEEDS", "30")))

pytestmark = pytest.mark.fuzz


@pytest.fixture(scope="module")
def harness():
    """One warm engine for the whole module — a fresh RoundEngine costs
    ~4s of compiles; the fuzzer's zero-recompile invariant needs the
    pooled engine anyway."""
    return FuzzHarness()


def test_corpus_passes_all_invariants(harness):
    agg = run_corpus(CORPUS_SEEDS, harness=harness)
    assert agg["cases"] == len(CORPUS_SEEDS)
    # the corpus must actually exercise the machinery, not no-op through
    assert agg["rounds"] > 100
    assert agg["kills"] > 0                 # some cases kill + restore
    assert agg["resumes"] == agg["kills"]   # every kill resumed
    assert agg["events_applied"] > 30
    assert all(r["plan_parity"] for r in agg["per_case"])


def test_generator_is_reproducible():
    for seed in (0, 7, 123):
        a = generate_case(seed)
        b = generate_case(seed)
        assert a.seed == b.seed == seed
        assert a.ops == b.ops
        assert a.total_rounds == b.total_rounds
        assert a.n_kills == b.n_kills
    # and different seeds explore different interleavings
    assert generate_case(0).ops != generate_case(1).ops


def test_case_replay_matches_fresh_generation(harness):
    case = generate_case(3)
    fresh = run_fuzz_case(harness, 3)
    replay = run_fuzz_case(harness, 3, case=case)
    assert fresh == replay


# -- cross-backend parity ------------------------------------------------------

def test_generator_fresh_arrival_taus_nondecreasing():
    """Fresh payload arrivals register in *application* order, so the
    generator's pool-order id model is only sound if their taus never
    decrease.  Seed 41 used to invert two arrivals and hand a
    TraceShift an id that didn't exist yet at its boundary
    (clients[i] IndexError deep in a fuzz run)."""
    for seed in range(64):
        taus = [op[1]["tau"] for op in generate_case(seed).ops
                if op[0] == "push" and op[1]["kind"] == "arrival"
                and op[1].get("client_id", 0) < 0]
        assert taus == sorted(taus), f"seed {seed}: {taus}"


def test_trace_shift_does_not_mutate_aliased_payload():
    """Copy-on-shift: the Client object a payload Arrival registered is
    aliased by that event (and by any service journal replaying it
    after a crash) — apply(TraceShift) must swap the registered object,
    never write through the alias, or post-rollback replay re-registers
    the shifted law and breaks chaos bit-exactness."""
    from repro.core.participation import TRACES
    from repro.fed import Arrival, TraceShift
    from repro.fed.scenarios import _make_clients

    st = FedState(clients=[], capacity=4)
    payload = _make_clients(1, seed=3)[0]
    original_trace = payload.trace
    st.push(Arrival(0, client=payload))
    assert st.due(0)
    for _, _, e in sorted(st.queue):
        st.apply(e, 0)
    st.queue.clear()
    cid = len(st.clients) - 1
    st.apply(TraceShift(1, client_id=cid, trace=TRACES[0]), 1)
    assert payload.trace is original_trace          # alias untouched
    assert st.clients[cid].trace is TRACES[0]       # state shifted
    # unknown device: no-op, never an IndexError
    assert st.apply(TraceShift(1, client_id=99, trace=TRACES[0]),
                    1) == ("", [])


def test_backend_parity_parallel_vs_sequential():
    """The same seeded op schedules on the fused-vmap and streaming
    engines: exact control plane + s streams, params within tolerance.
    The sharded third backend needs a multi-device mesh and runs in the
    subprocess below."""
    agg = run_backend_matrix(range(4))
    assert agg["cases"] == 4
    assert agg["backends"] == ["client_parallel", "client_sequential"]
    assert agg["rounds"] > 30
    assert agg["max_param_err"] < 5e-4


def test_backend_pool_sharded_requires_sharding():
    with pytest.raises(ValueError, match="sharded"):
        make_backend_pool(("client_parallel", "sharded"))


@pytest.fixture(scope="module")
def backends_check():
    """Run tests/_fuzz_backends_check.py once under a 4-device mesh."""
    return _subproc.run_check("_fuzz_backends_check.py")


def test_sharded_backend_matrix_subprocess(backends_check):
    r = backends_check
    assert r["n_devices"] == 4
    assert r["cases"] == 6
    assert r["rounds"] > 40
    assert r["events_applied"] > 20
    assert r["max_param_err"] < 5e-4


def test_mutation_sharded_parity_break_is_caught(backends_check):
    """Acceptance criterion: a seeded sharded-parity break (slot-0
    aggregation weight silently scaled) trips "backend-parity" — and the
    same case passes again once the mutation is lifted."""
    assert backends_check["parity_mutation_caught"] is True
    assert backends_check["parity_mutation_clean_after"] is True


# -- fuzzed supervised chaos ---------------------------------------------------

def test_chaos_corpus_bitexact(harness):
    """Generated fault plans (crashes, mid-span tears, snapshot bitrot +
    write failures, stale floods) against a real supervised
    FederationService running generated event schedules: every recovered
    run must be bit-identical to the fault-free service run."""
    agg = run_chaos_corpus(range(4), harness=harness)
    assert agg["cases"] == 4
    assert agg["recoveries"] > 0            # the plans actually bite
    assert agg["events_merged"] > 0         # floods actually flood
    assert agg["rounds"] > 30
    assert agg["mttr_max_s"] < 60.0


def test_mutation_broken_journal_replay_is_caught(harness, monkeypatch):
    """Acceptance criterion: drop journaling in the service's event
    accept path — post-recovery replay then misses events and the
    recovered trajectory diverges from the fault-free run, which the
    chaos cross-check must flag as "chaos-bitexact"."""
    from repro.fed.faults import Fault, FaultPlan
    from repro.fed.fuzz import run_chaos_case
    from repro.fed.service import FederationService

    plan = [Fault("worker", 0, "crash")]

    def mutated(seed):
        return FaultPlan(faults=list(plan), seed=seed)

    # clean machinery survives this plan bit-exactly...
    seed = 1
    stats = run_chaos_case(harness, seed, plan=mutated(seed))
    assert stats["recoveries"] >= 1

    orig = FederationService._accept

    def no_journal(self, sch, e, count=True):
        journal, self._journal = self._journal, None
        try:
            orig(self, sch, e, count)
        finally:
            self._journal = journal
    monkeypatch.setattr(FederationService, "_accept", no_journal)
    with pytest.raises(InvariantViolation) as ei:
        run_chaos_case(harness, seed, plan=mutated(seed))
    assert ei.value.invariant == "chaos-bitexact"


# -- mutation smoke: a fuzzer that can't fail is not a fuzzer ------------------

def test_mutation_broken_weights_is_caught(harness, monkeypatch):
    """Inflate the data weights the state hands the engine: the
    weight-sanity invariant (sum p <= 1) must fire."""
    orig = FedState.data_weights

    def inflated(self, *a, **kw):
        return np.asarray(orig(self, *a, **kw)) * 1.5
    monkeypatch.setattr(FedState, "data_weights", inflated)
    with pytest.raises(InvariantViolation) as ei:
        run_fuzz_case(harness, 0, check_plan_parity=False)
    assert "weight" in str(ei.value)


def test_mutation_broken_resume_is_caught(harness, monkeypatch):
    """Perturb the LR-decay anchor during kill/restore rehydration: the
    exact-resume invariant (bit-identical history across kills) must
    fire on any seed whose case kills at least once."""
    seed = next(s for s in range(64) if generate_case(s).n_kills > 0)
    orig = FedState.from_dict.__func__

    def skewed(cls, d, *a, **kw):
        st = orig(cls, d, *a, **kw)
        st.lr_shift_tau += 1
        return st
    monkeypatch.setattr(FedState, "from_dict", classmethod(skewed))
    with pytest.raises(InvariantViolation):
        run_fuzz_case(harness, seed, check_plan_parity=False)
