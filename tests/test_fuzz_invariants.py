"""Event-stream fuzzer (fed/fuzz.py): a seeded corpus of adversarial
interleavings — arrivals, departures, rejoins, trace shifts, bursts,
duplicate deliveries, kill/restore — each checked against the control
plane's invariants (exact resume, zero recompile, scheme-weight sanity,
plan-vs-device parity).  Plus the meta-test: deliberately break an
invariant source and assert the fuzzer actually catches it."""
import numpy as np
import pytest

from repro.fed import (FedState, FuzzHarness, InvariantViolation,
                       generate_case, run_corpus, run_fuzz_case)

# The tier-1 corpus: recorded so a violating seed reproduces exactly
# (`run_fuzz_case(FuzzHarness(), seed)` replays one).  Nightly scale
# lives in benchmarks/fuzz_bench.py.
CORPUS_SEEDS = range(30)


@pytest.fixture(scope="module")
def harness():
    """One warm engine for the whole module — a fresh RoundEngine costs
    ~4s of compiles; the fuzzer's zero-recompile invariant needs the
    pooled engine anyway."""
    return FuzzHarness()


def test_corpus_passes_all_invariants(harness):
    agg = run_corpus(CORPUS_SEEDS, harness=harness)
    assert agg["cases"] == len(CORPUS_SEEDS)
    # the corpus must actually exercise the machinery, not no-op through
    assert agg["rounds"] > 100
    assert agg["kills"] > 0                 # some cases kill + restore
    assert agg["resumes"] == agg["kills"]   # every kill resumed
    assert agg["events_applied"] > 30
    assert all(r["plan_parity"] for r in agg["per_case"])


def test_generator_is_reproducible():
    for seed in (0, 7, 123):
        a = generate_case(seed)
        b = generate_case(seed)
        assert a.seed == b.seed == seed
        assert a.ops == b.ops
        assert a.total_rounds == b.total_rounds
        assert a.n_kills == b.n_kills
    # and different seeds explore different interleavings
    assert generate_case(0).ops != generate_case(1).ops


def test_case_replay_matches_fresh_generation(harness):
    case = generate_case(3)
    fresh = run_fuzz_case(harness, 3)
    replay = run_fuzz_case(harness, 3, case=case)
    assert fresh == replay


# -- mutation smoke: a fuzzer that can't fail is not a fuzzer ------------------

def test_mutation_broken_weights_is_caught(harness, monkeypatch):
    """Inflate the data weights the state hands the engine: the
    weight-sanity invariant (sum p <= 1) must fire."""
    orig = FedState.data_weights

    def inflated(self, *a, **kw):
        return np.asarray(orig(self, *a, **kw)) * 1.5
    monkeypatch.setattr(FedState, "data_weights", inflated)
    with pytest.raises(InvariantViolation) as ei:
        run_fuzz_case(harness, 0, check_plan_parity=False)
    assert "weight" in str(ei.value)


def test_mutation_broken_resume_is_caught(harness, monkeypatch):
    """Perturb the LR-decay anchor during kill/restore rehydration: the
    exact-resume invariant (bit-identical history across kills) must
    fire on any seed whose case kills at least once."""
    seed = next(s for s in range(64) if generate_case(s).n_kills > 0)
    orig = FedState.from_dict.__func__

    def skewed(cls, d, *a, **kw):
        st = orig(cls, d, *a, **kw)
        st.lr_shift_tau += 1
        return st
    monkeypatch.setattr(FedState, "from_dict", classmethod(skewed))
    with pytest.raises(InvariantViolation):
        run_fuzz_case(harness, seed, check_plan_parity=False)
