"""Tiered client bank + double-buffered cohort prefetch (fed/bank.py).

The acceptance-critical properties pinned here:

  * the bank-backed scheduler (hot slots as cache, fleet host-side,
    arrival cohorts staged on a thread while the span computes) is
    BIT-identical to the plain device-resident scheduler on the
    scenario library, in both engine modes;
  * a fleet much larger than capacity runs end-to-end through the
    rotation scenario with history bit-identical to an all-resident
    run of the same schedule;
  * prefetch churn never recompiles the span scans (trace_count) and
    correctly covers the evicted-client-rejoins-at-the-same-boundary
    corner;
  * chunked (v2) federation checkpoints round-trip clients exactly and
    reject corrupt chunks.
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Arrival, Client, Departure, StreamScheduler
from repro.fed.bank import ClientBank, CohortStager, pad_rows
from repro.fed.scenarios import build_scheduler, make_scenario
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def make_clients(n=8, seed=0, trace_idx=None):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1],
                   trace=TRACES[trace_idx if trace_idx is not None
                                else rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_scheduler(clients, *, capacity=None, mode="device", seed=0,
                   chunk_size=4, events=(), **kw):
    return StreamScheduler(
        clients=clients, init_params=init_small(jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), capacity=capacity,
        local_epochs=5, batch_size=6, scheme="C", eta0=1.0, seed=seed,
        mode=mode, chunk_size=chunk_size, events=events, **kw)


def assert_history_identical(h1, h2):
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1.tau == r2.tau and r1.event == r2.event
        assert r1.eta == r2.eta and r1.n_active == r2.n_active
        np.testing.assert_array_equal(np.asarray(r1.s), np.asarray(r2.s))
        # non-eval rounds are NaN on both sides (NaN != NaN)
        np.testing.assert_array_equal(np.asarray(r1.loss),
                                      np.asarray(r2.loss))
        np.testing.assert_array_equal(np.asarray(r1.acc),
                                      np.asarray(r2.acc))


def assert_params_bitwise(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- ClientBank unit behavior -------------------------------------------------

def test_bank_put_rows_roundtrip_and_idempotence():
    sch = make_scheduler(make_clients(3, seed=1), capacity=3)
    bank = ClientBank(sch.engine.task, sch.engine.nmax)
    c = sch.clients[0]
    bank.put(0, c)
    rows = bank.rows(0)
    expect = pad_rows(sch.engine.task, sch.engine.nmax, c)
    assert set(rows) == set(expect)
    for name in rows:
        np.testing.assert_array_equal(rows[name], expect[name])
        assert rows[name].shape[0] == sch.engine.nmax
    puts = bank.puts
    bank.put(0, c)                       # idempotent: no re-pad
    assert bank.puts == puts
    st = bank.stats()
    assert st["clients"] == 1 and st["resident"] == 1
    assert st["row_nbytes"] > 0
    assert st["resident_bytes"] == st["row_nbytes"]


def test_bank_spills_lru_to_disk_and_reloads(tmp_path):
    sch = make_scheduler(make_clients(4, seed=2), capacity=4)
    bank = ClientBank(sch.engine.task, sch.engine.nmax,
                      spill_dir=str(tmp_path),
                      ram_budget_bytes=2 * ClientBank(
                          sch.engine.task, sch.engine.nmax).row_nbytes)
    for i, c in enumerate(sch.clients):
        bank.put(i, c)
    st = bank.stats()
    assert st["clients"] == 4
    assert st["resident"] <= 2 and st["spilled"] >= 2
    assert bank.spills >= 2
    assert list(tmp_path.glob("client-*.npz"))
    # a spilled client reloads bit-exactly (and becomes resident again)
    rows = bank.rows(0)
    expect = pad_rows(sch.engine.task, sch.engine.nmax, sch.clients[0])
    for name in expect:
        np.testing.assert_array_equal(rows[name], expect[name])


def test_bank_budget_requires_spill_dir():
    """A RAM budget with nowhere to evict to would have to drop data —
    refused at construction."""
    sch = make_scheduler(make_clients(2, seed=3), capacity=2)
    with pytest.raises(ValueError, match="spill_dir"):
        ClientBank(sch.engine.task, sch.engine.nmax, ram_budget_bytes=1)


# -- bit-identity vs the device-resident scheduler ----------------------------

@pytest.mark.parametrize("scenario", ["flash-crowd", "diurnal"])
@pytest.mark.parametrize("engine_mode",
                         ["client_parallel", "client_sequential"])
def test_bank_prefetch_bit_identical_to_resident(scenario, engine_mode):
    """The tentpole invariant: routing admits through the bank and the
    staging thread changes WHEN bytes move, never WHICH bytes — history
    and params are bit-identical to the all-resident scheduler."""
    rounds = 14
    plain = build_scheduler(make_scenario(scenario, seed=0),
                            engine_mode=engine_mode, chunk_size=4)
    plain.run(rounds, eval_every=7)
    banked = build_scheduler(make_scenario(scenario, seed=0),
                             engine_mode=engine_mode, chunk_size=4,
                             prefetch=True)
    banked.run(rounds, eval_every=7)
    banked.close()
    assert_history_identical(plain.history, banked.history)
    assert_params_bitwise(plain.params, banked.params)
    ps = banked.prefetch_stats()
    if scenario == "flash-crowd":         # its arrivals all prefetch
        assert ps["hits"] > 0 and ps["misses"] == 0


def test_fleet_beyond_capacity_bit_identical_to_all_resident():
    """256-clients-through-12-slots in spirit, sized for CI: the
    rotation scenario cycles a fleet through a small hot set
    (evict-to-bank + rejoin-from-bank at every boundary), and its
    history is bit-identical to the same schedule on an engine large
    enough to hold everyone.  Plan-mode sampling draws per occupied
    slot in slot order, so the trajectories are comparable across
    capacities; the all-resident run's extra slots stay exactly zero."""
    fleet, hot, rounds = 16, 6, 24
    small = build_scheduler(
        make_scenario("rotation", seed=0, fleet=fleet, hot=hot,
                      dwell=2, n_rounds=rounds),
        mode="plan", chunk_size=4, prefetch=True)
    small.run(rounds, eval_every=8)
    small.close()
    big = build_scheduler(
        make_scenario("rotation", seed=0, fleet=fleet, hot=hot,
                      dwell=2, n_rounds=rounds),
        mode="plan", chunk_size=4, capacity=fleet)
    big.run(rounds, eval_every=8)

    assert small.engine.capacity == hot < big.engine.capacity
    assert len(small.clients) > hot       # fleet really exceeded the slots
    assert small.prefetch_stats()["bank"]["clients"] == len(small.clients)
    for r1, r2 in zip(small.history, big.history):
        assert r1.tau == r2.tau and r1.event == r2.event
        assert r1.eta == r2.eta and r1.n_active == r2.n_active
        np.testing.assert_array_equal(np.asarray(r1.s),
                                      np.asarray(r2.s)[:hot])
        assert not np.asarray(r2.s)[hot:].any()
        np.testing.assert_array_equal(np.asarray(r1.loss),
                                      np.asarray(r2.loss))
    assert_params_bitwise(small.params, big.params)


# -- zero-recompile + staged-cohort corners -----------------------------------

def test_prefetch_churn_never_recompiles():
    """Across sustained evict+rejoin churn with prefetch on, the span
    scans compile exactly once per span length: RoundEngine.trace_count
    and the per-chunk compilation caches are flat after warmup."""
    fleet, hot = 10, 4
    sch = build_scheduler(
        make_scenario("rotation", seed=1, fleet=fleet, hot=hot,
                      dwell=2, n_rounds=48),
        chunk_size=4, prefetch=True)
    sch.eval_fn = None                    # eval-set growth is not churn
    sch.run(16, eval_every=10 ** 9)       # warmup: all span lengths seen
    engine = sch.engine
    traces = engine.trace_count
    fns = dict(engine._fns)
    sizes = {k: f._cache_size() for k, f in fns.items()}
    sch.run(24, eval_every=10 ** 9)       # 12 more churn boundaries
    sch.close()
    assert sch.engine is engine
    assert engine.trace_count == traces
    assert set(engine._fns) == set(fns)
    for k, f in fns.items():
        assert f._cache_size() == sizes[k], f"chunk {k} recompiled"
    assert sch.prefetch_stats()["misses"] == 0


def test_evicted_client_rejoins_within_staged_cohort():
    """The staging corner: a Departure and an Arrival for the SAME
    client coalesce at one boundary.  upcoming_arrivals must stage the
    still-slotted client (it has a queued departure), the boundary
    evicts then re-admits from the staged cohort, and the trajectory
    matches the unprefetched run bit-for-bit."""
    def build(prefetch):
        return make_scheduler(
            make_clients(3, seed=8, trace_idx=0), capacity=3,
            max_samples=600, prefetch=prefetch,
            events=[Departure(4, client_id=0, policy="include"),
                    Arrival(4, client_id=0)])

    plain = build(False)
    plain.run(8, eval_every=8)
    sch = build(True)
    sch.run(8, eval_every=8)
    sch.close()
    assert sch.prefetch_stats()["hits"] == 1
    assert sch.prefetch_stats()["misses"] == 0
    assert 0 in sch.slot_of               # re-admitted at the boundary
    for h in sch.history:                 # cpu_0: s = E surely throughout
        assert h.s[sch.slot_of[0]] == 5.0
    assert_history_identical(plain.history, sch.history)
    assert_params_bitwise(plain.params, sch.params)


def test_trace_shift_after_staging_is_not_stale():
    """Staged cohorts carry data rows only — n and the trace CDF are
    computed from the live Client at commit.  A TraceShift landing
    between staging and the boundary must therefore win."""
    sch = make_scheduler(make_clients(2, seed=9, trace_idx=4),
                         capacity=3, max_samples=600, prefetch=True)
    new_cl = make_clients(1, seed=10, trace_idx=4)[0]   # cpu_90
    sch.push(Arrival(4, client=new_cl))
    sch.run(2, eval_every=10 ** 9)
    # the cohort for tau=4 is already staged (or staging); now the
    # client's availability law changes before the boundary
    stager = sch._stager
    for _ in range(200):
        if stager._pending is not None:
            break
        sch.run(1, eval_every=10 ** 9)
        if sch._next_tau >= 4:
            break
    new_cl.trace = TRACES[0]              # cpu_0: s = E surely
    sch.run(max(0, 8 - (sch._next_tau - 0)), eval_every=10 ** 9)
    sch.close()
    slot = sch.slot_of[2]
    cdf = np.asarray(sch.engine.s_cdf)[slot]
    from repro.fed.engine import trace_cdf_row
    np.testing.assert_array_equal(cdf, trace_cdf_row(TRACES[0],
                                                     sch.engine.E))
    post = [h.s[slot] for h in sch.history if h.tau >= 4]
    assert post and all(s == 5.0 for s in post)


def test_stager_failure_falls_back_to_sync_admit():
    """A staging-thread failure must degrade to the synchronous path,
    never corrupt state or deadlock the boundary."""
    sch = make_scheduler(make_clients(2, seed=12, trace_idx=0),
                         capacity=3, max_samples=600, prefetch=True)
    new_cl = make_clients(1, seed=13, trace_idx=0)[0]
    sch.push(Arrival(2, client=new_cl))

    stager = sch._stager
    orig = stager._stage

    def boom(items, box):
        box["err"] = RuntimeError("injected staging failure")
        box["done"].set()
    stager._stage = boom
    sch.run(6, eval_every=10 ** 9)
    sch.close()
    stager._stage = orig
    assert stager.stage_errors == 1
    assert sch.prefetch_stats()["misses"] == 1       # sync fallback
    slot = sch.slot_of[2]
    assert all(h.s[slot] == 5.0 for h in sch.history if h.tau >= 2)


def test_cohort_stager_supersede_and_close():
    sch = make_scheduler(make_clients(2, seed=14), capacity=4,
                         max_samples=600)
    stager = CohortStager(sch.engine)
    c = make_clients(1, seed=15)[0]
    done = threading.Event()
    orig = stager._stage

    def slow(items, box):
        done.wait(5.0)
        orig(items, box)
    stager._stage = slow
    stager.submit([(None, c)])
    stager.submit([(None, c)])            # supersedes the in-flight one
    done.set()
    cohort = stager.collect()
    assert cohort is not None and cohort.k == 1
    assert stager.superseded == 1
    assert stager.collect() is None       # consumed
    stager.close()                        # idempotent on empty


# -- chunked (v2) federation checkpoints --------------------------------------

def _eval_fn(params, x, y):
    import jax.numpy as jnp
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(
        ll, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def test_chunked_checkpoint_resume_bit_exact(tmp_path):
    """A bank-backed scheduler checkpoints clients as per-client npz
    chunks (v2) and a restored run continues bit-exactly."""
    events = [Arrival(3, client=make_clients(1, seed=21, trace_idx=0)[0])]
    ref = make_scheduler(make_clients(3, seed=20), capacity=4,
                         max_samples=600, eval_fn=_eval_fn,
                         events=list(events), prefetch=True)
    ref.run(10, eval_every=5)
    ref.close()

    sch = make_scheduler(make_clients(3, seed=20), capacity=4,
                         max_samples=600, eval_fn=_eval_fn,
                         events=list(events), prefetch=True)
    sch.run(6, eval_every=5)
    ckpt = tmp_path / "ckpt"
    sch.save(str(ckpt))
    sch.close()
    chunks = sorted((ckpt / "clients").glob("client-*.npz"))
    assert len(chunks) == 4               # one npz per client

    res = StreamScheduler.restore(str(ckpt), loss_fn=make_loss_fn(CFG),
                                  eval_fn=_eval_fn)
    assert res.bank is not None           # bank/prefetch survive restore
    assert res._stager is not None
    res.run(4, eval_every=5)
    res.close()
    assert_history_identical(ref.history, res.history)
    assert_params_bitwise(ref.params, res.params)


def test_chunked_checkpoint_rejects_corrupt_chunk(tmp_path):
    from repro.checkpoint import CorruptCheckpointError
    sch = make_scheduler(make_clients(3, seed=22), capacity=3,
                         max_samples=600, prefetch=True)
    sch.run(4, eval_every=4)
    ckpt = tmp_path / "ckpt"
    sch.save(str(ckpt))
    sch.close()
    chunk = sorted((ckpt / "clients").glob("client-*.npz"))[1]
    raw = bytearray(chunk.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    chunk.write_bytes(bytes(raw))
    with pytest.raises(CorruptCheckpointError):
        StreamScheduler.restore(str(ckpt), loss_fn=make_loss_fn(CFG))


# -- fuzz: the banked backend leg ---------------------------------------------

def test_fuzz_banked_backend_parity():
    """One corpus seed through the cross-backend fuzzer with the
    bank-backed leg in the pool: the banked scheduler must walk the
    exact same trajectory as the reference backend."""
    from repro.fed.fuzz import make_backend_pool, run_cross_backend_case
    pool = make_backend_pool(("client_parallel", "banked"))
    out = run_cross_backend_case(pool, seed=3)
    assert out["rounds"] > 0
    assert "banked" in out["backends"]
