"""Fast-reboot (Cor. 4.0.2) and departure applicability (Cor. 4.0.3) on
closed-form quadratics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import scheme_coefficients
from repro.core.arrivals import RebootState, shift_weights_arrival, staircase_lr
from repro.core.departures import (BoundTerms, crossing_round,
                                   shift_weights_departure, should_exclude)
from repro.core.fed_step import make_fed_round
from repro.core.theory import (objective_shift_offset,
                               quadratic_problem_constants)

E = 4
DIM = 4


def build(seed, n):
    rng = np.random.default_rng(seed)
    A_list = [np.eye(DIM) * rng.uniform(0.8, 1.2) for _ in range(n)]
    c_list = [rng.normal(0, 2.0, DIM) for _ in range(n)]
    n_k = np.ones(n) * 100
    p = n_k / n_k.sum()
    return A_list, c_list, p


def fed_train(A_list, c_list, p, w0, rounds, eta0=0.5, boost=None,
              tau0=0, seed=0):
    A = jnp.asarray(np.stack(A_list))
    c = jnp.asarray(np.stack(c_list))
    N = len(A_list)

    def loss_fn(params, batch):
        k = batch["client"][0]
        d = params["w"] - c[k]
        return 0.5 * d @ A[k] @ d

    round_fn = jax.jit(make_fed_round(loss_fn, "client_parallel"))
    params = {"w": jnp.asarray(w0)}
    alpha = np.ones((N, E), np.float32)
    batches = {"client": jnp.asarray(
        np.tile(np.arange(N)[:, None, None], (1, E, 1)))}
    s = np.full(N, E, np.float32)
    traj = []
    for tau in range(rounds):
        coeffs = np.array(scheme_coefficients("C", jnp.asarray(p),
                                                jnp.asarray(s), E))
        if boost is not None:
            coeffs[-1] *= boost.coeff_multiplier(tau0 + tau)
        eta = staircase_lr(eta0, tau0 + tau + 1, tau0)
        params, _ = round_fn(params, batches, jnp.asarray(alpha),
                             jnp.asarray(coeffs), jnp.float32(eta))
        traj.append(np.asarray(params["w"]).copy())
    return np.asarray(traj)


def test_fast_reboot_accelerates_late_arrival():
    """A device arriving late (model near old optimum): boosted coefficient
    moves the model toward the NEW optimum faster (Cor. 4.0.2)."""
    A_list, c_list, p = build(0, 5)
    # old objective: first 4 devices
    pc_old, w_old = quadratic_problem_constants(A_list[:4], c_list[:4],
                                                p[:4] / p[:4].sum())
    pc_new, w_new = quadratic_problem_constants(A_list, c_list, p)
    # start AT the old optimum (late arrival, b ~= 0)
    traj_boost = fed_train(A_list, c_list, p, w_old, rounds=12,
                           boost=RebootState(0, 4, boost=3.0), tau0=40)
    traj_plain = fed_train(A_list, c_list, p, w_old, rounds=12, tau0=40)
    d_boost = np.linalg.norm(traj_boost - w_new, axis=1)
    d_plain = np.linalg.norm(traj_plain - w_new, axis=1)
    # boosted run gets closer to the new optimum in early rounds
    assert d_boost[3] < d_plain[3], (d_boost[:5], d_plain[:5])
    assert d_boost[6] < d_plain[6]


def test_objective_shift_bound_holds():
    """Theorem 3.2: ||w* - w~*|| within the analytic bound."""
    A_list, c_list, p = build(1, 5)
    pc_old, w_old = quadratic_problem_constants(A_list[:4], c_list[:4],
                                                p[:4] / p[:4].sum())
    pc_new, w_new = quadratic_problem_constants(A_list, c_list, p)
    gamma_l = float(0.5 * (w_old - c_list[4]) @ A_list[4] @ (w_old - c_list[4]))
    bound = objective_shift_offset(pc_new.L, pc_new.mu, 100.0, 400.0,
                                   gamma_l, arrival=True)
    assert np.linalg.norm(w_new - w_old) <= bound + 1e-8


def test_departure_rule_prefers_exclude_with_time_left():
    terms = BoundTerms(D=5.0, V=20.0, gamma=10.0, E=E)
    # leaves early, lots of time left -> exclude
    assert should_exclude(T=500, tau0=10, terms=terms, gamma_l=1.0)
    # leaves at the very end -> include
    assert not should_exclude(T=500, tau0=499, terms=terms, gamma_l=1.0)


def test_crossing_round_grows_with_noniid_and_tau0():
    """Table 5 trends: crossing time increases with Gamma_l and tau0."""
    terms = BoundTerms(D=5.0, V=20.0, gamma=10.0, E=E)
    c_small = crossing_round(2000, 50, terms, gamma_l=0.5)
    c_large = crossing_round(2000, 50, terms, gamma_l=5.0)
    assert c_small is not None and c_large is not None
    assert c_large >= c_small
    c_early = crossing_round(2000, 20, terms, gamma_l=1.0)
    c_late = crossing_round(2000, 200, terms, gamma_l=1.0)
    assert (c_late - 200) >= (c_early - 20)


def test_shift_weights():
    n = np.array([100.0, 200.0, 100.0])
    w_arr = shift_weights_arrival(n, 100.0)
    np.testing.assert_allclose(w_arr.sum(), 1.0)
    np.testing.assert_allclose(w_arr[-1], 0.2)
    w_dep = shift_weights_departure(n, 1)
    np.testing.assert_allclose(w_dep, [0.5, 0.5])
