"""Documentation must not rot: the fenced ``python`` snippets in
README.md and docs/*.md are executed for real (benchmarks/check_docs.py
is also wired as ``python -m benchmarks.run --check-docs``).  Snippets
that need hardware the CI container lacks are tagged ``python no-run``
and only counted."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.check_docs import doc_files, extract_blocks, run_file  # noqa: E402


def test_doc_set_is_complete():
    names = {p.name for p in doc_files()}
    assert {"README.md", "index.md", "engine.md", "streaming.md",
            "scaling.md"} <= names


def test_runnable_snippets_exist():
    """If the fence tags break (or every snippet gets tagged no-run), the
    doc gate silently checks nothing — pin the runnable count."""
    runnable = norun = 0
    for path in doc_files():
        for _, info, _ in extract_blocks(path):
            if info and info[0] == "python":
                if "no-run" in info:
                    norun += 1
                else:
                    runnable += 1
    assert runnable >= 4, runnable
    assert norun >= 1, norun    # the multi-device example stays tagged


def test_doc_snippets_execute():
    total = 0
    for path in doc_files():
        ran, _, err = run_file(path, verbose=False)
        assert err is None, f"{path}:{err[0]}\n{err[1]}"
        total += ran
    assert total >= 4
