"""Subprocess body for tests/test_fedmodel.py (the large-model federation
path under a real multi-device mesh) — same harness pattern as
tests/_sharded_check.py: XLA_FLAGS must virtualize devices before jax
initializes, so these checks run in a fresh interpreter and report a
``RESULT {json}`` line on success.

Checks:
  1. composite federation axes: a (pod x data) logreg federation matches
     the unsharded engine round-for-round in plan mode (capacity padded
     over the axis product);
  2. LM plan parity: a reduced mamba2-130m federation on a (data x model)
     mesh matches the unsharded run, in BOTH execution modes, with params
     staying sharded per the model spec in client_sequential;
  3. zero-recompile churn: a brand-new LM client admitted mid-training
     costs slot writes only — no new compiled chunk entries.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import _subproc  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.paper import SYNTHETIC_LR  # noqa: E402
from repro.core.participation import TRACES  # noqa: E402
from repro.data import synthetic_federation  # noqa: E402
from repro.fed import (Client, FedSharding, LMTask,  # noqa: E402
                       RoundEngine)
from repro.launch.fed_train import build_fleet  # noqa: E402
from repro.models.small import init_small, make_loss_fn  # noqa: E402

RESULTS = {}
SEQ, SAMPLES, E, B = 32, 12, 2, 2


def _span_kwargs(cap, n_active):
    p = np.zeros(cap)
    p[:n_active] = 1.0 / n_active
    return dict(p=p, active=(p > 0).astype(np.float32), lr_shift_tau=0,
                reboot_tau0=np.zeros(cap, np.int32),
                reboot_boost=np.ones(cap, np.float32))


def _maxdiff(a, b):
    return max(float(np.abs(np.asarray(x, np.float32)
                            - np.asarray(y, np.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def check_composite_axes():
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    fs = FedSharding(mesh=mesh, axis=("pod", "data"))
    assert fs.n_shards == 4
    train, _ = synthetic_federation(0.5, 0.5, 6, seed=0)
    rng = np.random.default_rng(0)
    clients = [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 5)])
               for tr in train]
    params = init_small(jax.random.PRNGKey(0), SYNTHETIC_LR)
    outs = {}
    for tag, sh in (("composite", fs), ("single", None)):
        eng = RoundEngine(loss_fn=make_loss_fn(SYNTHETIC_LR),
                          clients=clients, local_epochs=3, batch_size=4,
                          sharding=sh)
        cap = eng.capacity
        if sh is not None:
            assert cap == 8, cap           # 6 clients pad to 2 whole
        alphas = np.ones((3, cap, 3), np.float32)
        idxs = np.random.default_rng(1).integers(
            0, 20, size=(3, 8, 3, 4))[:, :cap]
        outs[tag], _ = eng.run_span(params, 0, 3,
                                    plan=(alphas, idxs),
                                    **_span_kwargs(cap, 6))
    err = _maxdiff(outs["composite"], outs["single"])
    RESULTS["composite_pod_data_err"] = err
    assert err < 1e-5, f"composite (pod,data) diverges: {err}"


def check_lm_plan_parity():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    fs = FedSharding(mesh=mesh, axis="data")
    cfg = get_config("mamba2-130m").reduced()
    rng = np.random.default_rng(0)
    plan = (np.ones((2, 4, E), np.float32),
            rng.integers(0, SAMPLES, size=(2, 4, E, B)))
    for mode in ("client_parallel", "client_sequential"):
        outs = {}
        for tag, sh in (("sharded", fs), ("single", None)):
            task = LMTask(cfg, seq_len=SEQ,
                          fsdp=(mode == "client_sequential"))
            clients = build_fleet(task, n_clients=4, samples=SAMPLES,
                                  seed=0)
            eng = RoundEngine(task=task, clients=clients, local_epochs=E,
                              batch_size=B, eta0=0.1, mode=mode,
                              sharding=sh)
            params = task.init_params(jax.random.PRNGKey(0))
            out, _ = eng.run_span(params, 0, 2, plan=plan,
                                  **_span_kwargs(eng.capacity, 4))
            outs[tag] = out
            if sh is not None and mode == "client_sequential":
                # the >=30B contract: params never replicate — FSDP x TP
                # specs survive the round
                specs = {str(l.sharding.spec)
                         for l in jax.tree.leaves(out)}
                assert any("data" in s for s in specs), specs
                assert any("model" in s for s in specs), specs
        err = _maxdiff(outs["sharded"], outs["single"])
        RESULTS[f"lm_plan_parity_err_{mode}"] = err
        assert err < 1e-5, f"{mode} sharded diverges: {err}"


def check_lm_zero_recompile_churn():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    fs = FedSharding(mesh=mesh, axis="data")
    cfg = get_config("mamba2-130m").reduced()
    task = LMTask(cfg, seq_len=SEQ, fsdp=True)
    clients = build_fleet(task, n_clients=3, samples=SAMPLES, seed=0)
    eng = RoundEngine(task=task, clients=clients, local_epochs=E,
                      batch_size=B, eta0=0.05, mode="client_sequential",
                      chunk_size=2, capacity=6, sharding=fs)
    params = task.init_params(jax.random.PRNGKey(0))
    kw = _span_kwargs(eng.capacity, 3)
    params, _ = eng.run_span(params, 0, 3, key=jax.random.PRNGKey(1),
                             **kw)                  # warm chunks {1, 2}
    sizes = {k: f._cache_size() for k, f in eng._fns.items()}
    assert sizes, "expected compiled chunk fns"
    fresh = build_fleet(task, n_clients=2, samples=SAMPLES, seed=99)
    eng.admit_many([(3, fresh[0]), (4, fresh[1])])  # burst admit
    kw = _span_kwargs(eng.capacity, 5)
    params, _ = eng.run_span(params, 3, 3, key=jax.random.PRNGKey(2),
                             **kw)
    for k, f in eng._fns.items():
        assert k in sizes and f._cache_size() == sizes[k], \
            f"chunk {k} recompiled after churn"
    RESULTS["lm_recompiles_across_churn"] = 0


def main():
    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 virtual devices, got {n_dev}"
    check_composite_axes()
    check_lm_plan_parity()
    check_lm_zero_recompile_churn()
    RESULTS["n_devices"] = n_dev
    _subproc.emit(RESULTS)


if __name__ == "__main__":
    main()
