"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("K", [1, 3, 8, 32])
@pytest.mark.parametrize("D", [64, 1000, 4096, 10001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_agg_matches_ref(K, D, dtype):
    k1, k2 = jax.random.split(KEY)
    c = jax.random.uniform(k1, (K,), jnp.float32)
    d = jax.random.normal(k2, (K, D), dtype)
    got = ops.weighted_agg(c, d)
    want = ref.weighted_agg_ref(c, d)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-6, atol=1e-5)


@pytest.mark.parametrize("K,k_block", [(8, 4), (32, 8), (48, 32), (7, 2)])
@pytest.mark.parametrize("D", [256, 5000])
def test_weighted_agg_tiled_k_matches_ref(K, k_block, D):
    """Streamed multi-block K path (client axis in k_block slabs,
    accumulated across the second grid dim) == single-block reference."""
    k1, k2 = jax.random.split(KEY)
    c = jax.random.uniform(k1, (K,), jnp.float32)
    d = jax.random.normal(k2, (K, D), jnp.float32)
    got = ops.weighted_agg(c, d, k_block=k_block)
    want = ref.weighted_agg_ref(c, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_weighted_agg_auto_tiles_large_k():
    """K beyond MAX_SINGLE_K silently switches to the streamed layout."""
    from repro.kernels.weighted_agg import MAX_SINGLE_K
    K = MAX_SINGLE_K + 9
    k1, k2 = jax.random.split(KEY)
    c = jax.random.uniform(k1, (K,), jnp.float32)
    d = jax.random.normal(k2, (K, 3000), jnp.float32)
    got = ops.weighted_agg(c, d)
    want = ref.weighted_agg_ref(c, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_weighted_agg_backend_aware_interpret_default():
    """interpret=None resolves from the backend: interpret mode everywhere
    except TPU (so the CPU CI container runs without Mosaic)."""
    from repro.kernels.weighted_agg import resolve_interpret
    expected = jax.default_backend() != "tpu"
    assert resolve_interpret(None) == expected
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # and the public wrapper works with no interpret argument at all
    c = jax.random.uniform(KEY, (4,), jnp.float32)
    d = jax.random.normal(KEY, (4, 300), jnp.float32)
    np.testing.assert_allclose(ops.weighted_agg(c, d),
                               ref.weighted_agg_ref(c, d),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(K=st.integers(1, 16), D=st.integers(1, 3000),
       block=st.sampled_from([128, 512, 2048]))
def test_weighted_agg_property(K, D, block):
    rng = np.random.default_rng(K * 1000 + D)
    c = jnp.asarray(rng.uniform(0, 2, K), jnp.float32)
    d = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
    got = ops.weighted_agg(c, d, block=block)
    want = ref.weighted_agg_ref(c, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def _quantized(K, D, chunk, seed=0):
    from repro.core.compression import quantize_chunked
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.uniform(0, 1, K), jnp.float32)
    flat = jnp.asarray(rng.normal(size=(K, D)) * 0.3, jnp.float32)
    payload, scales = quantize_chunked(flat, chunk=chunk)
    return c, payload, scales


@pytest.mark.parametrize("K,k_block", [(1, None), (8, None), (32, 8),
                                       (70, None)])  # 70 > MAX_SINGLE_K
@pytest.mark.parametrize("D,chunk", [(256, 64), (1000, 128), (4096, 256)])
def test_weighted_agg_quant_matches_ref(K, k_block, D, chunk):
    """Fused dequant-and-reduce == dequantize-then-reduce oracle, across
    chunk geometries, the streamed multi-block-K layout, and the
    auto-tiled large-K path."""
    c, payload, scales = _quantized(K, D, chunk)
    got = ops.weighted_agg_quant(c, payload, scales, chunk=chunk,
                                 k_block=k_block)
    want = ref.weighted_agg_quant_ref(c, payload, scales, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_weighted_agg_quant_block_not_chunk_aligned():
    """block is re-floored to a chunk multiple internally; a block
    smaller than chunk must still work (clamped up to one chunk)."""
    c, payload, scales = _quantized(4, 2048, 256)
    want = ref.weighted_agg_quant_ref(c, payload, scales, chunk=256)
    for block in (300, 128, 512):
        got = ops.weighted_agg_quant(c, payload, scales, chunk=256,
                                     block=block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_weighted_agg_quant_rejects_bad_shapes():
    c, payload, scales = _quantized(4, 512, 128)
    with pytest.raises(ValueError):
        ops.weighted_agg_quant(c, payload, scales[:, :-1], chunk=128)
    with pytest.raises(ValueError):
        ops.weighted_agg_quant(c, payload[:, :-1], scales, chunk=128)


def test_weighted_agg_quant_never_materializes_f32_deltas():
    """The acceptance criterion of the fused path: no f32 tensor of the
    full (K, D) payload size exists outside the pallas_call — the
    dequantized deltas live only in VMEM tiles."""
    K, D, chunk = 8, 4096, 256
    c, payload, scales = _quantized(K, D, chunk)
    jaxpr = jax.make_jaxpr(
        lambda c, p, s: ops.weighted_agg_quant(c, p, s, chunk=chunk))(
        c, payload, scales)

    def walk(jxp):
        for eqn in jxp.eqns:
            if eqn.primitive.name == "pallas_call":
                continue                  # VMEM tiles are allowed
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or not hasattr(aval, "shape"):
                    continue
                if (aval.dtype == jnp.float32
                        and int(np.prod(aval.shape or (1,))) >= K * D):
                    raise AssertionError(
                        f"f32 {aval.shape} materialized by "
                        f"{eqn.primitive.name}")
            for val in eqn.params.values():
                if hasattr(val, "eqns"):                # Jaxpr
                    walk(val)
                elif hasattr(val, "jaxpr"):             # ClosedJaxpr
                    walk(val.jaxpr)
    walk(jaxpr.jaxpr)


@pytest.mark.parametrize("D", [128, 5000, 16384])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [0.0, 1.0])
def test_masked_sgd_matches_ref(D, dtype, alpha):
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (D,), dtype)
    g = jax.random.normal(k2, (D,), dtype)
    ea = jnp.float32(0.05 * alpha)
    got = ops.masked_sgd(w, g, ea)
    want = ref.masked_sgd_ref(w, g, ea)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-5)


def test_masked_sgd_zero_alpha_is_identity():
    w = jax.random.normal(KEY, (999,))
    g = jax.random.normal(KEY, (999,))
    out = ops.masked_sgd(w, g, jnp.float32(0.0))
    np.testing.assert_allclose(out, w)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
    (1, 4, 1, 384, 128),   # MQA, non-pow2 blocks coverage
    (2, 2, 2, 100, 32),    # padded seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    got = ops.flash_attention(q, k, v)
    kr = jnp.repeat(k, H // KV, 1)
    vr = jnp.repeat(v, H // KV, 1)
    want = ref.flash_attention_ref(q, kr, vr)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("Q,N,P", [(16, 8, 8), (64, 32, 16), (128, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_chunk_matches_ref(Q, N, P, dtype):
    rng = np.random.default_rng(Q + N)
    G = 6
    cum = jnp.asarray(np.cumsum(
        -rng.uniform(0.01, 0.1, (G, Q)), axis=-1), jnp.float32)
    C = jnp.asarray(rng.normal(size=(G, Q, N)), dtype)
    B = jnp.asarray(rng.normal(size=(G, Q, N)), dtype)
    x = jnp.asarray(rng.normal(size=(G, Q, P)), dtype)
    got = ops.ssd_intra_chunk(cum, C, B, x)
    want = ref.ssd_intra_chunk_ref(cum, C, B, x)
    if dtype == jnp.bfloat16:
        # scores are cast to bf16 for the second MXU matmul (TPU-realistic);
        # tolerance scales with the Q-term accumulation magnitude
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=6e-2, atol=0.4)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_ssd_intra_chunk_matches_model_ssd():
    """The kernel reproduces models/ssd.ssd_chunked's intra-chunk term:
    single chunk, zero initial state => whole output is intra-chunk."""
    from repro.models.ssd import ssd_chunked
    rng = np.random.default_rng(0)
    Bb, S, H, P, N = 1, 32, 2, 8, 4   # one chunk of Q=S, G=H groups
    x = jnp.asarray(rng.normal(size=(Bb, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (Bb, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bb, S, H, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bb, S, H, N)), jnp.float32)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=S)  # G=H
    # kernel view: one cell per (b, head)
    cum = jnp.cumsum(dt * A[None, None, :], axis=1)      # (Bb,S,H)
    cum_g = jnp.moveaxis(cum, -1, 1).reshape(Bb * H, S)
    C_g = jnp.moveaxis(Cm, 2, 1).reshape(Bb * H, S, N)
    B_g = jnp.moveaxis(Bm, 2, 1).reshape(Bb * H, S, N)
    xdt = x * dt[..., None]
    x_g = jnp.moveaxis(xdt, 2, 1).reshape(Bb * H, S, P)
    y_k = ops.ssd_intra_chunk(cum_g, C_g, B_g, x_g)
    y_k = jnp.moveaxis(y_k.reshape(Bb, H, S, P), 1, 2)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model),
                               rtol=2e-3, atol=2e-3)
