"""Shared subprocess harness for multi-virtual-device checks.

Multi-device sharding can only be exercised if XLA_FLAGS is set before
jax initializes, and the tier-1 pytest process has long since imported
jax — so those checks run in a fresh interpreter.  This module holds
the re-exec boilerplate both sides share:

  parent (a pytest fixture)     results = _subproc.run_check("_x_check.py")
  child  (tests/_*_check.py)    _subproc.emit(RESULTS)   # last stdout line

The child script must set XLA_FLAGS *before importing jax* (emit/
run_check cannot do that for it), exit nonzero on any failure, and emit
exactly one ``RESULT {json}`` line; run_check re-execs it with the
repo's src/ on PYTHONPATH, asserts a clean exit, and returns the parsed
payload.  Child scripts can ``import _subproc`` too — python puts the
script's directory on sys.path.
"""
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def run_check(script_name: str, *, devices: int = 4,
              timeout: float = 900.0) -> dict:
    """Run tests/<script_name> in a fresh interpreter under an
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>`` CPU
    mesh and return its parsed RESULT payload."""
    script = os.path.join(HERE, script_name)
    src = os.path.join(os.path.dirname(HERE), "src")
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={devices}",
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, (
        f"{script_name} failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    lines = [l for l in proc.stdout.splitlines()
             if l.startswith("RESULT ")]
    assert lines, proc.stdout
    return json.loads(lines[-1][len("RESULT "):])


def emit(results: dict) -> None:
    """Child-side: print the one RESULT line run_check parses."""
    print("RESULT " + json.dumps(results))
