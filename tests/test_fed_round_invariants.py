"""Invariants of the jitted federated round (Eq. 1–2), property-tested."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.fed_step import local_sgd, make_fed_round

DIM = 5
E = 3
C = 4


def _loss(params, batch):
    return 0.5 * jnp.sum(jnp.square(params["w"] - batch["c"][0]))


def _batches(rng):
    return {"c": jnp.asarray(rng.normal(size=(C, E, 1, DIM)), jnp.float32)}


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_zero_alpha_is_identity(seed):
    """All-inactive round: w unchanged regardless of coefficients."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=DIM), jnp.float32)}
    alpha = jnp.zeros((C, E))
    coeffs = jnp.asarray(rng.uniform(0, 2, C), jnp.float32)
    out, _ = make_fed_round(_loss, "client_parallel")(
        params, _batches(rng), alpha, coeffs, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(params["w"]), atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_zero_coeffs_is_identity(seed):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=DIM), jnp.float32)}
    alpha = jnp.ones((C, E))
    out, _ = make_fed_round(_loss, "client_parallel")(
        params, _batches(rng), alpha, jnp.zeros(C), jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(params["w"]), atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_aggregation_is_linear_in_coefficients(seed):
    """Eq. (2): the round update is linear in p_tau^k — the delta from a
    coefficient vector c1+c2 equals the sum of the individual deltas."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=DIM), jnp.float32)}
    batches = _batches(rng)
    alpha = jnp.asarray((rng.random((C, E)) < 0.7).astype(np.float32))
    c1 = jnp.asarray(rng.uniform(0, 1, C), jnp.float32)
    c2 = jnp.asarray(rng.uniform(0, 1, C), jnp.float32)
    rf = make_fed_round(_loss, "client_parallel")
    eta = jnp.float32(0.05)
    w0 = params["w"]
    d1 = rf(params, batches, alpha, c1, eta)[0]["w"] - w0
    d2 = rf(params, batches, alpha, c2, eta)[0]["w"] - w0
    d12 = rf(params, batches, alpha, c1 + c2, eta)[0]["w"] - w0
    np.testing.assert_allclose(np.asarray(d12), np.asarray(d1 + d2),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_masked_steps_match_truncated_run(seed):
    """Equivalent view (App. A.1.1): a client with prefix mask s equals a
    client literally running only s local steps."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=DIM), jnp.float32)}
    batch = {"c": jnp.asarray(rng.normal(size=(E, 1, DIM)), jnp.float32)}
    s = int(rng.integers(1, E + 1))
    alpha = jnp.asarray((np.arange(E) < s).astype(np.float32))
    eta = jnp.float32(0.05)
    delta_masked = local_sgd(_loss, params, batch, alpha, eta)
    batch_s = {"c": batch["c"][:s]}
    delta_trunc = local_sgd(_loss, params, batch_s, jnp.ones(s), eta)
    np.testing.assert_allclose(np.asarray(delta_masked["w"]),
                               np.asarray(delta_trunc["w"]),
                               rtol=1e-5, atol=1e-6)
