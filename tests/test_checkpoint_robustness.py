"""Checkpoint durability: atomic writes (a failed or killed save never
damages the previous checkpoint), checksum-gated loads (corruption is
detected, not resumed), and bit-exact round-trips for non-native dtypes
(bf16 leaves survive the npz container via a uint16 view + manifest
dtype record)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CorruptCheckpointError
from repro.checkpoint.io import (load_checkpoint, load_fed_checkpoint,
                                 save_checkpoint, save_fed_checkpoint)
from repro.fed import Fault, FaultPlan, InjectedWriteError
from repro.fed.faults import corrupt_file


def small_params(scale=1.0):
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4) * scale,
            "b": np.ones(4, np.float32) * scale}


def small_state(tau=3):
    return {"next_tau": tau, "seq": 0, "events_applied": 0,
            "rb_tau0": np.zeros(4, np.int32)}


def test_failed_save_leaves_previous_checkpoint_intact(tmp_path):
    """The io-error fires after the tmp file is written but before the
    rename — the prior npz/manifest pair must remain the committed one."""
    path = str(tmp_path / "ckpt")
    save_fed_checkpoint(path, small_params(1.0), small_state(tau=3))
    plan = FaultPlan([Fault("ckpt_save", 0, "io-error")], seed=0)
    with pytest.raises(InjectedWriteError):
        save_fed_checkpoint(path, small_params(2.0), small_state(tau=9),
                            injector=plan)
    params, state, _, _, _ = load_fed_checkpoint(path)
    np.testing.assert_array_equal(params["w"], small_params(1.0)["w"])
    assert state["next_tau"] == 3            # the old run, not the torn one
    assert not [f for f in os.listdir(path) if f.endswith(".tmp")]


def test_corrupted_npz_fails_checksum(tmp_path):
    path = str(tmp_path / "ckpt")
    save_fed_checkpoint(path, small_params(), small_state())
    corrupt_file(os.path.join(path, "fed_checkpoint.npz"),
                 np.random.default_rng(0))
    with pytest.raises(CorruptCheckpointError, match="checksum"):
        load_fed_checkpoint(path)
    # verify=False trades the gate for speed — on an intact file only;
    # here the zip container itself may also be broken, so just assert
    # the verified path is the one that guarantees detection
    with pytest.raises(Exception):
        load_fed_checkpoint(path)


def test_truncated_npz_is_corrupt_not_crash(tmp_path):
    path = str(tmp_path / "ckpt")
    save_fed_checkpoint(path, small_params(), small_state())
    npz = os.path.join(path, "fed_checkpoint.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(CorruptCheckpointError):
        load_fed_checkpoint(path)


def test_mangled_manifest_is_corrupt_not_crash(tmp_path):
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, small_params(), step=5)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write('{"step": 5, "keys": {')      # torn mid-write
    with pytest.raises(CorruptCheckpointError, match="manifest"):
        load_checkpoint(path)


@pytest.mark.parametrize("dtype", ["bfloat16", "float16", "float32"])
def test_plain_checkpoint_dtype_roundtrip(tmp_path, dtype):
    """npz cannot hold bf16 natively; the writer views it as uint16 and
    records the true dtype in the manifest — the round-trip must be
    bit-exact, not a float32 détour."""
    path = str(tmp_path / "ckpt")
    w = jnp.asarray(np.linspace(-3, 3, 24).reshape(4, 6), dtype=dtype)
    save_checkpoint(path, {"w": w, "n": np.arange(3)}, step=1)
    loaded, manifest = load_checkpoint(path)
    assert str(loaded["w"].dtype) == dtype
    np.testing.assert_array_equal(
        np.asarray(loaded["w"]).view(np.uint16 if dtype != "float32"
                                     else np.uint32),
        np.asarray(jax.device_get(w)).view(np.uint16 if dtype != "float32"
                                           else np.uint32))
    np.testing.assert_array_equal(loaded["n"], np.arange(3))


def test_fed_checkpoint_bf16_roundtrip(tmp_path):
    path = str(tmp_path / "ckpt")
    params = {"w": jnp.asarray([[1.5, -2.25], [0.125, 3e-3]],
                               dtype=jnp.bfloat16),
              "b": np.zeros(2, np.float32)}
    state = small_state()
    # state dicts carry numpy (FedState.to_dict contract) — an ml_dtypes
    # bf16 ndarray, not a jax Array
    state["blob"] = np.asarray(jax.device_get(
        jnp.asarray([0.1, 0.7], dtype=jnp.bfloat16)))
    save_fed_checkpoint(path, params, state)
    loaded, lstate, _, _, _ = load_fed_checkpoint(path)
    assert str(loaded["w"].dtype) == "bfloat16"
    np.testing.assert_array_equal(
        np.asarray(loaded["w"]).view(np.uint16),
        np.asarray(jax.device_get(params["w"])).view(np.uint16))
    assert str(lstate["blob"].dtype) == "bfloat16"
    np.testing.assert_array_equal(
        np.asarray(lstate["blob"]).view(np.uint16),
        state["blob"].view(np.uint16))
