"""Theory-scored validation (fed/validate.py): fuzzed participation
schedules executed through the real engine on closed-form quadratic
federations, every run scored against the Theorem 3.1 envelope computed
from the *observed* participation matrix, plus the paper's Table-1
scheme ordering.  And the meta-tests: seed the two breakage classes the
validator exists to catch — a mis-weighted scheme C (collapsed onto B's
biased coefficients) must trip the ordering check, and a mis-signed
aggregation must trip the bound check."""
import numpy as np
import pytest

import repro.fed.engine as engine_mod
from repro.core.aggregation import scheme_coefficients
from repro.fed import InvariantViolation
from repro.fed.validate import (QuadraticRunner, TheoryValidator,
                                generate_participation_schedule,
                                make_quadratic_problem, validate_corpus)

pytestmark = pytest.mark.fuzz


@pytest.fixture(scope="module")
def runner():
    """One pooled per-scheme engine set for the whole module (the scheme
    is baked at trace time, so each scheme owns its jit cache)."""
    return QuadraticRunner()


def test_validator_corpus_bound_and_ordering(runner):
    agg = validate_corpus(range(2), runner=runner)
    assert agg["cases"] == 2
    # the envelope is loose by construction; a clean run sits far below
    assert agg["max_margin"] < 0.05
    for row in agg["per_case"]:
        assert row["n_events"] >= 2            # schedules actually churn
        # Table-1 ordering with real headroom, not a squeaker
        assert row["tails"]["C"] < 0.6 * row["tails"]["A"]
        assert row["tails"]["C"] < 0.6 * row["tails"]["B"]


def test_quadratic_constants_are_closed_form():
    pr = make_quadratic_problem(seed=3)
    # w* solves sum_k p_k A_k (w - c_k) = 0 for diagonal A_k
    num = (pr.p[:, None] * pr.a_diag * pr.c).sum(0)
    den = (pr.p[:, None] * pr.a_diag).sum(0)
    np.testing.assert_allclose(pr.w_star, num / den, rtol=1e-10)
    assert pr.pc.mu > 0 and pr.pc.L >= pr.pc.mu
    assert pr.G2 > 0 and np.all(np.asarray(pr.pc.sigma2) == 0)


def test_schedule_generator_reproducible():
    a = generate_participation_schedule(5, n_clients=4, rounds=64)
    b = generate_participation_schedule(5, n_clients=4, rounds=64)
    assert repr(a) == repr(b)
    assert 2 <= len(a) <= 6
    assert repr(a) != repr(
        generate_participation_schedule(6, n_clients=4, rounds=64))


def test_observed_stats_feed_the_bound(runner):
    """score() consumes the run's own (p, s) matrix: E_ps sums to a
    positive effective rate and the bound trajectory is finite and
    decreasing in tau (the 1/(tau E + gamma) envelope)."""
    dump = runner.run("C", rounds=16, seed=0)
    sc = TheoryValidator(runner.problem).score(dump)
    assert sc["S"] > 0
    assert np.all(np.isfinite(sc["bounds"]))
    assert sc["bounds"][-1] < sc["bounds"][0]
    assert 0.0 <= sc["biased_frac"] <= 1.0


# -- mutation smoke: a validator that can't fail validates nothing -------------

def test_mutation_collapsed_scheme_c_is_caught(monkeypatch):
    """Drop scheme C's E/s debiasing (serve B's coefficients instead):
    C lands on B's bias plateau and the Table-1 ordering check fires.
    The engine bakes the coefficient fn at trace time, so the mutation
    patches the engine module's global before any engine is built."""
    def collapsed(scheme, p, s, E):
        return scheme_coefficients("B" if scheme == "C" else scheme,
                                   p, s, E)
    monkeypatch.setattr(engine_mod, "scheme_coefficients", collapsed)
    with pytest.raises(InvariantViolation) as ei:
        validate_corpus(range(1), runner=QuadraticRunner())
    assert ei.value.invariant == "scheme-ordering"


def test_mutation_sign_flipped_weights_are_caught(monkeypatch):
    """Mis-signed aggregation drives the iterate *away* from w*; the
    gap crosses the (loose) Theorem 3.1 envelope within a few rounds
    and the bound check fires — the divergence-tripwire role."""
    monkeypatch.setattr(
        engine_mod, "scheme_coefficients",
        lambda scheme, p, s, E: -scheme_coefficients(scheme, p, s, E))
    runner = QuadraticRunner()
    dump = runner.run("C", rounds=64, seed=0)
    with pytest.raises(InvariantViolation) as ei:
        TheoryValidator(runner.problem).check_bound(dump)
    assert ei.value.invariant == "theory-bound"
