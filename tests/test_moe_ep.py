"""Expert-parallel (shard_map) MoE path vs the dense jnp reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.moe import _moe_ffn_dense, moe_ffn
from repro.models.params import _moe_params
from repro.models.sharding import use_mesh

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "deepseek-v3-671b"])
def test_ep_matches_dense(arch):
    """On a 1x1 mesh the shard_map EP path must reproduce the dense path
    exactly (same routing, same capacity semantics per shard)."""
    cfg = get_config(arch).reduced()
    p = _moe_params(KEY, cfg, jnp.float32)
    x = 0.1 * jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_dense, aux_dense = _moe_ffn_dense(p, x, cfg)
    mesh = make_smoke_mesh(1, 1)
    with use_mesh(mesh):
        y_ep, aux_ep = moe_ffn(p, x, cfg, ep=True)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_ep), float(aux_dense), rtol=1e-4)


def test_ep_grads_match_dense():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = _moe_params(KEY, cfg, jnp.float32)
    x = 0.1 * jax.random.normal(KEY, (1, 16, cfg.d_model))

    def loss_dense(p):
        y, aux = _moe_ffn_dense(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    def loss_ep(p):
        y, aux = moe_ffn(p, x, cfg, ep=True)
        return jnp.sum(jnp.square(y)) + aux

    g_dense = jax.grad(loss_dense)(p)
    mesh = make_smoke_mesh(1, 1)
    with use_mesh(mesh):
        g_ep = jax.grad(loss_ep)(p)
    for a, b in zip(jax.tree.leaves(g_dense), jax.tree.leaves(g_ep)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)


def test_ep_refuses_under_vmap_misalignment():
    """ep=False (the client_parallel default) must take the dense path even
    with a mesh active."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = _moe_params(KEY, cfg, jnp.float32)
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    mesh = make_smoke_mesh(1, 1)
    with use_mesh(mesh):
        y1, _ = moe_ffn(p, x, cfg, ep=False)
    y2, _ = _moe_ffn_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
