"""Property tests for the participation-scheme algebra (paper §4.1-4.3):
seeded sweeps over random weight vectors, epoch counts and membership
churn, pinning the invariants every other layer leans on — coefficient
mass conservation, scheme A's objective-only N counting, scheme C's
exact debias identity, include-departed mass retention in
FedState.data_weights, and the staircase-LR restart convention shared by
core.arrivals and the in-jit engine formula.  Runs under real hypothesis
when installed, else the deterministic shim in conftest.py."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.aggregation import scheme_coefficients, theta_bound
from repro.core.arrivals import staircase_lr
from repro.core.participation import TRACES
from repro.fed import Arrival, Client, Departure, FedState
from repro.fed.validate import QuadraticRunner


def _random_p(rng, n, capacity):
    """Normalized weights over n members, zero-padded to capacity slots
    (the engine's buffer layout: empty columns carry p = 0)."""
    w = rng.uniform(0.2, 2.0, size=n)
    p = np.zeros(capacity)
    p[:n] = w / w.sum()
    return p


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 6),
       pad=st.integers(0, 4), E=st.integers(1, 8))
def test_coefficient_mass_and_bounds(seed, n, pad, E):
    """Coefficients are finite, non-negative, zero on padding, and each
    stays under the Assumption 3.5 ratio c_k <= theta p^k.  Per-round
    coefficient mass sum_k p_tau^k s_tau^k is conserved (<= E sum_k p^k)
    for schemes B and C; scheme A only bounds it by theta = N — its
    per-round excess when heavy devices finish IS the bias Theorem 3.1
    charges through M_tau."""
    rng = np.random.default_rng(seed)
    p = _random_p(rng, n, n + pad)
    s = np.where(np.arange(n + pad) < n,
                 rng.integers(0, E + 1, size=n + pad), 0)
    for scheme in ("A", "B", "C"):
        c = np.asarray(scheme_coefficients(scheme, p, s, E), np.float64)
        assert np.all(np.isfinite(c)) and np.all(c >= 0)
        assert np.all(c[p == 0] == 0)            # padding never weighted
        theta = theta_bound(scheme, n, E)
        assert np.all(c <= theta * p + 1e-6)
        cap = E * p.sum() if scheme in ("B", "C") else E * theta * p.sum()
        assert (c * s).sum() <= cap + 1e-5


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 6),
       pad=st.integers(0, 4), E=st.integers(1, 8))
def test_scheme_a_counts_objective_not_buffer(seed, n, pad, E):
    """Scheme A's N is the number of objective members (p > 0), not the
    slot-buffer length — zero-padded columns must not inflate the
    reweighting.  Checked against a direct numpy transcription of Eq. (2)
    restricted to the populated columns."""
    rng = np.random.default_rng(seed)
    p = _random_p(rng, n, n + pad)
    s = np.where(np.arange(n + pad) < n,
                 rng.integers(0, E + 1, size=n + pad), 0)
    c = np.asarray(scheme_coefficients("A", p, s, E), np.float64)
    complete = (s >= E) & (p > 0)
    K = complete.sum()
    want = np.zeros_like(p)
    if K > 0:
        want[complete] = n * p[complete] / K
    np.testing.assert_allclose(c, want, atol=1e-6)


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 6),
       E=st.integers(1, 8))
def test_scheme_c_debias_identity(seed, n, E):
    """The paper's contribution in one line: p_tau^k s_tau^k == E p^k
    whenever the device did any work — every participating member
    contributes its full unbiased mass regardless of how little it
    completed."""
    rng = np.random.default_rng(seed)
    p = _random_p(rng, n, n)
    s = rng.integers(0, E + 1, size=n)
    c = np.asarray(scheme_coefficients("C", p, s, E), np.float64)
    np.testing.assert_allclose(c * s, np.where(s > 0, E * p, 0.0),
                               atol=1e-6)


@settings(max_examples=10)
@given(seed=st.integers(0, 10_000), depart=st.integers(1, 3))
def test_include_departed_mass_retention(seed, depart):
    """§4.3 'include': a departed device keeps its mass in the
    normalization (the objective does not shift) but holds no slot, so
    data_weights sums to 1 - p_l while every remaining member keeps its
    original weight exactly; a later rejoin restores the full unit mass
    without an LR restart."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 20, size=4)
    clients = [Client(x=np.zeros((int(m), 2), np.float32),
                      y=np.zeros(int(m), np.int32), trace=TRACES[0])
               for m in counts]
    state = FedState(clients=clients, capacity=5)
    assert state.data_weights().sum() == pytest.approx(1.0)
    state.apply(Departure(3, client_id=depart, policy="include"), 3)
    total = counts.sum()
    p = state.data_weights()
    assert p.sum() == pytest.approx(1.0 - counts[depart] / total)
    for i in range(4):
        if i != depart:
            assert p[state.slot_of[i]] == pytest.approx(
                counts[i] / total)
    shift_before = state.lr_shift_tau
    state.apply(Arrival(7, client_id=depart), 7)
    assert state.data_weights().sum() == pytest.approx(1.0)
    assert state.lr_shift_tau == shift_before    # rejoin: no LR restart


@settings(max_examples=10)
@given(eta0=st.floats(0.01, 10.0), tau=st.integers(0, 200),
       tau0=st.integers(0, 200))
def test_staircase_lr_restart_and_decay(eta0, tau, tau0):
    """Cor. 3.2.1 shape: the restarted staircase returns exactly eta0 on
    the first round after the shift and decays monotonically after."""
    assert staircase_lr(eta0, tau0 + 1, tau0) == pytest.approx(eta0)
    a = staircase_lr(eta0, tau + 1, tau0)
    b = staircase_lr(eta0, tau + 2, tau0)
    assert 0 < b <= a <= eta0 + 1e-12


def test_staircase_lr_identity_through_engine():
    """The in-jit engine LR and core.arrivals.staircase_lr share one
    off-by-one convention: a real run's history must satisfy
    eta(tau) == staircase_lr(eta0, tau + 1, lr_shift_tau), including
    across a mid-run objective shift that restarts the staircase."""
    from repro.fed.stream import StreamScheduler
    runner = QuadraticRunner()
    eng = runner._engine("C")
    for slot in range(eng.capacity):
        eng.evict(slot)
    clients = runner._clients()
    eng.admit_many(list(enumerate(clients)))
    sch = StreamScheduler(
        clients=clients, init_params=runner.init_params, engine=eng,
        mode="device", seed=0, log_spans=True,
        events=[Departure(5, client_id=2, policy="exclude")])
    sch.run(10, eval_every=1 << 30)
    log = sorted(sch.span_log, key=lambda t: t[0])
    shifts = set()
    j = 0
    for rec in sch.history:
        while j + 1 < len(log) and log[j + 1][0] <= rec.tau:
            j += 1
        lr_shift = log[j][3]
        shifts.add(lr_shift)
        assert rec.eta == pytest.approx(
            staircase_lr(runner.eta0, rec.tau + 1, lr_shift), rel=1e-5)
    assert shifts == {0, 5}                      # the departure restarted
