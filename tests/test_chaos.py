"""Chaos-hardened supervision (fed/service.py + fed/faults.py): injected
failures at every boundary must auto-recover from span-consistent
snapshots with the RoundRecord history — and the final params — exactly
what a fault-free run produces.  The bit-exact bar is what makes
recovery testable at all: per-round randomness is folded from tau, so a
rollback-and-replay trajectory is indistinguishable from never crashing."""
import os
import time

import jax
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import (Client, Fault, FaultPlan, FederationService,
                       StreamScheduler, TraceShift)
from repro.fed.faults import corrupt_file
from repro.models.small import init_small, make_loss_fn

CFG = SYNTHETIC_LR
NO_EVAL = 1 << 30


def make_clients(n=4, seed=0):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[0],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_scheduler(**kw):
    return StreamScheduler(
        clients=make_clients(), init_params=init_small(
            jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), capacity=6, max_samples=600,
        local_epochs=5, batch_size=6, scheme="C", eta0=1.0, seed=0,
        mode="device", chunk_size=4, **kw)


def supervised(sch, tmpdir, **kw):
    eng = sch.engine
    defaults = dict(span_rounds=4, supervise=True,
                    snapshot_dir=str(tmpdir), snapshot_every=1,
                    keep_snapshots=4, backoff0=0.01, join_timeout=10.0,
                    engine_factory=lambda: eng,
                    restore_kwargs=dict(loss_fn=make_loss_fn(CFG)))
    defaults.update(kw)
    return FederationService(sch, **defaults)


def assert_bitexact(ref, live):
    assert len(ref.history) == len(live.history)
    for r1, r2 in zip(ref.history, live.history):
        assert (r1.tau, r1.event, r1.eta) == (r2.tau, r2.event, r2.eta)
        np.testing.assert_array_equal(r1.s, r2.s)
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(live.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_acceptance_soak_every_fault_site_one_run(tmp_path):
    """The headline: worker crash, worker hang (watchdog), mid-span
    scheduler crash, snapshot write failure, snapshot corruption, and a
    256-event stale flood — in ONE 32-round run — and the service still
    produces the bit-exact fault-free trajectory."""
    ref = make_scheduler()
    ref.run(32, eval_every=NO_EVAL)

    plan = FaultPlan([
        Fault("worker", 1, "crash"),
        Fault("worker", 4, "hang", seconds=30.0),
        Fault("sched_span", 6, "crash"),
        Fault("ckpt_save", 3, "io-error"),
        Fault("ckpt_written", 5, "corrupt", size=16),
        Fault("flood", 2, "flood", size=256),
    ], seed=7)
    sch = make_scheduler(injector=plan)
    svc = supervised(sch, tmp_path, max_rounds=32, span_timeout=2.0,
                     queue_policy="merge-stale", max_queue=64)
    with svc:
        assert svc.wait_rounds(32, timeout=300), svc.stats()
    rep = svc.chaos_report()

    fired_sites = {site for site, _, _ in rep["faults"]["fired"]}
    assert fired_sites == {"worker", "sched_span", "ckpt_save",
                           "ckpt_written", "flood"}
    assert rep["n_recoveries"] >= 3          # crash, watchdog, mid-span
    assert rep["snapshot_failures"] >= 1     # the io-error was absorbed
    assert rep["events_merged"] == 256       # the flood never hit history
    assert rep["mttr_max_s"] < 60
    causes = " ".join(r["cause"] for r in rep["recoveries"])
    assert "Timeout" in causes               # the hang died by watchdog
    assert all(r["engine_reused"] for r in rep["recoveries"])
    assert_bitexact(ref, svc.scheduler)


def test_corrupt_newest_snapshot_falls_back(tmp_path):
    """Corrupt the snapshot written right before a crash: recovery must
    detect it (checksum), skip to the older epoch, recompute the lost
    span, and still land bit-exact."""
    ref = make_scheduler()
    ref.run(16, eval_every=NO_EVAL)

    # save #0 is the gen-0 base; span k writes save #k+1 — corrupting
    # ckpt_written #2 poisons the newest snapshot (tau=8) exactly when
    # worker #2 crashes before span 2 runs
    plan = FaultPlan([
        Fault("ckpt_written", 2, "corrupt", size=16),
        Fault("worker", 2, "crash"),
    ], seed=11)
    sch = make_scheduler(injector=plan)
    svc = supervised(sch, tmp_path, max_rounds=16)
    with svc:
        assert svc.wait_rounds(16, timeout=180), svc.stats()
    rep = svc.chaos_report()

    assert rep["n_recoveries"] == 1
    rec = rep["recoveries"][0]
    assert len(rec["corrupt_skipped"]) == 1  # newest snapshot rejected
    assert rec["tau_at_failure"] == 8
    assert rec["tau_resumed"] == 4           # older epoch, one span back
    assert rep["recovered_rounds"] == 4
    assert_bitexact(ref, svc.scheduler)


def test_journal_replays_events_lost_with_the_snapshot(tmp_path):
    """Events ingested after the last snapshot must survive a crash:
    they are journaled at ingest and replayed onto the restored state."""
    ref = make_scheduler()
    ref.push(TraceShift(5, client_id=0, trace=TRACES[3]))
    ref.run(12, eval_every=NO_EVAL)

    plan = FaultPlan([Fault("worker", 2, "crash")], seed=0)
    sch = make_scheduler(injector=plan)
    # snapshot_every huge: the gen-0 base snapshot (tau=0) is the only
    # one on disk, so recovery must re-derive everything from the journal
    svc = supervised(sch, tmp_path, max_rounds=12, snapshot_every=10 ** 6)
    svc.submit(TraceShift(5, client_id=0, trace=TRACES[3]))
    with svc:
        assert svc.wait_rounds(12, timeout=180), svc.stats()
    rep = svc.chaos_report()

    assert rep["n_recoveries"] == 1
    rec = rep["recoveries"][0]
    assert rec["tau_resumed"] == 0           # rolled back to the base
    assert rec["events_replayed"] == 1       # ...but kept the news
    assert_bitexact(ref, svc.scheduler)
    assert any("shift" in h.event for h in svc.scheduler.history)


def test_watchdog_frees_a_hung_worker(tmp_path):
    """A worker stuck mid-span trips the span watchdog; the supervisor
    abandons the wedged generation (its span lock is never coming back)
    and a fresh worker finishes the job."""
    plan = FaultPlan([Fault("worker", 1, "hang", seconds=120.0)], seed=0)
    sch = make_scheduler(injector=plan)
    svc = supervised(sch, tmp_path, max_rounds=12, span_timeout=1.5)
    t0 = time.monotonic()
    with svc:
        assert svc.wait_rounds(12, timeout=120), svc.stats()
    assert time.monotonic() - t0 < 100       # did not sit out the hang
    rep = svc.chaos_report()
    assert rep["n_recoveries"] == 1
    assert "Timeout" in rep["recoveries"][0]["cause"]
    assert svc.generation == 1


def test_gives_up_after_max_restarts(tmp_path):
    """A fault that returns on every restart must not retry forever:
    after max_restarts consecutive failures the supervisor surfaces the
    error instead of burning the machine."""
    plan = FaultPlan([Fault("worker", k, "crash") for k in range(16)],
                     seed=0)
    sch = make_scheduler(injector=plan)
    svc = supervised(sch, tmp_path, max_rounds=32, max_restarts=3)
    svc.start()
    with pytest.raises(RuntimeError, match="worker died"):
        svc.wait_rounds(32, timeout=60)
    with pytest.raises(RuntimeError, match="worker died"):
        svc.stop(wait=True, timeout=30)
    assert len(svc.recoveries) == 3              # tried, tried, tried
    assert svc.scheduler._next_tau == 0          # every span crashed


def test_recovery_without_engine_factory_rebuilds(tmp_path):
    """No pooled engine offered: recovery falls back to a cold rebuild
    (slower, still bit-exact)."""
    ref = make_scheduler()
    ref.run(8, eval_every=NO_EVAL)

    plan = FaultPlan([Fault("worker", 1, "crash")], seed=0)
    sch = make_scheduler(injector=plan)
    svc = supervised(sch, tmp_path, max_rounds=8, engine_factory=None)
    with svc:
        assert svc.wait_rounds(8, timeout=180), svc.stats()
    rep = svc.chaos_report()
    assert rep["n_recoveries"] == 1
    assert not rep["recoveries"][0]["engine_reused"]
    assert_bitexact(ref, svc.scheduler)


def test_snapshot_retention_prunes_disk(tmp_path):
    """keep_snapshots bounds disk: old epochs (and their journal prefix)
    are dropped as new snapshots land."""
    sch = make_scheduler()
    svc = supervised(sch, tmp_path, max_rounds=24, keep_snapshots=2)
    with svc:
        assert svc.wait_rounds(24, timeout=180)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("snap-"))
    assert len(kept) <= 2
    assert svc.stats()["snapshots_kept"] == len(kept)


def test_manual_corruption_detected_at_load(tmp_path):
    """Byte-flip a persisted fed checkpoint: the manifest checksum gate
    refuses it with CorruptCheckpointError instead of resuming garbage."""
    from repro.checkpoint import CorruptCheckpointError

    sch = make_scheduler()
    sch.run(4, eval_every=NO_EVAL)
    path = str(tmp_path / "ckpt")
    sch.save(path)
    StreamScheduler.restore(path, loss_fn=make_loss_fn(CFG))  # loads fine
    rng = np.random.default_rng(0)
    corrupt_file(os.path.join(path, "fed_checkpoint.npz"), rng)
    with pytest.raises(CorruptCheckpointError):
        StreamScheduler.restore(path, loss_fn=make_loss_fn(CFG))
