"""System-level behaviour + deliverable invariants."""
import os

import jax
import numpy as np
import pytest

import repro
from repro.configs import ARCH_IDS, INPUT_SHAPES, PAPER_IDS, get_config


def test_all_assigned_archs_registered():
    assert len(ARCH_IDS) == 10
    families = {get_config(a).family for a in ARCH_IDS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_input_shapes_match_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long_context_skips_are_principled():
    """long_500k runs for sub-quadratic archs only (DESIGN.md)."""
    runs = {a for a in ARCH_IDS if get_config(a).supports_shape("long_500k")}
    assert runs == {"mamba2-130m", "hymba-1.5b", "starcoder2-3b"}


def test_configs_cite_sources():
    for a in ARCH_IDS:
        assert get_config(a).source, a


def test_dryrun_sets_device_count_before_imports():
    """The dry-run MUST set XLA_FLAGS before any jax import."""
    path = os.path.join(os.path.dirname(repro.__file__), "launch",
                        "dryrun.py")
    with open(path) as f:
        src = f.read()
    assert src.index("XLA_FLAGS") < src.index("import jax")
    head = src.splitlines()[:2]
    assert head[0].startswith("import os")
    assert "xla_force_host_platform_device_count=512" in head[1]


def test_serving_paths_run_reduced():
    """Drift gate for the serving substrate: both serving drivers
    (repro.launch.serve and examples/serve_batched) must keep running a
    ``cfg.reduced()`` model end-to-end while the model layer is
    refactored — prefill + a couple of decode steps each."""
    import importlib.util

    from repro.launch import serve as serve_cli
    serve_cli.main(["--arch", "mamba2-130m", "--batch", "2",
                    "--prompt-len", "8", "--gen", "2"])

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "serve_batched", os.path.join(root, "examples", "serve_batched.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    n, t_prefill, t_decode = mod.serve(
        get_config("starcoder2-3b").reduced(), batch=2, prompt_len=8, gen=2)
    assert n > 0 and t_prefill > 0 and t_decode > 0


def test_exact_arch_dimensions():
    """Spot-check assigned dims against the brief."""
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert (c.n_experts, c.top_k, c.n_shared_experts) == (256, 8, 1)
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 12288, 96, 8, 33792, 256000)
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.ssm_d_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_d_state) == (24, 768,
                                                               50280, 128)
    assert c.attn_free


def test_paper_models_present():
    from repro.configs.paper import PAPER_CONFIGS
    assert set(PAPER_CONFIGS) == {"mnist_mlp", "emnist_cnn", "synthetic_lr"}
    assert set(PAPER_IDS) == set(PAPER_CONFIGS)


def test_param_counts_in_expected_range():
    """Full configs land near their nameplate sizes (abstract shapes)."""
    from repro.launch.steps import param_bytes
    expect = {
        "gemma-7b": (7e9, 10.5e9),
        "mamba2-130m": (0.1e9, 0.25e9),
        "command-r-plus-104b": (95e9, 118e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "nemotron-4-15b": (13e9, 19e9),
        "starcoder2-3b": (2.5e9, 4e9),
        "hymba-1.5b": (1.1e9, 2.2e9),
        "llava-next-34b": (29e9, 38e9),
        "musicgen-medium": (1.0e9, 2.4e9),
    }
    for a, (lo, hi) in expect.items():
        n_params = param_bytes(get_config(a)) / 2  # bf16
        assert lo <= n_params <= hi, (a, n_params)
