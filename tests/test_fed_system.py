"""End-to-end federated system tests: the driver trains real (small)
models, handles arrivals/departures, and checkpoints roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def make_eval_fn(cfg):
    def eval_fn(params, x, y):
        lg = logits_small(params, cfg, x)
        ll = jax.nn.log_softmax(lg)
        loss = -jnp.mean(jnp.take_along_axis(
            ll, y[:, None].astype(jnp.int32), axis=1))
        acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
        return float(loss), float(acc)
    return eval_fn


def make_clients(n=12, seed=0, alpha=0.5, beta=0.5, trace_pool=5):
    train, test = synthetic_federation(alpha, beta, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1],
                   trace=TRACES[rng.integers(0, trace_pool)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_trainer(clients, scheme="C", **kw):
    return FederatedTrainer(
        loss_fn=make_loss_fn(CFG), eval_fn=make_eval_fn(CFG),
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        clients=clients, local_epochs=5, batch_size=20, scheme=scheme,
        eta0=1.0, seed=0, **kw)


def test_training_reduces_loss():
    tr = make_trainer(make_clients())
    hist = tr.run(20)
    assert hist[-1].loss < 0.7 * hist[0].loss
    assert hist[-1].acc > hist[0].acc


def test_arrival_triggers_shift_and_reboot():
    clients = make_clients(8)
    clients.append(
        Client(x=clients[0].x, y=clients[0].y, trace=TRACES[0],
               x_test=clients[0].x_test, y_test=clients[0].y_test,
               active_from=5))
    tr = make_trainer(clients)
    hist = tr.run(8)
    assert 8 in tr.objective
    ev = [h.event for h in hist if h.event]
    assert any("arrival:8" in e for e in ev)
    assert tr.lr_shift_tau == 5
    assert len(tr.reboots) == 1


def test_departure_exclude_shrinks_objective():
    clients = make_clients(8)
    clients[3].departs_at = 4
    clients[3].departure_policy = "exclude"
    tr = make_trainer(clients)
    tr.run(6)
    assert 3 not in tr.objective
    p = tr.data_weights()
    assert p[3] == 0.0
    np.testing.assert_allclose(p.sum(), 1.0)


def test_departure_include_keeps_objective():
    clients = make_clients(8)
    clients[3].departs_at = 4
    clients[3].departure_policy = "include"
    tr = make_trainer(clients)
    hist = tr.run(6)
    assert 3 in tr.objective
    # but it no longer participates
    assert hist[-1].s[3] == 0.0


def test_checkpoint_roundtrip(tmp_path):
    tr = make_trainer(make_clients(4))
    tr.run(3)
    save_checkpoint(str(tmp_path / "ckpt"), tr.params, step=3,
                    extra={"scheme": "C"})
    params2, manifest = load_checkpoint(str(tmp_path / "ckpt"))
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(params2)):
        np.testing.assert_allclose(np.asarray(a), b)


def test_scheme_c_beats_b_heterogeneous_noniid():
    """The paper's headline experimental claim (Table 3), miniaturized:
    with heterogeneous traces + non-IID data, Scheme C >= Scheme B."""
    accs = {}
    for scheme in ("B", "C"):
        clients = make_clients(16, seed=3, alpha=1.0, beta=1.0,
                               trace_pool=5)
        tr = make_trainer(clients, scheme=scheme)
        hist = tr.run(40)
        accs[scheme] = np.mean([h.acc for h in hist[-5:]])
    assert accs["C"] >= accs["B"] - 0.02, accs


def test_auto_departure_policy_uses_corollary():
    """policy='auto' applies Cor. 4.0.3: exclude when plenty of time
    remains, include when the deadline is imminent."""
    # plenty of time -> exclude
    clients = make_clients(8)
    clients[2].departs_at = 3
    clients[2].departure_policy = "auto"
    tr = make_trainer(clients)
    tr.horizon = 500
    tr.run(5)
    assert 2 not in tr.objective
    # departing late with the deadline imminent -> include (the
    # restarted bound V~/((T-tau0)E+gamma) cannot beat the nearly
    # converged f0; cf. test_departure_rule_prefers_exclude_with_time_left)
    clients = make_clients(8)
    clients[2].departs_at = 6
    clients[2].departure_policy = "auto"
    tr = make_trainer(clients)
    tr.horizon = 7
    tr.bound_terms = type(tr.bound_terms)(D=5.0, V=20.0, gamma=10.0, E=5)
    tr.clients[2].gamma_l = 10.0  # strongly non-IID departer
    tr.run(8)
    assert 2 in tr.objective
