"""Concat audit (ROADMAP): every mixed-sharding concatenate in the model
zoo routes through models/common.safe_concat, and the sharded paths match
single-device values on a real (virtual) 4-device mesh.

In-process tests pin safe_concat's arithmetic; the mesh regression runs
in a subprocess (tests/_concat_check.py) because XLA_FLAGS must virtualize
devices before jax initializes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _subproc
from repro.models.common import safe_concat


def test_safe_concat_matches_concatenate_single_device():
    key = jax.random.PRNGKey(0)
    parts = [jax.random.normal(jax.random.fold_in(key, i), shape)
             for i, shape in enumerate([(3, 5, 7), (3, 5, 2), (3, 5, 11)])]
    for axis in (-1, 2):
        np.testing.assert_array_equal(
            np.asarray(safe_concat(parts, axis)),
            np.asarray(jnp.concatenate(parts, axis)))
    rows = [jax.random.normal(key, (2, 4, 6)),
            jax.random.normal(key, (2, 1, 6))]
    np.testing.assert_array_equal(
        np.asarray(safe_concat(rows, 1)),
        np.asarray(jnp.concatenate(rows, 1)))


def test_mla_and_conv_decode_use_safe_concat():
    """Source-level guard: the audited call sites must not regress to a
    raw concatenate (values only diverge on multi-device meshes, which
    the tier-1 in-process suite cannot see)."""
    import repro.models.mla as mla
    import repro.models.ssd as ssd
    import inspect
    mla_src = inspect.getsource(mla.mla_attention)
    assert "safe_concat" in mla_src
    assert "jnp.concatenate" not in mla_src
    ssd_src = inspect.getsource(ssd.mamba_mixer)
    assert "safe_concat" in ssd_src
    assert "jnp.concatenate" not in ssd_src


@pytest.fixture(scope="module")
def concat_check():
    """Run tests/_concat_check.py once under a 4-device CPU mesh."""
    return _subproc.run_check("_concat_check.py")


def test_safe_concat_bug_shape_multi_device(concat_check):
    assert concat_check["safe_concat_micro_err"] < 1e-6
    assert concat_check["n_devices"] == 4


def test_mla_sharded_decode_multi_device(concat_check):
    assert concat_check["deepseek-v2-lite-16b_prefill_decode_err"] < 1e-4


def test_ssd_conv_cache_sharded_decode_multi_device(concat_check):
    assert concat_check["mamba2-130m_prefill_decode_err"] < 1e-4
