"""Subprocess body for tests/test_sharded_engine.py.

Multi-device sharding can only be exercised if XLA_FLAGS is set before
jax initializes, and the tier-1 pytest process has long since imported
jax — so the 4-virtual-device checks run here, in a fresh interpreter.
Any assertion failure exits nonzero with a traceback on stderr; on
success the last stdout line is ``RESULT {json}`` for the parent test to
parse.

Checks (the acceptance criteria of the sharded federation axis):
  1. weighted_agg_sharded == the single-device reduction, for both the
     single-block-K layout and the streamed multi-block-K (k_block) one;
  2. plan-mode parity: a sharded StreamScheduler matches the unsharded
     one round-for-round (identical RNG stream, params allclose) through
     arrival/departure churn, with capacity padded 6 -> 8 over 4 shards;
  3. device-mode sampling is sharding-invariant: identical s streams;
  4. zero scan recompiles across admit/evict/trace-shift churn under
     sharding (compile-cache entry counts are flat);
  5. null-vs-enabled telemetry on the *sharded* engine: bit-identical
     history and params, identical trace counts (the single-device
     pin lives in tests/test_telemetry.py).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import _subproc  # noqa: E402
from repro.configs.paper import SYNTHETIC_LR  # noqa: E402
from repro.core.participation import TRACES  # noqa: E402
from repro.data import synthetic_federation  # noqa: E402
from repro.fed import (Arrival, Client, Departure,  # noqa: E402
                       StreamScheduler, TraceShift, make_fed_sharding)
from repro.models.small import init_small, make_loss_fn  # noqa: E402

CFG = SYNTHETIC_LR
RESULTS = {}


def make_clients(n=6, seed=0):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_sched(sharding, mode, agg="auto", capacity=7, chunk_size=16):
    newcomer = make_clients(1, seed=99)[0]
    return StreamScheduler(
        clients=make_clients(), init_params=init_small(
            jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), capacity=capacity, max_samples=60,
        local_epochs=5, batch_size=10, scheme="C", eta0=0.5, seed=0,
        mode=mode, agg=agg, sharding=sharding, chunk_size=chunk_size,
        events=[Arrival(3, client=newcomer),
                Departure(6, client_id=2, policy="exclude")])


def check_kernel_psum(fs):
    from repro.kernels.ops import weighted_agg, weighted_agg_sharded
    K, D = 64, 600
    coeffs = jax.random.uniform(jax.random.PRNGKey(0), (K,))
    deltas = jax.random.normal(jax.random.PRNGKey(1), (K, D))
    want = np.asarray(weighted_agg(coeffs, deltas))
    for kb in (None, 8):   # single-block K and streamed multi-block K
        got = np.asarray(weighted_agg_sharded(
            coeffs, deltas, mesh=fs.mesh, k_block=kb))
        err = float(np.abs(got - want).max())
        RESULTS[f"kernel_err_kblock_{kb}"] = err
        assert err < 1e-4, f"psum epilogue diverges (k_block={kb}): {err}"


def check_plan_parity(fs):
    single = make_sched(None, "plan")
    sharded = make_sched(fs, "plan")
    assert sharded.engine.capacity == 8, sharded.engine.capacity  # 7 -> 8
    assert single.engine.capacity == 7
    maxerr = 0.0
    for _ in range(12):
        single.run(1, eval_every=4)
        sharded.run(1, eval_every=4)
        for a, b in zip(jax.tree.leaves(single.params),
                        jax.tree.leaves(sharded.params)):
            maxerr = max(maxerr, float(np.abs(np.asarray(a, np.float32)
                                              - np.asarray(b, np.float32)
                                              ).max()))
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-5)
    for h1, h2 in zip(single.history, sharded.history):
        np.testing.assert_array_equal(h1.s, h2.s[:len(h1.s)])
        assert (h2.s[len(h1.s):] == 0).all()    # padded slots never train
        assert h1.event == h2.event
    RESULTS["plan_parity_rounds"] = 12
    RESULTS["plan_parity_max_err"] = maxerr


def check_device_sampling_invariance(fs):
    # equal capacity on both sides: the on-device uniform draw is shaped
    # (R, capacity), so only the mesh layout may differ — the sampled s
    # stream must not (threefry is placement-invariant under GSPMD)
    single = make_sched(None, "device", capacity=8)
    sharded = make_sched(fs, "device", capacity=8)
    single.run(10, eval_every=5)
    sharded.run(10, eval_every=5)
    for h1, h2 in zip(single.history, sharded.history):
        np.testing.assert_array_equal(h1.s, h2.s)
    RESULTS["device_s_stream_identical"] = True


def check_zero_recompile_churn(fs):
    # chunk_size=2 bounds the pow2 chunk lengths to {1, 2}; the first run
    # (with its own events at tau 3 and 6) warms both, so any new cache
    # entry afterwards is a genuine membership-churn recompile
    sch = make_sched(fs, "device", agg="flat", chunk_size=2)
    sch.run(10, eval_every=5)           # warm every pow2 chunk + events
    eng = sch.engine
    fns = dict(eng._fns)
    assert fns, "expected compiled chunk fns"
    sizes = {k: f._cache_size() for k, f in fns.items()}
    sch.push(Arrival(12, client=make_clients(1, seed=123)[0]),
             TraceShift(13, client_id=0, trace=TRACES[3]),
             Departure(15, client_id=1, policy="exclude"))
    sch.run(10, eval_every=5)
    for k, f in eng._fns.items():
        if k in sizes:
            assert f._cache_size() == sizes[k], f"chunk {k} recompiled"
    assert set(eng._fns) == set(fns), "new scan lengths compiled"
    RESULTS["recompiles_across_churn"] = 0
    RESULTS["events_applied"] = sch.events_applied


def check_null_telemetry(fs):
    # PR 7 pinned null-vs-enabled telemetry bit-identity on the single-
    # device engine only; the sharded engine threads telemetry through
    # shard_map'd spans, so the contract needs its own pin here
    from repro.obs.telemetry import Telemetry

    def build(telemetry):
        newcomer = make_clients(1, seed=99)[0]
        sch = StreamScheduler(
            clients=make_clients(), init_params=init_small(
                jax.random.PRNGKey(0), CFG),
            loss_fn=make_loss_fn(CFG), capacity=8, max_samples=60,
            local_epochs=5, batch_size=10, scheme="C", eta0=0.5, seed=0,
            mode="device", sharding=fs, chunk_size=4,
            telemetry=telemetry,
            events=[Arrival(3, client=newcomer),
                    Departure(6, client_id=2, policy="exclude"),
                    TraceShift(5, client_id=1, trace=TRACES[3])])
        sch.run(10, eval_every=4)
        return sch

    a = build(None)
    b = build(Telemetry())
    assert a.engine.trace_count == b.engine.trace_count, \
        (a.engine.trace_count, b.engine.trace_count)
    assert len(a.history) == len(b.history)
    for ra, rb in zip(a.history, b.history):
        assert ra.tau == rb.tau and ra.event == rb.event
        assert ra.n_active == rb.n_active and ra.eta == rb.eta
        np.testing.assert_array_equal(np.asarray(ra.s), np.asarray(rb.s))
    for la, lb in zip(jax.tree.leaves(a.params),
                      jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    RESULTS["null_telemetry_bit_identical"] = True
    RESULTS["null_telemetry_trace_count"] = int(a.engine.trace_count)


def main():
    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 virtual devices, got {n_dev}"
    fs = make_fed_sharding(4)
    assert fs.n_shards == 4
    check_kernel_psum(fs)
    check_plan_parity(fs)
    check_device_sampling_invariance(fs)
    check_zero_recompile_churn(fs)
    check_null_telemetry(fs)
    RESULTS["n_devices"] = n_dev
    _subproc.emit(RESULTS)


if __name__ == "__main__":
    main()
