"""Subprocess body for tests/test_compression.py.

Multi-device sharding needs XLA_FLAGS set before jax initializes, so the
4-virtual-device quantized-aggregation checks run here in a fresh
interpreter (same pattern as tests/_sharded_check.py).  On success the
last stdout line is ``RESULT {json}``.

Checks (the sharded acceptance criteria of the compressed-delta path):
  1. weighted_agg_quant_sharded == the single-device quantized kernel
     (identical codes/scales, shard-local dequant matvec + f32 psum
     epilogue vs one full reduction), single- and multi-block-K;
  2. a sharded int8 StreamScheduler matches the single-device int8 one
     (equal capacity, identical s streams, params within the same
     tolerance the f32 plan-parity check uses);
  3. zero scan recompiles across admit/evict/trace-shift churn on the
     quantized flat path.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import _subproc  # noqa: E402
from repro.configs.paper import SYNTHETIC_LR  # noqa: E402
from repro.core.compression import quantize_chunked  # noqa: E402
from repro.core.participation import TRACES  # noqa: E402
from repro.data import synthetic_federation  # noqa: E402
from repro.fed import (Arrival, Client, Departure,  # noqa: E402
                       StreamScheduler, TraceShift, make_fed_sharding)
from repro.models.small import init_small, make_loss_fn  # noqa: E402

CFG = SYNTHETIC_LR
RESULTS = {}


def make_clients(n=6, seed=0):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_sched(sharding, capacity=8, chunk_size=4):
    newcomer = make_clients(1, seed=99)[0]
    return StreamScheduler(
        clients=make_clients(), init_params=init_small(
            jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), capacity=capacity, max_samples=60,
        local_epochs=5, batch_size=10, scheme="C", eta0=0.5, seed=0,
        mode="device", agg="flat", compression="int8",
        sharding=sharding, chunk_size=chunk_size,
        events=[Arrival(3, client=newcomer),
                Departure(6, client_id=2, policy="exclude")])


def check_quant_kernel_psum(fs):
    from repro.kernels.ops import weighted_agg_quant, \
        weighted_agg_quant_sharded
    K, D, chunk = 64, 600, 64
    coeffs = jax.random.uniform(jax.random.PRNGKey(0), (K,))
    flat = jax.random.normal(jax.random.PRNGKey(1), (K, D)) * 0.3
    payload, scales = quantize_chunked(flat, chunk=chunk)
    want = np.asarray(weighted_agg_quant(coeffs, payload, scales,
                                         chunk=chunk))
    for kb in (None, 8):   # single-block K and streamed multi-block K
        got = np.asarray(weighted_agg_quant_sharded(
            coeffs, payload, scales, chunk=chunk, mesh=fs.mesh,
            k_block=kb))
        err = float(np.abs(got - want).max())
        RESULTS[f"quant_kernel_err_kblock_{kb}"] = err
        assert err < 1e-4, \
            f"quant psum epilogue diverges (k_block={kb}): {err}"


def check_quant_scheduler_parity(fs):
    # equal capacity on both sides so the (R, capacity) uniform draw —
    # and therefore the quantization input trajectory — coincides; only
    # the f32 reduction order differs (shard partials + psum vs one
    # accumulating grid), which amplifies like the documented flat-vs-
    # tree case, so the tolerance matches the f32 plan-parity gate
    single = make_sched(None)
    sharded = make_sched(fs)
    assert single.engine.compression.name == "int8"
    assert sharded.engine.compression.name == "int8"
    maxerr = 0.0
    for _ in range(12):
        single.run(1, eval_every=4)
        sharded.run(1, eval_every=4)
        for a, b in zip(jax.tree.leaves(single.params),
                        jax.tree.leaves(sharded.params)):
            maxerr = max(maxerr, float(np.abs(np.asarray(a, np.float32)
                                              - np.asarray(b, np.float32)
                                              ).max()))
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-5)
    for h1, h2 in zip(single.history, sharded.history):
        np.testing.assert_array_equal(h1.s, h2.s)
        assert h1.event == h2.event
    RESULTS["quant_parity_rounds"] = 12
    RESULTS["quant_parity_max_err"] = maxerr


def check_zero_recompile_churn(fs):
    # chunk_size=2 bounds the pow2 chunk lengths to {1, 2}; the first
    # run (with its own events at tau 3 and 6) warms both, so any new
    # cache entry afterwards is a genuine membership-churn recompile
    sch = make_sched(fs, chunk_size=2)
    sch.run(10, eval_every=5)           # warm every pow2 chunk + events
    eng = sch.engine
    fns = dict(eng._fns)
    assert fns, "expected compiled chunk fns"
    sizes = {k: f._cache_size() for k, f in fns.items()}
    sch.push(Arrival(12, client=make_clients(1, seed=123)[0]),
             TraceShift(13, client_id=0, trace=TRACES[3]),
             Departure(15, client_id=1, policy="exclude"))
    sch.run(10, eval_every=5)
    for k, f in eng._fns.items():
        if k in sizes:
            assert f._cache_size() == sizes[k], f"chunk {k} recompiled"
    assert set(eng._fns) == set(fns), "new scan lengths compiled"
    RESULTS["recompiles_across_churn"] = 0
    RESULTS["events_applied"] = sch.events_applied


def main():
    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 virtual devices, got {n_dev}"
    fs = make_fed_sharding(4)
    assert fs.n_shards == 4
    check_quant_kernel_psum(fs)
    check_quant_scheduler_parity(fs)
    check_zero_recompile_churn(fs)
    RESULTS["n_devices"] = n_dev
    _subproc.emit(RESULTS)


if __name__ == "__main__":
    main()
