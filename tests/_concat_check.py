"""Subprocess body for tests/test_safe_concat.py (the concat audit under a
real multi-device mesh) — same harness pattern as tests/_sharded_check.py:
XLA_FLAGS must virtualize devices before jax initializes, so the checks
run in a fresh interpreter and report a ``RESULT {json}`` line on success.

Background: this jax/XLA's GSPMD partitioner miscompiles ``concatenate``
when the operands carry different shardings and the concatenated dim's
shard boundary does not align with the piece boundaries (wrong *values*,
observed max err ~4.5 — see models/common.safe_concat).  PR 4 fixed the
SSD mixer's xBC projection; the ROADMAP concat audit flagged MLA's q/k
rope concats and the decode-path conv cache concat as the same shape.
Those now route through safe_concat; this check pins the sharded paths to
the single-device reference values:

  1. MLA prefill + absorbed decode (deepseek-v2-lite reduced) on a
     (data=1, model=4) mesh with 'model'-sharded params == replicated
     no-mesh run;
  2. SSD prefill + conv-cache decode (mamba2-130m reduced), same mesh;
  3. safe_concat == concatenate on mixed-sharded operands directly (the
     micro-reproducer of the underlying bug shape).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import _subproc  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.models.common import safe_concat  # noqa: E402
from repro.models.params import init_params  # noqa: E402
from repro.models.sharding import (named_sharding,  # noqa: E402
                                   tree_param_specs, use_mesh)

RESULTS = {}
KEY = jax.random.PRNGKey(0)


def _decode_trace(cfg, params, tokens, mesh=None):
    """Prefill most of the prompt, then step-decode the tail; returns the
    stacked decode logits.  With a mesh, params are placed per the model's
    partition specs and the forward runs under use_mesh."""
    B, S = tokens.shape[0], tokens.shape[1]
    Sp = S - 3

    def run():
        cache = transformer.init_cache(cfg, B, S)
        lg, cache = transformer.prefill(params, cfg, tokens[:, :Sp], cache)
        outs = [lg]
        for t in range(Sp, S):
            lg, cache = transformer.decode_step(params, cfg, cache,
                                                tokens[:, t:t + 1],
                                                jnp.int32(t))
            outs.append(lg)
        return np.stack([np.asarray(o[:, 0]) for o in outs])

    if mesh is None:
        return run()
    with use_mesh(mesh):
        return run()


def check_arch(arch: str, mesh) -> float:
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    want = _decode_trace(cfg, params, tokens)            # replicated ref
    specs = tree_param_specs(params, fsdp=False)         # pure TP
    sharded = jax.tree.map(
        lambda l, s: jax.device_put(l, named_sharding(mesh, s)),
        params, specs)
    got = _decode_trace(cfg, sharded, tokens, mesh=mesh)
    err = float(np.abs(got - want).max())
    RESULTS[f"{arch}_prefill_decode_err"] = err
    assert err < 1e-4, f"{arch} sharded prefill/decode diverges: {err}"
    return err


def check_safe_concat_micro(mesh):
    """The raw bug shape: a 'model'-sharded (…, 512) piece next to
    replicated narrow pieces, concatenated on the sharded dim.
    safe_concat must equal the unsharded numpy concat."""
    a = jax.random.normal(KEY, (4, 512))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16))
    c = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 16))
    want = np.concatenate([np.asarray(a), np.asarray(b), np.asarray(c)],
                          axis=-1)
    a_sh = jax.device_put(a, NamedSharding(mesh, P(None, "model")))
    b_r = jax.device_put(b, NamedSharding(mesh, P()))
    c_r = jax.device_put(c, NamedSharding(mesh, P()))
    got = np.asarray(jax.jit(lambda *xs: safe_concat(list(xs), -1))(
        a_sh, b_r, c_r))
    err = float(np.abs(got - want).max())
    RESULTS["safe_concat_micro_err"] = err
    assert err < 1e-6, f"safe_concat diverges on the bug shape: {err}"


def main():
    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 virtual devices, got {n_dev}"
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    check_safe_concat_micro(mesh)
    check_arch("deepseek-v2-lite-16b", mesh)   # MLA q/k rope concats
    check_arch("mamba2-130m", mesh)            # SSD conv-cache concat
    RESULTS["n_devices"] = n_dev
    _subproc.emit(RESULTS)


if __name__ == "__main__":
    main()
