"""Streaming participation subsystem: event queue, capacity slots,
scheduler/trainer parity, scenario library.

The acceptance-critical property pinned here: a client constructed
*after* the RoundEngine was built can be admitted mid-training via an
Arrival event and contributes to aggregation without an engine rebuild or
a scan recompile (compilation cache entries are counted across the
admit).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import (Arrival, Client, Departure, FederatedTrainer,
                       InactivityBurst, StreamScheduler, TraceShift)
from repro.fed.scenarios import (SCENARIOS, make_scenario, run_scenario,
                                 summarize_history)
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(
        ll, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def make_clients(n=8, seed=0, trace_idx=None):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1],
                   trace=TRACES[trace_idx if trace_idx is not None
                                else rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_scheduler(clients, *, capacity=None, mode="device", seed=0,
                   chunk_size=4, events=(), **kw):
    return StreamScheduler(
        clients=clients, init_params=init_small(jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn, capacity=capacity,
        local_epochs=5, batch_size=6, scheme="C", eta0=1.0, seed=seed,
        mode=mode, chunk_size=chunk_size, events=events, **kw)


def assert_params_close(p1, p2, rtol=3e-4, atol=1e-5):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


# -- scheduler vs trainer parity (satellite) ----------------------------------

def test_scheduler_replays_static_schedule_like_trainer():
    """A precomputed schedule (arrival at tau=3, departure at tau=6)
    replayed through a *standalone* StreamScheduler — the arriving client
    admitted into a capacity slot via an Arrival event — reproduces the
    FederatedTrainer engine-mode history round-for-round: identical
    RoundRecord.s / eta / event streams and allclose params."""
    all_clients = make_clients(8, seed=0)
    tr_clients = make_clients(8, seed=0)
    tr_clients[7].active_from = 3
    tr_clients[2].departs_at = 6
    tr = FederatedTrainer(
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn,
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        clients=tr_clients, local_epochs=5, batch_size=6, scheme="C",
        eta0=1.0, seed=0, engine="plan", chunk_size=4)
    h1 = tr.run(10, eval_every=4)

    sch = make_scheduler(
        all_clients[:7], capacity=8, mode="plan", seed=0,
        max_samples=max(c.n for c in all_clients),
        events=[Arrival(3, client=all_clients[7]),
                Departure(6, client_id=2)])
    h2 = sch.run(10, eval_every=4)

    assert len(h1) == len(h2) == 10
    for r1, r2 in zip(h1, h2):
        assert r1.tau == r2.tau
        np.testing.assert_array_equal(r1.s, r2.s)   # identical RNG stream
        np.testing.assert_allclose(r1.eta, r2.eta, rtol=1e-6)
        assert r1.event == r2.event
        assert r1.n_active == r2.n_active
        assert np.isnan(r1.loss) == np.isnan(r2.loss)
        if np.isfinite(r1.loss):
            np.testing.assert_allclose(r1.loss, r2.loss, rtol=1e-4,
                                       atol=1e-5)
    assert_params_close(tr.params, sch.params)
    assert tr.objective == sch.objective


# -- capacity slots (acceptance criterion) ------------------------------------

def test_arrival_after_build_no_rebuild_no_recompile():
    """A client constructed after RoundEngine build is admitted
    mid-training and contributes to aggregation; the compiled span scans
    are reused (per-chunk compilation cache entries unchanged across the
    admit) and the engine object is never rebuilt."""
    sch = make_scheduler(make_clients(4, seed=5), capacity=6,
                         max_samples=600, mode="device", chunk_size=4)
    engine = sch.engine
    sch.run(4, eval_every=4)
    fns = dict(engine._fns)
    assert fns, "expected compiled chunk fns after the first run"
    sizes = {k: f._cache_size() for k, f in fns.items()}

    # brand-new device: data and trace did not exist at build time
    new_cl = make_clients(1, seed=77, trace_idx=0)[0]  # cpu_0: s=E surely
    sch.push(Arrival(4, client=new_cl))
    sch.run(4, eval_every=4)

    assert sch.engine is engine                      # no rebuild
    for k, f in fns.items():                         # no recompile
        assert f._cache_size() == sizes[k], f"chunk {k} recompiled"
    assert set(engine._fns) == set(fns), "new scan lengths compiled"

    slot = sch.slot_of[4]
    assert slot == 4
    # the new client participates (cpu_0 trace: all E epochs, every round)
    post = [h for h in sch.history if h.tau >= 4]
    assert all(h.s[slot] == 5.0 for h in post)
    # and carries aggregation weight
    assert sch.data_weights()[slot] > 0
    assert any("arrival:4;" in h.event for h in post)


def test_capacity_exhausted_raises():
    sch = make_scheduler(make_clients(2, seed=1), capacity=2,
                         max_samples=600)
    sch.push(Arrival(1, client=make_clients(1, seed=9)[0]))
    with pytest.raises(RuntimeError, match="capacity"):
        sch.run(4, eval_every=4)


def test_departure_frees_slot_for_reuse():
    """Exclude-departure evicts the slot; a later Arrival reuses it."""
    sch = make_scheduler(make_clients(3, seed=2), capacity=3,
                         max_samples=600,
                         events=[Departure(2, client_id=0,
                                           policy="exclude")])
    new_cl = make_clients(1, seed=33, trace_idx=0)[0]
    sch.push(Arrival(4, client=new_cl))
    sch.run(8, eval_every=8)
    assert 0 not in sch.objective and 3 in sch.objective
    assert sch.slot_of[3] == 0                       # slot 0 recycled
    assert int(np.asarray(sch.engine.n)[0]) == new_cl.n
    for h in sch.history:
        if h.tau in (0, 1):
            pass                                     # old client may train
        elif 2 <= h.tau < 4:
            assert h.s[0] == 0.0                     # slot empty
        else:
            assert h.s[0] == 5.0                     # new client, cpu_0


# -- event semantics ----------------------------------------------------------

def test_trace_shift_changes_sampling_law():
    sch = make_scheduler(make_clients(3, seed=3, trace_idx=4),
                         events=[TraceShift(3, 0, TRACES[0])])
    sch.run(8, eval_every=8)
    post = [h.s[0] for h in sch.history if h.tau >= 3]
    assert all(s == 5.0 for s in post)               # cpu_0: s = E surely
    pre = [h.s[0] for h in sch.history if h.tau < 3]
    assert np.mean(pre) < 4.0                        # cpu_90: mean 0.3*E


def test_inactivity_burst_masks_and_resumes():
    sch = make_scheduler(make_clients(4, seed=4, trace_idx=0),
                         events=[InactivityBurst(2, 2, (0, 1))])
    sch.run(6, eval_every=6)
    for h in sch.history:
        masked = 2 <= h.tau < 4
        assert (h.s[0] == 0.0) == masked
        assert (h.s[1] == 0.0) == masked
        assert h.s[2] == 5.0 and h.s[3] == 5.0       # cohort-local outage
    assert any("burst:0,1@2;" in h.event for h in sch.history)


def test_events_applied_in_tau_order_and_coalesced():
    """Out-of-order pushes fire in tau order; same-tau events coalesce
    into a single span boundary."""
    clients = make_clients(4, seed=6, trace_idx=0)
    sch = make_scheduler(clients, capacity=5, max_samples=600)
    sch.push(Departure(5, client_id=1))              # pushed first...
    sch.push(TraceShift(2, 0, TRACES[4]))            # ...fires earlier
    sch.push(InactivityBurst(2, 1, (3,)))            # same tau: coalesced
    sch.run(8, eval_every=8)
    ev = {h.tau: h.event for h in sch.history if h.event}
    assert set(ev) == {2, 5}
    assert ev[2] == "trace-shift:0;burst:3@1;"
    assert ev[5] == "departure-exclude:1;"


def test_include_departed_client_can_rejoin():
    """Regression (review finding): an include-policy departure keeps the
    client in the objective, so the duplicate-arrival guard used to
    swallow its re-arrival and the device stayed dark forever.  A rejoin
    must resume participation (slot re-admitted, s > 0) without an LR
    restart — the objective never shifted."""
    sch = make_scheduler(make_clients(3, seed=8, trace_idx=0),
                         events=[Departure(2, client_id=0,
                                           policy="include"),
                                 Arrival(4, client_id=0)])
    sch.run(8, eval_every=8)
    assert 0 in sch.objective and 0 not in sch.departed
    assert 0 in sch.slot_of                          # slot re-admitted
    assert sch.lr_shift_tau == 0                     # no objective shift
    for h in sch.history:
        expect = 0.0 if 2 <= h.tau < 4 else 5.0      # cpu_0: s = E surely
        assert h.s[sch.slot_of[0]] == expect, h.tau
    assert any("rejoin:0;" in h.event for h in sch.history if h.tau == 4)


def test_scheme_a_not_inflated_by_capacity_padding():
    """Regression (review finding): Scheme A's N must count devices in
    the objective (p > 0), not engine capacity columns."""
    from repro.core.aggregation import scheme_coefficients
    p = jnp.asarray([0.5, 0.5, 0.0, 0.0])           # 2 devices, 2 empty
    s = jnp.asarray([5.0, 5.0, 0.0, 0.0])
    c = np.asarray(scheme_coefficients("A", p, s, 5))
    np.testing.assert_allclose(c, [0.5, 0.5, 0.0, 0.0])  # N=2, K=2


def test_late_event_fires_at_next_boundary():
    """An event whose tau is already in the past (late-arriving news)
    applies at the next span boundary instead of being lost."""
    sch = make_scheduler(make_clients(3, seed=7, trace_idx=0))
    sch.run(4, eval_every=4)
    sch.push(Departure(1, client_id=2))              # tau=1 already passed
    sch.run(4, eval_every=4)
    assert 2 not in sch.objective
    assert any("departure-exclude:2;" in h.event
               for h in sch.history if h.tau == 4)


# -- honest records under streaming (satellite) -------------------------------

def test_churn_scenario_honest_nan_records():
    """With eval_every=5, only eval rounds and event rounds carry finite
    loss/acc; everything else is NaN, and history consumers
    (summarize_history, paper_tables-style mean) must filter."""
    sc = make_scenario("churn", n_clients=6, n_rounds=15, seed=1)
    sch, summary = run_scenario(sc, eval_every=5)
    assert len(sch.history) == 15
    for h in sch.history:
        should_eval = h.tau % 5 == 0 or bool(h.event)
        assert np.isfinite(h.loss) == should_eval
        assert np.isfinite(h.acc) == should_eval
    finite = [h for h in sch.history if np.isfinite(h.loss)]
    assert 0 < len(finite) < len(sch.history)
    assert summary["evals"] == len(finite)
    assert np.isfinite(summary["final_loss"])
    # benchmarks/paper_tables._run-style aggregation over filtered accs
    accs = [h.acc for h in sch.history if np.isfinite(h.acc)]
    assert np.isfinite(np.mean(accs[-3:]))


# -- scenario library ---------------------------------------------------------

def test_scenarios_reproducible_from_seed():
    for name in SCENARIOS:
        a = make_scenario(name, seed=3)
        b = make_scenario(name, seed=3)
        assert a.signature() == b.signature()
        assert len(a.clients) == len(b.clients)
        for ca, cb in zip(a.clients, b.clients):
            np.testing.assert_array_equal(ca.x, cb.x)
            assert ca.trace == cb.trace
        c = make_scenario(name, seed=4)
        assert a.signature() != c.signature() or any(
            not np.array_equal(ca.x, cc.x)
            for ca, cc in zip(a.clients, c.clients))


def test_scenario_smoke_via_benchmarks_run():
    """The --scenario smoke flag's implementation: a tiny scenario runs
    end-to-end through benchmarks/run.py without the full benchmark."""
    from benchmarks.run import scenario_smoke
    summary = scenario_smoke("staggered", rounds=8)
    assert summary["rounds"] == 8
    assert summary["events_applied"] >= 1            # cohort 1 arrived
    assert summary["scenario"] == "staggered"
    assert np.isfinite(summary["final_loss"])


def test_fed_stream_cli(tmp_path):
    from repro.launch.fed_stream import main as cli_main
    out = tmp_path / "stream.json"
    summary = cli_main(["--scenario", "diurnal", "--rounds", "6",
                        "--eval-every", "3", "--quiet",
                        "--json", str(out)])
    assert out.exists()
    assert summary["rounds"] == 6
    assert summary["rounds_per_sec"] > 0


# -- eval-set caching (satellite) ---------------------------------------------

def test_evaluate_caches_concat_and_invalidates_on_objective_change():
    """Satellite fix: evaluate() used to re-concatenate (and re-transfer)
    every client's test set on every eval round.  The concatenated device
    arrays are now cached and invalidated only by objective-changing
    events (arrival / exclude-departure) — an InactivityBurst or rejoin
    leaves the cache warm."""
    sch = make_scheduler(make_clients(4, seed=11, trace_idx=0), capacity=6,
                         max_samples=600)
    sch.run(2, eval_every=1)
    x1, y1 = sch._eval_arrays()
    assert x1 is sch._eval_arrays()[0]               # cache hit: same array
    n_before = x1.shape[0]

    # membership-neutral event: burst masks but objective is unchanged
    sch.push(InactivityBurst(2, 1, (1,)))
    sch.run(2, eval_every=1)
    assert sch._eval_arrays()[0] is x1               # still warm

    # objective-changing events invalidate: arrival grows the eval set...
    new_cl = make_clients(1, seed=44, trace_idx=0)[0]
    sch.push(Arrival(4, client=new_cl))
    sch.run(2, eval_every=1)
    x2, y2 = sch._eval_arrays()
    assert x2 is not x1
    assert x2.shape[0] == n_before + len(new_cl.x_test)
    # ...and an exclude-departure shrinks it again
    sch.push(Departure(6, client_id=0, policy="exclude"))
    sch.run(2, eval_every=1)
    x3, _ = sch._eval_arrays()
    assert x3.shape[0] == x2.shape[0] - len(sch.clients[0].x_test)
    # cached arrays equal a fresh concatenation over the objective
    xs = np.concatenate([sch.clients[i].x_test
                         for i in sorted(sch.objective)])
    np.testing.assert_array_equal(np.asarray(x3), xs)
