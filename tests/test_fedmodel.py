"""The large-model federation path: one ClientTask interface from logreg
to the architecture zoo.

Two layers of coverage (mirroring tests/test_sharded_engine.py):

* in-process smokes on the default single-device backend — a reduced
  transformer config (mamba2-130m) runs multi-round federated spans
  through the RoundEngine and the ``repro.launch.fed_train`` CLI in both
  execution modes, with plan-mode parity between them;
* one subprocess (tests/_fedmodel_check.py) under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` pinning the
  multi-device contracts: composite (pod x data) federation axes, LM
  plan parity on a (data x model) mesh in both modes (params staying
  FSDP x TP sharded in client_sequential), and zero scan recompiles
  across an arrival burst.
"""
import jax
import numpy as np
import pytest

import _subproc
from repro.configs import get_config
from repro.fed import LMTask, RoundEngine
from repro.launch.fed_train import build_fleet, main as fed_train_main

SEQ, SAMPLES, E, B = 32, 12, 2, 2


def _engine(mode, **kw):
    cfg = get_config("mamba2-130m").reduced()
    task = LMTask(cfg, seq_len=SEQ)
    clients = build_fleet(task, n_clients=3, samples=SAMPLES, seed=0)
    eng = RoundEngine(task=task, clients=clients, local_epochs=E,
                      batch_size=B, eta0=0.1, mode=mode, **kw)
    params = task.init_params(jax.random.PRNGKey(0))
    cap = eng.capacity
    kwargs = dict(p=np.full(cap, 1 / 3), active=np.ones(cap, np.float32),
                  lr_shift_tau=0, reboot_tau0=np.zeros(cap, np.int32),
                  reboot_boost=np.ones(cap, np.float32))
    return eng, params, kwargs


def test_lm_engine_modes_parity_and_finite():
    """Same plan -> both execution modes produce the same (finite,
    changed) params on the reduced transformer."""
    rng = np.random.default_rng(0)
    plan = (np.ones((2, 3, E), np.float32),
            rng.integers(0, SAMPLES, size=(2, 3, E, B)))
    outs = {}
    for mode in ("client_parallel", "client_sequential"):
        eng, params, kwargs = _engine(mode)
        out, m = eng.run_span(params, 0, 2, plan=plan, **kwargs)
        changed = 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
            bf = np.asarray(b, np.float32)
            assert np.isfinite(bf).all()
            if not np.allclose(np.asarray(a, np.float32), bf):
                changed += 1
        assert changed > 0
        outs[mode] = out
    for a, b in zip(jax.tree.leaves(outs["client_parallel"]),
                    jax.tree.leaves(outs["client_sequential"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_lm_engine_admit_new_client_no_recompile():
    """A brand-new LM client admitted mid-training reuses every compiled
    chunk (the churn contract carries over to the task layer)."""
    eng, params, kwargs = _engine("client_parallel", capacity=5,
                                  chunk_size=2)
    params, _ = eng.run_span(params, 0, 3, key=jax.random.PRNGKey(1),
                             **kwargs)
    sizes = {k: f._cache_size() for k, f in eng._fns.items()}
    cfg = get_config("mamba2-130m").reduced()
    task = LMTask(cfg, seq_len=SEQ)
    eng.admit(3, build_fleet(task, n_clients=1, samples=SAMPLES,
                             seed=5)[0])
    params, _ = eng.run_span(params, 3, 3, key=jax.random.PRNGKey(2),
                             **kwargs)
    assert {k: f._cache_size() for k, f in eng._fns.items()} == sizes


def test_fed_train_cli_smoke():
    """The CLI completes a short span (with a mid-run arrival) and the
    probe loss improves from the random-init baseline."""
    res = fed_train_main(["--arch", "mamba2-130m", "--rounds", "4",
                          "--clients", "2", "--seq", "32", "--samples",
                          "8", "--local-epochs", "1", "--batch", "2",
                          "--arrive", "1", "--eval-every", "2",
                          "--quiet"])
    assert res["rounds"] == 4
    assert res["events_applied"] == 1
    assert np.isfinite(res["final_loss"])


# -- 4-virtual-device subprocess ----------------------------------------------

@pytest.fixture(scope="module")
def fedmodel_check():
    """Run tests/_fedmodel_check.py once under a 4-device CPU mesh."""
    return _subproc.run_check("_fedmodel_check.py")


def test_composite_axes_multi_device(fedmodel_check):
    assert fedmodel_check["composite_pod_data_err"] < 1e-5


def test_lm_sharded_plan_parity_multi_device(fedmodel_check):
    assert fedmodel_check["lm_plan_parity_err_client_parallel"] < 1e-5
    assert fedmodel_check["lm_plan_parity_err_client_sequential"] < 1e-5


def test_lm_zero_recompile_churn_multi_device(fedmodel_check):
    assert fedmodel_check["lm_recompiles_across_churn"] == 0
    assert fedmodel_check["n_devices"] == 4
