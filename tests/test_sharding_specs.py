"""Sharding-spec validity: every parameter/cache leaf of every assigned
arch must be divisible along its sharded dims on the production meshes
(GSPMD rejects non-divisible *argument* shardings) — this is the cheap
static proxy for the full dry-run."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

MESH_AXES = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(specs, shapes, where):
    import jax
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_specs) == len(flat_shapes)
    for spec, leaf in zip(flat_specs, flat_shapes):
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([MESH_AXES[a] for a in axes]))
            assert dim % n == 0, (where, leaf.shape, spec, dim, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    import jax
    from repro.launch.steps import abstract_params
    from repro.models.sharding import tree_param_specs
    cfg = get_config(arch)
    aparams = abstract_params(cfg)
    specs = tree_param_specs(aparams, fsdp=fsdp)
    _check_divisible(specs, aparams, f"{arch} fsdp={fsdp}")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    import jax
    from repro.models import transformer
    cfg = get_config(arch)
    if not cfg.supports_shape(shape_name):
        pytest.skip("long_500k unsupported for full-attention arch")
    shape = INPUT_SHAPES[shape_name]
    cache = jax.eval_shape(lambda: transformer.init_cache(
        cfg, shape.global_batch, shape.seq_len))

    # reproduce steps.py cache specs (without a real mesh)
    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        baxes = None  # B=1 (long_500k) worst case -> replicated; skip batch
        if name in ("k", "v"):
            return P(None, baxes, None, "model")
        if name in ("ckv", "krope"):
            return P(None, baxes, "model", None)
        if name == "pos_map":
            return P(None, None)
        if name == "conv":
            return P(None, baxes, None, "model")
        if name == "state":
            return P(None, baxes, None, None, "model", None)
        return P(*([None] * len(leaf.shape)))

    import jax.tree_util as jtu
    specs = jtu.tree_map_with_path(spec_for, cache)
    _check_divisible(specs, cache, f"{arch} {shape_name}")


def test_param_bytes_within_hbm():
    """Per-device param bytes must fit v5e HBM (16 GB) for serving."""
    from repro.launch.steps import param_bytes, serve_fsdp
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pb = param_bytes(cfg)
        shard = 256 if serve_fsdp(cfg) else 16
        per_dev = pb / shard
        assert per_dev < 16e9, (arch, per_dev)
