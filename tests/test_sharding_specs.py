"""Sharding-spec validity: every parameter/cache leaf of every assigned
arch must be divisible along its sharded dims on the production meshes
(GSPMD rejects non-divisible *argument* shardings) — this is the cheap
static proxy for the full dry-run."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

MESH_AXES = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(specs, shapes, where):
    import jax
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_specs) == len(flat_shapes)
    for spec, leaf in zip(flat_specs, flat_shapes):
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([MESH_AXES[a] for a in axes]))
            assert dim % n == 0, (where, leaf.shape, spec, dim, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    import jax
    from repro.launch.steps import abstract_params
    from repro.models.sharding import tree_param_specs
    cfg = get_config(arch)
    aparams = abstract_params(cfg)
    specs = tree_param_specs(aparams, fsdp=fsdp)
    _check_divisible(specs, aparams, f"{arch} fsdp={fsdp}")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    import jax
    from repro.models import transformer
    cfg = get_config(arch)
    if not cfg.supports_shape(shape_name):
        pytest.skip("long_500k unsupported for full-attention arch")
    shape = INPUT_SHAPES[shape_name]
    cache = jax.eval_shape(lambda: transformer.init_cache(
        cfg, shape.global_batch, shape.seq_len))

    # reproduce steps.py cache specs (without a real mesh)
    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        baxes = None  # B=1 (long_500k) worst case -> replicated; skip batch
        if name in ("k", "v"):
            return P(None, baxes, None, "model")
        if name in ("ckv", "krope"):
            return P(None, baxes, "model", None)
        if name == "pos_map":
            return P(None, None)
        if name == "conv":
            return P(None, baxes, None, "model")
        if name == "state":
            return P(None, baxes, None, None, "model", None)
        return P(*([None] * len(leaf.shape)))

    import jax.tree_util as jtu
    specs = jtu.tree_map_with_path(spec_for, cache)
    _check_divisible(specs, cache, f"{arch} {shape_name}")


def test_composite_fed_axis_specs():
    """Composite federation axes: the client dim shards over the product
    of the named axes with a tuple PartitionSpec entry."""
    import jax
    from repro.fed.sharding import FedSharding

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    fs = FedSharding(mesh=mesh, axis=("pod", "data"))
    assert fs.axes == ("pod", "data")
    assert fs.client_spec(2) == P(("pod", "data"), None)
    assert fs.client_spec(4, axis_dim=1) == P(None, ("pod", "data"),
                                              None, None)
    # single-axis spec entry stays a bare name (layout-identical to PR 3)
    fs1 = FedSharding(mesh=mesh, axis="data")
    assert fs1.client_spec(2) == P("data", None)


def test_composite_fed_axis_padding_ownership():
    """pad_capacity rounds to whole slots per shard over the *product* of
    the federation axes, and padding is idempotent."""
    import jax
    from repro.fed.sharding import FedSharding

    mesh = jax.make_mesh((1, 1), ("pod", "data"))

    class SixShards(FedSharding):
        n_shards = 6                      # pod=2 x data=3 geometry

    fs = SixShards(mesh=mesh, axis=("pod", "data"))
    assert [fs.pad_capacity(c) for c in (1, 5, 6, 7, 12, 13)] == \
        [6, 6, 6, 12, 12, 18]
    for c in (1, 5, 6, 7, 12, 13):
        assert fs.pad_capacity(fs.pad_capacity(c)) == fs.pad_capacity(c)
        assert fs.pad_capacity(c) % fs.n_shards == 0


def test_composite_fed_axis_validation():
    """Every named federation axis must exist on the mesh."""
    import jax
    import pytest as _pytest
    from repro.fed.sharding import FedSharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with _pytest.raises(ValueError, match="no 'pod' axis"):
        FedSharding(mesh=mesh, axis=("pod", "data"))


def test_fed_param_sharding_filters_missing_axes():
    """param_sharding drops spec axes the mesh lacks, so one model rule
    table serves every mesh shape (pod entries vanish on single-pod)."""
    import jax
    from repro.fed.sharding import FedSharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fs = FedSharding(mesh=mesh, axis="data")
    ns = fs.param_sharding(P(("pod", "data"), "model"))
    # singleton tuple normalizes to the bare name (cache-key-stable form)
    assert ns.spec == P("data", "model")
    assert fs.param_sharding(None).spec == P()


def test_param_bytes_within_hbm():
    """Per-device param bytes must fit v5e HBM (16 GB) for serving."""
    from repro.launch.steps import param_bytes, serve_fsdp
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pb = param_bytes(cfg)
        shard = 256 if serve_fsdp(cfg) else 16
        per_dev = pb / shard
        assert per_dev < 16e9, (arch, per_dev)


def test_sequential_batch_pad_to_divisible(caplog):
    """Satellite (ROADMAP sequential-mode batch sharding): a ragged batch
    dim pads to the next multiple of the shard count by wrapping the
    leading samples — sharded shape divisible, original rows intact in
    order, padding fraction logged once per shape at trace time — and a
    divisible batch passes through bit-identically with no padding."""
    import jax
    import jax.numpy as jnp
    import logging
    from repro.core.fed_step import _constrain_batch, _log_batch_padding
    from repro.fed.sharding import FedSharding

    mesh = jax.make_mesh((1,), ("data",))

    class ThreeShards(FedSharding):
        n_shards = 3                      # ragged vs B=10

        def constrain_client(self, x, axis_dim=0):
            return x                      # 1-device mesh: layout no-op

    fs = ThreeShards(mesh=mesh, axis="data")
    _log_batch_padding.cache_clear()
    batch = {"x": jnp.arange(2 * 10 * 4, dtype=jnp.float32
                             ).reshape(2, 10, 4),
             "y": jnp.arange(2 * 10).reshape(2, 10)}
    with caplog.at_level(logging.WARNING,
                         logger="repro.core.fed_step"):
        out = _constrain_batch(fs, batch, axis_dim=1)
    assert out["x"].shape == (2, 12, 4) and out["y"].shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(out["x"][:, :10]),
                                  np.asarray(batch["x"]))
    # wrap-around: padded rows repeat the leading samples
    np.testing.assert_array_equal(np.asarray(out["x"][:, 10:]),
                                  np.asarray(batch["x"][:, :2]))
    msgs = [r.message for r in caplog.records if "ragged" in r.message]
    assert len(msgs) == 1                 # once per (b, shards) shape,
    #                                       deduped across the two leaves
    assert "0.167" in msgs[0]             # logged padding fraction 2/12

    class TwoShards(ThreeShards):
        n_shards = 2                      # divides B=10

    _log_batch_padding.cache_clear()
    caplog.clear()
    with caplog.at_level(logging.WARNING,
                         logger="repro.core.fed_step"):
        out2 = _constrain_batch(TwoShards(mesh=mesh, axis="data"),
                                batch, axis_dim=1)
    assert out2["x"].shape == (2, 10, 4)
    np.testing.assert_array_equal(np.asarray(out2["x"]),
                                  np.asarray(batch["x"]))
    assert not [r for r in caplog.records if "ragged" in r.message]
