"""SSD (Mamba2) chunked algorithm vs the naive per-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssd import segsum, ssd_chunked, ssd_decode_step


def naive_ssd(x, dt, A, B, C):
    """Direct recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    hg = H // G
    h = np.zeros((Bb, G, hg, P, N))
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(jnp.asarray(h), jnp.asarray(x[:, t]),
                               jnp.asarray(dt[:, t]), jnp.asarray(A),
                               jnp.asarray(B[:, t]), jnp.asarray(C[:, t]))
        h = np.asarray(h)
        ys.append(np.asarray(y))
    return np.stack(ys, axis=1), h


@settings(max_examples=12, deadline=None)
@given(S=st.sampled_from([8, 16, 32]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
def test_chunked_matches_recurrence(S, chunk, seed):
    rng = np.random.default_rng(seed)
    Bb, H, P, G, N = 2, 4, 8, 2, 4
    x = rng.normal(size=(Bb, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(Bb, S, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(H,)).astype(np.float32)
    B = rng.normal(size=(Bb, S, G, N)).astype(np.float32)
    C = rng.normal(size=(Bb, S, G, N)).astype(np.float32)
    y_chunk, h_chunk = ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                   jnp.asarray(A), jnp.asarray(B),
                                   jnp.asarray(C), chunk)
    y_naive, h_naive = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_chunk), y_naive,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), h_naive,
                               rtol=1e-3, atol=1e-3)


def test_initial_state_carries():
    rng = np.random.default_rng(0)
    Bb, S, H, P, G, N = 1, 16, 2, 4, 1, 4
    x = rng.normal(size=(Bb, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(Bb, S, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(H,)).astype(np.float32)
    B = rng.normal(size=(Bb, S, G, N)).astype(np.float32)
    C = rng.normal(size=(Bb, S, G, N)).astype(np.float32)
    # full pass vs two half passes with carried state
    y_full, h_full = ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                 jnp.asarray(A), jnp.asarray(B),
                                 jnp.asarray(C), 8)
    y1, h1 = ssd_chunked(jnp.asarray(x[:, :8]), jnp.asarray(dt[:, :8]),
                         jnp.asarray(A), jnp.asarray(B[:, :8]),
                         jnp.asarray(C[:, :8]), 8)
    y2, h2 = ssd_chunked(jnp.asarray(x[:, 8:]), jnp.asarray(dt[:, 8:]),
                         jnp.asarray(A), jnp.asarray(B[:, 8:]),
                         jnp.asarray(C[:, 8:]), 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_segsum_lower_triangular():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5,)),
                    jnp.float32)
    M = np.asarray(segsum(x))
    assert np.all(np.isneginf(M[np.triu_indices(5, 1)]))
    np.testing.assert_allclose(np.diag(M), 0.0, atol=1e-6)
    np.testing.assert_allclose(M[3, 1], float(x[2] + x[3]), rtol=1e-5)
