"""FederationService: concurrent ingestion while spans run, backpressure,
pause/drain/snapshot, and the live-vs-preloaded equivalence that makes
the service layer a faithful transport for the event stream."""
import time

import jax
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import (Arrival, Client, Departure, FederationService,
                       StreamScheduler, TraceShift)
from repro.models.small import init_small, make_loss_fn

CFG = SYNTHETIC_LR


def make_clients(n=4, seed=0, trace_idx=0):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[trace_idx],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_scheduler(seed=0, capacity=6):
    return StreamScheduler(
        clients=make_clients(4, seed=seed),
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), capacity=capacity, max_samples=600,
        local_epochs=5, batch_size=6, scheme="C", eta0=1.0, seed=seed,
        mode="device", chunk_size=4)


def test_concurrent_ingestion_applies_events():
    """Events submitted WHILE the worker trains land on the scheduler and
    take effect (the serve.py gap, closed): the main thread is the
    traffic source, the worker never stops spanning."""
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, eval_every=1 << 30,
                            max_rounds=None)
    newcomer = make_clients(1, seed=99)[0]
    with svc:
        assert svc.wait_rounds(4, timeout=120)
        # late news (tau=0 already passed): applies at the next boundary
        assert svc.submit(Arrival(0, client=newcomer))
        assert svc.submit(TraceShift(0, client_id=0, trace=TRACES[4]))
        assert svc.drain(timeout=120)
        assert svc.wait_rounds(sch._next_tau + 6, timeout=240)
    assert svc.events_ingested == 2
    assert sch.events_applied == 2
    assert 4 in sch.objective                # newcomer admitted + joined
    slot = sch.slot_of[4]
    assert any(h.s[slot] > 0 for h in sch.history)  # and it trained
    assert sch._next_tau >= 10


def test_backpressure_bounded_inbox():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_pending=2)
    # not started: nothing drains the inbox
    assert svc.submit(TraceShift(1, 0, TRACES[1]), block=False)
    assert svc.submit(TraceShift(2, 0, TRACES[2]), block=False)
    assert not svc.submit(TraceShift(3, 0, TRACES[3]), block=False)
    assert svc.events_submitted == 2
    assert not svc.submit(TraceShift(3, 0, TRACES[3]), timeout=0.05)


def test_pause_resume_and_drain():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_rounds=None)
    with svc:
        assert svc.wait_rounds(2, timeout=120)
        svc.pause()
        frozen = sch._next_tau
        svc.submit(TraceShift(0, client_id=1, trace=TRACES[2]))
        assert svc.drain(timeout=60)         # ingested while paused
        assert svc.events_ingested == 1
        time.sleep(0.05)
        assert sch._next_tau == frozen       # no spans while paused
        svc.resume()
        assert svc.wait_rounds(frozen + 2, timeout=120)
    assert sch.clients[1].trace == TRACES[2]


def test_live_stream_matches_preloaded_run():
    """Feeding a schedule through the service (submitted ahead of their
    taus) reproduces the same trajectory as preloading the events into a
    blocking scheduler — the service is pure transport."""
    newcomer = make_clients(1, seed=7)[0]
    events = [TraceShift(3, client_id=0, trace=TRACES[2]),
              Arrival(5, client=make_clients(1, seed=7)[0]),
              Departure(8, client_id=1, policy="exclude")]
    pre = make_scheduler()
    pre.push(TraceShift(3, client_id=0, trace=TRACES[2]),
             Arrival(5, client=newcomer),
             Departure(8, client_id=1, policy="exclude"))
    pre.run(12, eval_every=1 << 30)

    live = make_scheduler()
    svc = FederationService(live, span_rounds=12, eval_every=1 << 30,
                            max_rounds=12)
    svc.submit(*events)                      # before start: deterministic
    with svc:
        assert svc.wait_rounds(12, timeout=240)
    assert len(live.history) == len(pre.history) == 12
    for r1, r2 in zip(pre.history, live.history):
        np.testing.assert_array_equal(r1.s, r2.s)
        assert r1.event == r2.event
    for a, b in zip(jax.tree.leaves(pre.params),
                    jax.tree.leaves(live.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_worker_error_surfaces():
    """A raising span must not hang callers: wait_rounds and stop re-raise
    from the worker."""
    sch = make_scheduler(capacity=4)         # no free slots
    svc = FederationService(sch, span_rounds=2, max_rounds=20)
    svc.submit(Arrival(0, client=make_clients(1, seed=3)[0]))
    svc.start()
    with pytest.raises(RuntimeError, match="worker died"):
        svc.wait_rounds(20, timeout=120)
    with pytest.raises(RuntimeError, match="worker died"):
        svc.stop()


def test_stats_shape():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=4, max_rounds=4)
    with svc:
        svc.wait_rounds(4, timeout=120)
    st = svc.stats()
    assert st["rounds"] == 4
    assert st["spans_run"] >= 1
    assert st["inbox_depth"] == 0
    assert st["running"] is False


# -- lifecycle error paths -----------------------------------------------------

def test_submit_after_stop_raises():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_rounds=2)
    with svc:
        svc.wait_rounds(2, timeout=120)
    with pytest.raises(RuntimeError, match="stopped"):
        svc.submit(TraceShift(0, client_id=0, trace=TRACES[1]))


def test_double_start_is_idempotent_restart_is_not():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_rounds=None)
    svc.start()
    assert svc.start() is svc                # already running: no-op
    assert svc.wait_rounds(2, timeout=120)
    svc.stop()
    with pytest.raises(RuntimeError, match="restarted"):
        svc.start()                          # dead services stay dead


def test_snapshot_while_paused_stays_paused():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_rounds=None)
    with svc:
        assert svc.wait_rounds(2, timeout=120)
        svc.pause()
        frozen = sch._next_tau
        state = svc.snapshot()               # consistent even while paused
        assert state["next_tau"] == frozen
        time.sleep(0.05)
        assert svc.stats()["paused"]         # snapshot didn't resume us
        assert sch._next_tau == frozen
        svc.resume()
        assert svc.wait_rounds(frozen + 2, timeout=120)


def test_drain_racing_a_dead_worker_raises():
    """drain() must not hang forever when the worker died with the inbox
    non-empty — it re-raises the worker's error instead of spinning."""
    from repro.fed import Fault, FaultPlan
    plan = FaultPlan([Fault("worker", k, "crash") for k in range(4)],
                     seed=0)
    sch = make_scheduler()
    sch.injector = plan
    svc = FederationService(sch, span_rounds=2, max_rounds=20)
    svc.start()
    time.sleep(0.2)                          # let the crash land
    svc.submit(TraceShift(0, client_id=0, trace=TRACES[1]))
    with pytest.raises(RuntimeError, match="worker died"):
        svc.drain(timeout=30)                # nobody is draining
    with pytest.raises(RuntimeError, match="worker died"):
        svc.stop()


def test_stop_with_timeout_joins_cleanly():
    sch = make_scheduler()
    svc = FederationService(sch, span_rounds=2, max_rounds=None)
    svc.start()
    assert svc.wait_rounds(2, timeout=120)
    svc.stop(wait=True, timeout=30)          # bounded join, no error
    assert not svc.running


def test_supervise_requires_snapshot_dir():
    with pytest.raises(ValueError, match="snapshot_dir"):
        FederationService(make_scheduler(), supervise=True)
    with pytest.raises(ValueError, match="queue_policy"):
        FederationService(make_scheduler(), queue_policy="bogus")
