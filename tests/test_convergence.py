"""Theorem 3.1 / Table 1 validation on closed-form strongly-convex
quadratics: under heterogeneous device participation only Scheme C
converges to the global optimum; Schemes A and B plateau at a biased point.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import scheme_coefficients
from repro.core.fed_step import make_fed_round
from repro.core.theory import quadratic_problem_constants

E = 4
N = 4
DIM = 6


def make_problem(seed=0):
    rng = np.random.default_rng(seed)
    A_list = [np.diag(rng.uniform(0.5, 2.0, DIM)) for _ in range(N)]
    c_list = [rng.normal(0, 2.0, DIM) for _ in range(N)]
    n_k = rng.integers(50, 200, N).astype(float)
    p = n_k / n_k.sum()
    pc, w_star = quadratic_problem_constants(A_list, c_list, p)
    return A_list, c_list, p, w_star


def quad_loss_factory(A_list, c_list, p):
    A = jnp.asarray(np.stack(A_list))
    c = jnp.asarray(np.stack(c_list))

    def loss_fn(params, batch):
        k = batch["client"][0]
        w = params["w"]
        d = w - c[k]
        return 0.5 * d @ A[k] @ d

    return loss_fn


def run_scheme(scheme, A_list, c_list, p, w_star, *, s_pattern,
               rounds=300, eta0=0.5, seed=0):
    """s_pattern: per-client FIXED epochs completed each round (max
    heterogeneity, deterministic full-batch gradients)."""
    loss_fn = quad_loss_factory(A_list, c_list, p)
    round_fn = jax.jit(make_fed_round(loss_fn, "client_parallel"))
    params = {"w": jnp.zeros(DIM)}
    alpha = (np.arange(E)[None, :] < np.asarray(s_pattern)[:, None]
             ).astype(np.float32)
    batches = {"client": np.tile(np.arange(N)[:, None, None], (1, E, 1))}
    coeffs = np.array(scheme_coefficients(
        scheme, jnp.asarray(p), jnp.asarray(s_pattern, dtype=np.float32), E))
    for tau in range(rounds):
        eta = eta0 / (tau + 1)
        params, _ = round_fn(params,
                             {"client": jnp.asarray(batches["client"])},
                             jnp.asarray(alpha), jnp.asarray(coeffs),
                             jnp.float32(eta))
    return float(np.linalg.norm(np.asarray(params["w"]) - w_star))


@pytest.fixture(scope="module")
def problem():
    return make_problem(0)


def test_scheme_c_converges_heterogeneous(problem):
    A_list, c_list, p, w_star = problem
    err = run_scheme("C", A_list, c_list, p, w_star,
                     s_pattern=[E, 2, 1, 3])
    assert err < 0.05, err


def test_scheme_b_biased_heterogeneous(problem):
    A_list, c_list, p, w_star = problem
    err_b = run_scheme("B", A_list, c_list, p, w_star,
                       s_pattern=[E, 2, 1, 3])
    err_c = run_scheme("C", A_list, c_list, p, w_star,
                       s_pattern=[E, 2, 1, 3])
    # B converges to a suboptimal point: strictly worse than C
    assert err_b > 5 * err_c, (err_b, err_c)
    assert err_b > 0.05


def test_schemes_equivalent_homogeneous(problem):
    """With s^k identical across clients all three schemes aggregate the
    same update direction (Table 1, homogeneous column)."""
    A_list, c_list, p, w_star = problem
    errs = {s: run_scheme(s, A_list, c_list, p, w_star,
                          s_pattern=[2, 2, 2, 2], rounds=200)
            for s in "ABC"}
    # A uses N p / K with K=0 complete => all coeffs 0 unless s=E; use full
    assert errs["B"] < 0.1 and errs["C"] < 0.1
    err_full = {s: run_scheme(s, A_list, c_list, p, w_star,
                              s_pattern=[E] * N, rounds=200)
                for s in "ABC"}
    for s in "ABC":
        assert err_full[s] < 0.05, (s, err_full[s])


def test_full_participation_fedavg_converges(problem):
    """Sanity: classic FedAvg (s=E, scheme B) reaches w*."""
    A_list, c_list, p, w_star = problem
    err = run_scheme("B", A_list, c_list, p, w_star, s_pattern=[E] * N)
    assert err < 0.02, err
