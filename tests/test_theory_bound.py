"""Measured Scheme-C convergence stays inside the Theorem 3.1 envelope."""
from benchmarks.bound_check import run


def test_trajectory_within_thm31_bound():
    rows = run(rounds=80, seed=1)
    assert rows, "no measurements"
    for tau, err, bound in rows:
        assert err <= bound, (tau, err, bound)
    # and the run actually converges
    assert rows[-1][1] < 0.1 * max(rows[0][1], 1e-6) or rows[-1][1] < 0.05
