"""Participation traces and the equivalent-view alpha masks."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.participation import (TRACES, BernoulliParticipation, Trace,
                                      assign_traces, sample_alpha)


def test_cpu_traces_never_inactive():
    rng = np.random.default_rng(0)
    for t in TRACES[:5]:
        s = t.sample_s(rng, 5, size=(500,))
        assert (s >= 1).all(), t.name


def test_bw_traces_include_inactive():
    rng = np.random.default_rng(0)
    for t in TRACES[5:]:
        s = t.sample_s(rng, 5, size=(2000,))
        frac_zero = (s == 0).mean()
        assert abs(frac_zero - t.p_inactive) < 0.05, (t.name, frac_zero)


def test_alpha_is_prefix_mask():
    rng = np.random.default_rng(1)
    traces = [TRACES[i % 8] for i in range(20)]
    alpha = sample_alpha(rng, traces, E=5)
    assert alpha.shape == (20, 5)
    # prefix structure: once 0, stays 0
    diffs = np.diff(alpha, axis=1)
    assert (diffs <= 0).all()


def test_trace_moments_roughly_match():
    rng = np.random.default_rng(2)
    t = TRACES[2]  # cpu_50: mean .75 stdev .113
    f = t.sample_fraction(rng, size=(20000,))
    assert abs(f.mean() - t.mean) < 0.02
    assert abs(f.std() - t.stdev) < 0.03


@settings(max_examples=10, deadline=None)
@given(q=st.floats(0.1, 0.9), seed=st.integers(0, 100))
def test_bernoulli_equivalent_view(q, seed):
    """App. A.1.1: alpha_t ~ Bern(q) => s ~ Bin(E, q)."""
    rng = np.random.default_rng(seed)
    E = 8
    bp = BernoulliParticipation(q)
    alpha = bp.sample_alpha(rng, 3000, E)
    s = alpha.sum(axis=1)
    assert abs(s.mean() - E * q) < 0.3
    assert abs(s.var() - E * q * (1 - q)) < 0.5


def test_assign_traces_uses_first_j():
    rng = np.random.default_rng(0)
    traces = assign_traces(rng, 50, 3)
    names = {t.name for t in traces}
    assert names <= {t.name for t in TRACES[:3]}
