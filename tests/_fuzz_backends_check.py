"""Subprocess body for the cross-backend fuzz parity axis
(tests/test_fuzz_invariants.py) — the "sharded" backend only exists
under a multi-device mesh, and XLA_FLAGS must virtualize devices before
jax initializes, so this check runs in a fresh interpreter (the
in-process tier-1 test covers client_parallel vs client_sequential).

Checks:
  1. the fuzzer's seeded op schedules walk ONE trajectory across all
     three execution backends — client_parallel, client_sequential and
     the 4-shard engine: exact control plane + s streams, final params
     within tolerance, zero recompiles on every warm pool engine;
  2. mutation smoke (acceptance criterion): a seeded parity break — the
     sharded engine's slot-0 weight silently scaled 1.5x — must be
     caught by the cross-check as a "backend-parity" violation.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import _subproc  # noqa: E402
from repro.fed import make_fed_sharding  # noqa: E402
from repro.fed.fuzz import (InvariantViolation,  # noqa: E402
                            make_backend_pool, run_backend_matrix,
                            run_cross_backend_case)

RESULTS = {}
SEEDS = range(6)


def check_matrix(pool):
    stats = run_backend_matrix(SEEDS, pool=pool)
    assert stats["cases"] == len(SEEDS)
    assert stats["backends"] == ["client_parallel", "client_sequential",
                                 "sharded"]
    RESULTS["cases"] = stats["cases"]
    RESULTS["rounds"] = stats["rounds"]
    RESULTS["max_param_err"] = stats["max_param_err"]
    RESULTS["events_applied"] = int(sum(
        r["events_applied"] for r in stats["per_case"]))


def check_parity_mutation_caught(pool):
    # seeded breakage: scale the sharded engine's slot-0 aggregation
    # weight — the kind of silent bias a wrong psum epilogue would
    # introduce.  The cross-check must flag it, and must recover once
    # the mutation is lifted.
    eng = pool["sharded"].engine
    orig = eng.run_span

    def biased(params, tau_start, n_rounds, *, p, **kw):
        p = np.asarray(p, np.float32).copy()
        p[0] *= 1.5
        return orig(params, tau_start, n_rounds, p=p, **kw)

    eng.run_span = biased
    try:
        run_cross_backend_case(pool, 0)
        raise SystemExit("biased sharded aggregation was NOT caught")
    except InvariantViolation as e:
        assert e.invariant == "backend-parity", e
        RESULTS["parity_mutation_caught"] = True
    finally:
        del eng.run_span                   # restore the bound method
    run_cross_backend_case(pool, 0)        # clean engine passes again
    RESULTS["parity_mutation_clean_after"] = True


def main():
    n_dev = len(jax.devices())
    assert n_dev == 4, f"expected 4 virtual devices, got {n_dev}"
    pool = make_backend_pool(
        ("client_parallel", "client_sequential", "sharded"),
        sharding=make_fed_sharding(4))
    check_matrix(pool)
    check_parity_mutation_caught(pool)
    RESULTS["n_devices"] = n_dev
    _subproc.emit(RESULTS)


if __name__ == "__main__":
    main()
