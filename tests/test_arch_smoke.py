"""REQUIRED per-arch smoke tests: a reduced variant of each assigned
architecture (2 layers, d_model<=512, <=4 experts) runs one forward and one
federated train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.fed_step import fed_train_step
from repro.models import transformer
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, key, with_client_dims=None):
    shp = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    if with_client_dims:
        C, E = with_client_dims
        shp = (C, E) + shp
    toks = jax.random.randint(key, shp, 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_patches:
        pshape = shp[:-1] + (cfg.n_patches, cfg.d_model)
        batch["patch_emb"] = 0.02 * jax.random.normal(key, pshape,
                                                      jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, KEY)
    h, aux, _ = transformer.model_forward(
        params, cfg, batch["tokens"],
        patch_emb=batch.get("patch_emb"))
    S_total = S + (cfg.n_patches or 0)
    assert h.shape == (B, S_total, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    lg = transformer.logits_fn(params, cfg, h[:, -1:])
    assert lg.shape[-1] == cfg.vocab_padded
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_fed_train_step(arch):
    """One federated round (the paper's Eq. 2) on the reduced arch."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    C, E, B, S = 2, 2, 1, 16
    batch = make_batch(cfg, B, S, KEY, with_client_dims=(C, E))
    alpha = jnp.asarray([[1.0, 1.0], [1.0, 0.0]])  # one incomplete client
    p_weights = jnp.asarray([0.5, 0.5])

    def loss_fn(p, b):
        return transformer.train_loss(p, cfg, b)

    from repro.core.fed_step import make_fed_round
    from repro.core.aggregation import scheme_coefficients
    s = jnp.sum(alpha, -1)
    coeffs = scheme_coefficients("C", p_weights, s, E)
    new_params, metrics = make_fed_round(loss_fn, "client_parallel")(
        params, batch, alpha, coeffs, jnp.float32(1e-3))
    # shapes preserved, finite, and actually changed
    changed = 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        bf = np.asarray(b, np.float32)
        assert np.isfinite(bf).all()
        if not np.allclose(np.asarray(a, np.float32), bf):
            changed += 1
    assert changed > 0
    assert np.isfinite(float(metrics["delta_norm"]))


@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v2-lite-16b",
                                  "mamba2-130m"])
def test_sequential_mode_matches_parallel(arch):
    """client_sequential and client_parallel implement the same Eq. (2)."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    C, E, B, S = 2, 2, 1, 16
    batch = make_batch(cfg, B, S, KEY, with_client_dims=(C, E))
    alpha = jnp.asarray([[1.0, 1.0], [1.0, 0.0]])
    coeffs = jnp.asarray([0.5, 1.0])

    def loss_fn(p, b):
        return transformer.train_loss(p, cfg, b)

    from repro.core.fed_step import make_fed_round
    out_p, _ = make_fed_round(loss_fn, "client_parallel")(
        params, batch, alpha, coeffs, jnp.float32(1e-3))
    out_s, _ = make_fed_round(loss_fn, "client_sequential")(
        params, batch, alpha, coeffs, jnp.float32(1e-3))
    for a, b in zip(jax.tree.leaves(out_p), jax.tree.leaves(out_s)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-5)
