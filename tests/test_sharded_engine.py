"""The sharded federation axis (fed/sharding.py + engine sharding).

Two layers of coverage:

* in-process tests on a degenerate 1-device 'data' mesh — the sharded
  code path (committed NamedShardings, shard_map psum epilogue, capacity
  padding) with trivially-verifiable arithmetic, cheap enough for every
  tier-1 run;
* a single subprocess (tests/_sharded_check.py) with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` set before jax
  initializes, pinning the real multi-device contracts: round-for-round
  parity of the sharded engine vs the single-device engine, sampling
  invariance, the cross-device psum reduction for both weighted_agg
  layouts, and zero scan recompiles across membership churn.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _subproc

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer, make_fed_sharding
from repro.fed.sharding import FedSharding
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


# -- spec unit tests (no mesh computation) ------------------------------------

def test_pad_capacity_whole_slots_per_shard():
    fs = make_fed_sharding(1)
    assert fs.pad_capacity(6) == 6
    mesh = jax.make_mesh((1,), ("data",))

    class FourShards(FedSharding):
        n_shards = 4
    fs4 = FourShards(mesh=mesh)
    assert [fs4.pad_capacity(c) for c in (1, 4, 6, 8, 9)] == [4, 4, 8, 8, 12]


def test_client_spec_axis_dim():
    fs = make_fed_sharding(1)
    assert fs.client_spec(3) == jax.sharding.PartitionSpec(
        "data", None, None)
    assert fs.client_spec(4, axis_dim=1) == jax.sharding.PartitionSpec(
        None, "data", None, None)
    assert fs.n_shards == 1


def test_fed_sharding_requires_named_axis():
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no 'data' axis"):
        FedSharding(mesh=mesh)


def test_weighted_agg_sharded_rejects_ragged_client_axis():
    from repro.kernels.ops import weighted_agg_sharded
    fs = make_fed_sharding(1)
    # a 1-device mesh can't produce the error, so check the guard directly
    with pytest.raises(ValueError, match="not divisible"):
        from repro.kernels.weighted_agg import weighted_agg_sharded as raw

        class FakeMesh:
            shape = {"data": 2}
        raw(jnp.ones(3), jnp.ones((3, 8)), mesh=FakeMesh())
    # happy path on the real mesh
    out = weighted_agg_sharded(jnp.ones(4), jnp.ones((4, 10)), mesh=fs.mesh)
    np.testing.assert_allclose(np.asarray(out), 4.0, rtol=1e-6)


# -- 1-device mesh: sharded path == unsharded path ----------------------------

def _make_clients(n=6, seed=0):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def _eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(
        ll, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


@pytest.mark.parametrize("agg", ["tree", "flat"])
def test_one_device_mesh_matches_unsharded(agg):
    """On a (1,) 'data' mesh the sharded engine runs the identical
    arithmetic (psum over one shard is the identity), so plan-mode
    trajectories must agree tightly with the unsharded engine."""
    def trainer(sharding):
        return FederatedTrainer(
            loss_fn=make_loss_fn(CFG), eval_fn=_eval_fn,
            init_params=init_small(jax.random.PRNGKey(0), CFG),
            clients=_make_clients(), local_epochs=5, batch_size=10,
            scheme="C", eta0=0.5, seed=0, engine="plan", agg=agg,
            sharding=sharding)

    t0 = trainer(None)
    t1 = trainer(make_fed_sharding(1))
    t0.run(6, eval_every=3)
    t1.run(6, eval_every=3)
    assert t1.engine.sharding is not None
    for a, b in zip(jax.tree.leaves(t0.params), jax.tree.leaves(t1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for h0, h1 in zip(t0.history, t1.history):
        np.testing.assert_array_equal(h0.s, h1.s)


# -- 4-virtual-device subprocess ----------------------------------------------

@pytest.fixture(scope="module")
def sharded_check():
    """Run tests/_sharded_check.py once under a 4-device CPU mesh."""
    return _subproc.run_check("_sharded_check.py")


def test_sharded_engine_round_for_round_parity(sharded_check):
    r = sharded_check
    assert r["n_devices"] == 4
    assert r["plan_parity_rounds"] == 12
    assert r["plan_parity_max_err"] < 3e-3
    assert r["device_s_stream_identical"] is True


def test_sharded_psum_aggregation_both_layouts(sharded_check):
    assert sharded_check["kernel_err_kblock_None"] < 1e-4
    assert sharded_check["kernel_err_kblock_8"] < 1e-4


def test_sharded_churn_zero_recompiles(sharded_check):
    assert sharded_check["recompiles_across_churn"] == 0
    assert sharded_check["events_applied"] >= 5


def test_sharded_null_telemetry_bit_identity(sharded_check):
    # the single-device pin lives in tests/test_telemetry.py; this one
    # covers the shard_map'd span path
    assert sharded_check["null_telemetry_bit_identical"] is True
    assert sharded_check["null_telemetry_trace_count"] > 0
