"""MoE routing/dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import _capacity, moe_ffn
from repro.models.params import _moe_params

KEY = jax.random.PRNGKey(0)


def make_moe(cfg_name="deepseek-v2-lite-16b"):
    cfg = get_config(cfg_name).reduced()
    p = _moe_params(KEY, cfg, jnp.float32)
    return cfg, p


def test_moe_output_shape_and_finite():
    cfg, p = make_moe()
    x = 0.1 * jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_moe_sigmoid_router_v3():
    cfg, p = make_moe("deepseek-v3-671b")
    assert "router_bias" in p
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_capacity_rounding():
    assert _capacity(64, 2, 4, 1.25) % 8 == 0
    assert _capacity(64, 2, 4, 1.25) >= 64 * 2 / 4


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([8, 32, 64]), seed=st.integers(0, 100))
def test_moe_gates_bounded(T, seed):
    cfg, p = make_moe()
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (1, T, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    # output magnitude bounded by sum of expert outputs (gates sum to <=1
    # after renormalisation) — crude sanity: no exploding combine
    assert float(jnp.max(jnp.abs(y))) < 1e3


def test_moe_grad_flows():
    cfg, p = make_moe()
    x = 0.1 * jax.random.normal(KEY, (1, 16, cfg.d_model))

    def f(p):
        y, aux = moe_ffn(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(f)(p)
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router receives gradient (through gate weights)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
