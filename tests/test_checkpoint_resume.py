"""Mid-stream checkpoint/resume: the event-sourced control plane round-
trips through disk and the restored run replays the remaining rounds
bit-for-bit.

The acceptance-critical property pinned here: a streamed run killed
mid-stream (pending events still queued — including an Arrival carrying a
brand-new client's data) and restored from disk produces round-for-round
identical RoundRecord history and max|param diff| < 1e-6 versus the same
run never interrupted, in BOTH sampling modes.  The uninterrupted
baseline runs its rounds in ONE run() call while the checkpointed run is
cut in half — so the test also pins the stronger invariance the design
rests on: per-round randomness never depends on span/chunk structure
(device mode folds the round index into a never-split base key; plan mode
draws host RNG per round in tau order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import (Arrival, Client, Departure, FedState,
                       InactivityBurst, StreamScheduler, TraceShift)
from repro.fed.stream import history_from_dict, history_to_dict
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(
        ll, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def make_clients(n=6, seed=0, trace_idx=None):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    return [Client(x=tr[0], y=tr[1],
                   trace=TRACES[trace_idx if trace_idx is not None
                                else rng.integers(0, 8)],
                   x_test=te[0], y_test=te[1])
            for tr, te in zip(train, test)]


def make_scheduler(mode, seed=0):
    """A run with every event type: an early trace shift and burst, a
    departure freeing a slot, and — crucially — events still PENDING at
    the checkpoint round (an Arrival with brand-new client data at tau=8
    and a departure at tau=10, both past the tau=6 cut)."""
    newcomer = make_clients(1, seed=seed + 500)[0]
    return StreamScheduler(
        clients=make_clients(6, seed=seed),
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn, capacity=8,
        max_samples=600, local_epochs=5, batch_size=6, scheme="C",
        eta0=1.0, seed=seed, mode=mode, chunk_size=4,
        events=[TraceShift(2, client_id=0, trace=TRACES[1]),
                InactivityBurst(3, 2, (1, 2)),
                Departure(5, client_id=3, policy="exclude"),
                Arrival(8, client=newcomer),
                Departure(10, client_id=1, policy="include")])


def assert_history_identical(h1, h2):
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1.tau == r2.tau
        np.testing.assert_array_equal(r1.s, r2.s)
        assert r1.eta == r2.eta
        assert r1.event == r2.event
        assert r1.n_active == r2.n_active
        assert np.isnan(r1.loss) == np.isnan(r2.loss)
        if np.isfinite(r1.loss):
            assert r1.loss == r2.loss and r1.acc == r2.acc


def max_param_diff(p1, p2):
    return max(float(np.abs(np.asarray(a, np.float32)
                            - np.asarray(b, np.float32)).max())
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))


@pytest.mark.parametrize("mode", ["device", "plan"])
def test_resume_parity_mid_stream(mode, tmp_path):
    """Kill at tau=6 (Arrival at 8 + Departure at 10 still queued),
    restore from disk, run the remaining rounds: history bit-identical,
    params < 1e-6, versus one uninterrupted 12-round run."""
    baseline = make_scheduler(mode)
    baseline.run(12, eval_every=4)            # one uncut run

    sch = make_scheduler(mode)
    sch.run(6, eval_every=4)
    assert sch.pending == 2                   # events still queued at kill
    ckpt = str(tmp_path / "ckpt")
    sch.save(ckpt)
    del sch                                   # "crash"

    res = StreamScheduler.restore(ckpt, loss_fn=make_loss_fn(CFG),
                                  eval_fn=eval_fn)
    assert res.mode == mode and res._next_tau == 6
    assert res.pending == 2                   # the queue survived the disk
    res.run(6, eval_every=4)

    assert_history_identical(baseline.history, res.history)
    diff = max_param_diff(baseline.params, res.params)
    assert diff < 1e-6, f"resume diverged: max|param diff| = {diff}"
    # control-plane state converged too
    assert res.objective == baseline.objective
    assert res.slot_of == baseline.slot_of
    assert res.departed == baseline.departed
    assert res.lr_shift_tau == baseline.lr_shift_tau
    assert res.events_applied == baseline.events_applied


def test_run_call_structure_invariance():
    """The same rounds cut into different run() calls produce the same
    trajectory — the invariance resume parity rests on (device mode:
    never-split base key + per-round fold; plan mode: per-round host
    draws in tau order)."""
    for mode in ("device", "plan"):
        a = make_scheduler(mode)
        a.run(12, eval_every=4)
        b = make_scheduler(mode)
        for n in (1, 4, 2, 5):
            b.run(n, eval_every=4)
        assert_history_identical(a.history, b.history)
        assert max_param_diff(a.params, b.params) == 0.0


def test_fedstate_dict_roundtrip():
    """FedState.to_dict/from_dict is exact: membership, slot registry,
    queue (with a brand-new Arrival client payload), reboot arrays, RNG
    stream and key all survive."""
    sch = make_scheduler("plan")
    sch.run(6, eval_every=4)
    st = sch.state
    d = st.to_dict()
    st2 = FedState.from_dict(d)
    assert st2.objective == st.objective
    assert st2.slot_of == st.slot_of
    assert st2.client_at == st.client_at
    assert sorted(st2.free_slots) == sorted(st.free_slots)
    assert st2.joined == st.joined
    assert st2.departed == st.departed
    assert st2.mask_until == st.mask_until
    assert st2.expiry_taus == st.expiry_taus
    assert st2.lr_shift_tau == st.lr_shift_tau
    assert st2.next_tau == st.next_tau
    assert st2.seq == st.seq
    assert st2.events_applied == st.events_applied
    np.testing.assert_array_equal(st2.rb_tau0, st.rb_tau0)
    np.testing.assert_array_equal(st2.rb_boost, st.rb_boost)
    np.testing.assert_array_equal(np.asarray(st2.key), np.asarray(st.key))
    # identical future RNG stream (state copied, not reseeded)
    np.testing.assert_array_equal(st2.rng.integers(0, 1 << 30, 16),
                                  st.rng.integers(0, 1 << 30, 16))
    # pending events round-trip including the new client's data arrays
    assert st2.pending == st.pending
    evs1 = sorted(st.queue)
    evs2 = sorted(st2.queue)
    for (t1, s1, e1), (t2, s2, e2) in zip(evs1, evs2):
        assert (t1, s1, type(e1)) == (t2, s2, type(e2))
    arr1 = next(e for _, _, e in evs1 if isinstance(e, Arrival))
    arr2 = next(e for _, _, e in evs2 if isinstance(e, Arrival))
    np.testing.assert_array_equal(arr1.client.x, arr2.client.x)
    assert arr1.client.trace == arr2.client.trace
    # clients and their traces (shifted at tau=2) round-trip
    assert len(st2.clients) == len(st.clients)
    assert st2.clients[0].trace == TRACES[1]


def test_history_dict_roundtrip():
    sch = make_scheduler("plan")
    sch.run(8, eval_every=3)
    back = history_from_dict(history_to_dict(sch.history))
    assert_history_identical(sch.history, back)
    assert history_from_dict(history_to_dict([])) == []


def test_restore_into_service_continues(tmp_path):
    """A snapshot taken by the service layer restores into a plain
    scheduler (and vice versa) — the checkpoint format is shared."""
    from repro.fed.service import FederationService
    sch = make_scheduler("device")
    svc = FederationService(sch, span_rounds=3, eval_every=4, max_rounds=6)
    ckpt = str(tmp_path / "svc_ckpt")
    with svc:
        assert svc.wait_rounds(6, timeout=120)
        svc.snapshot(ckpt)
    res = StreamScheduler.restore(ckpt, loss_fn=make_loss_fn(CFG),
                                  eval_fn=eval_fn)
    assert res._next_tau == 6
    res.run(6, eval_every=4)
    baseline = make_scheduler("device")
    baseline.run(12, eval_every=4)
    assert_history_identical(baseline.history, res.history)
    assert max_param_diff(baseline.params, res.params) < 1e-6
