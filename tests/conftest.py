"""Test-session setup.

The container may not ship `hypothesis`; at the seed this made six test
modules fail at *collection*, killing the whole tier-1 run.  When the real
library is absent we install a tiny deterministic shim that supports the
subset used in this repo (`given`, `settings`, `st.integers`, `st.floats`,
`st.sampled_from`, `st.booleans`): each @given test is executed with a
fixed number of examples drawn from a seeded numpy Generator, so runs are
reproducible and the property tests still sweep a nontrivial input space.
"""
from __future__ import annotations

import sys
import types


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "fuzz: seeded-corpus fuzz/validation tests; corpus size scales "
        "with REPRO_FUZZ_SEEDS (default 30; benchmarks/run.py --full "
        "drives the 128-seed nightly tier)")


try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as np

    _MAX_EXAMPLES_CAP = 10  # keep the shimmed sweeps cheap

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_shim_max_examples", 10),
                        _MAX_EXAMPLES_CAP)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s._draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # expose the signature minus the strategy kwargs so pytest does
            # not mistake them for fixtures
            import inspect
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._shim_given = True
            return wrapper
        return deco

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.floats = floats
    _st.sampled_from = sampled_from
    _st.booleans = booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
