"""Device-resident round engine: parity vs the seed per-round host loop.

The plan-mode engine consumes the trainer's numpy RNG in the seed draw
order, so alpha masks and batch indices are sample-for-sample identical to
the legacy loop; with the matching ("tree") aggregation layout the
trajectories agree to f32 tolerance over many rounds including
arrival/departure events and a mid-chunk decaying reboot boost.

The pytree-flat Pallas aggregation is parity-tested at the aggregation
level (tight allclose vs aggregate_deltas across f32/bf16 leaves) and over
a short multi-round run.  Long chained runs under post-event dynamics
(reboot boost + LR restart) amplify the f32 sum-order difference between
the two layouts chaotically (observed 1e-7 -> 1e-2 within ~9 rounds), so
layout-crossed trajectory comparisons are intentionally short.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import SYNTHETIC_LR
from repro.core.aggregation import (aggregate_deltas, aggregate_deltas_flat,
                                    accumulate_delta)
from repro.core.participation import TRACES
from repro.data import synthetic_federation
from repro.fed import Client, FederatedTrainer, RoundEngine
from repro.fed.engine import _pow2_chunks, trace_s_cdf
from repro.models.small import init_small, logits_small, make_loss_fn

CFG = SYNTHETIC_LR


def eval_fn(params, x, y):
    lg = logits_small(params, CFG, x)
    ll = jax.nn.log_softmax(lg)
    loss = -jnp.mean(jnp.take_along_axis(
        ll, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(lg, -1) == y).astype(jnp.float32))
    return float(loss), float(acc)


def make_clients(n=8, seed=0, with_events=False):
    train, test = synthetic_federation(0.5, 0.5, n, seed=seed)
    rng = np.random.default_rng(seed)
    clients = [Client(x=tr[0], y=tr[1], trace=TRACES[rng.integers(0, 8)],
                      x_test=te[0], y_test=te[1])
               for tr, te in zip(train, test)]
    if with_events:
        clients[-1].active_from = 3   # arrival => reboot boost from tau=3
        clients[2].departs_at = 6
    return clients


def make_trainer(clients, *, scheme="C", engine="plan", agg="auto", **kw):
    return FederatedTrainer(
        loss_fn=make_loss_fn(CFG), eval_fn=eval_fn,
        init_params=init_small(jax.random.PRNGKey(0), CFG),
        clients=clients, local_epochs=5, batch_size=10, scheme=scheme,
        eta0=1.0, seed=0, engine=engine, agg=agg, **kw)


def assert_params_close(p1, p2, rtol=3e-4, atol=1e-5):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("scheme", ["A", "B", "C"])
def test_engine_matches_host_loop_with_midchunk_reboot(scheme):
    """Fused multi-round scan == per-round host loop for schemes A/B/C,
    including an arrival at tau=3 whose reboot boost decays *inside* the
    subsequent chunk (eval_every=12 keeps rounds 3..11 in one span)."""
    th = make_trainer(make_clients(with_events=True), scheme=scheme,
                      engine="host")
    te = make_trainer(make_clients(with_events=True), scheme=scheme,
                      engine="plan", agg="tree", chunk_size=16)
    h1 = th.run(12, eval_every=12)
    h2 = te.run(12, eval_every=12)
    assert_params_close(th.params, te.params)
    assert th.objective == te.objective
    assert len(te.reboots) == len(th.reboots) == 1
    for r1, r2 in zip(h1, h2):
        np.testing.assert_array_equal(r1.s, r2.s)  # identical RNG stream
        np.testing.assert_allclose(r1.eta, r2.eta, rtol=1e-6)
        assert r1.event == r2.event
        assert np.isnan(r1.loss) == np.isnan(r2.loss)


def test_engine_flat_agg_short_trajectory_parity():
    """The flat Pallas layout tracks the host loop over a short run (before
    f32 sum-order differences can amplify through the training map)."""
    th = make_trainer(make_clients(), engine="host")
    tf = make_trainer(make_clients(), engine="plan", agg="flat")
    th.run(5, eval_every=5)
    tf.run(5, eval_every=5)
    assert_params_close(th.params, tf.params, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("dtypes", [(jnp.float32, jnp.float32),
                                    (jnp.float32, jnp.bfloat16)])
def test_flat_aggregation_matches_tree(dtypes):
    """aggregate_deltas_flat (one weighted_agg launch over the flattened
    model) == aggregate_deltas (per-leaf scaled-add) on mixed-dtype trees."""
    dt_a, dt_b = dtypes
    key = jax.random.PRNGKey(0)
    C = 6
    params = {"w": jax.random.normal(key, (37, 11), dt_a),
              "b": jax.random.normal(key, (11,), dt_b),
              "nested": {"v": jax.random.normal(key, (5, 3, 2), dt_a)}}
    deltas = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, p.size),
                                    (C,) + p.shape, p.dtype), params)
    coeffs = jax.random.uniform(jax.random.PRNGKey(1), (C,))
    want = aggregate_deltas(params, deltas, coeffs)
    got = aggregate_deltas_flat(params, deltas, coeffs)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert w.dtype == g.dtype
        np.testing.assert_allclose(np.asarray(w, np.float32),
                                   np.asarray(g, np.float32),
                                   rtol=2e-2 if dt_b == jnp.bfloat16
                                   else 1e-5, atol=1e-3)


def test_engine_device_sampling_distribution():
    """On-device inverse-CDF sampling reproduces Trace.sample_s's law:
    per-client mean of s within a few stderr of the host sampler."""
    clients = make_clients(6, seed=1)
    eng = RoundEngine(loss_fn=make_loss_fn(CFG), clients=clients,
                      local_epochs=5, batch_size=4)
    from repro.fed.engine import device_sample_span
    alphas, idxs = device_sample_span(
        jax.random.PRNGKey(0), 600, jnp.ones(len(clients)), eng.n,
        eng.s_cdf, 5, 4)
    s_dev = np.asarray(alphas.sum(-1))        # (600, C)
    rng = np.random.default_rng(0)
    s_host = np.stack([[c.trace.sample_s(rng, 5) for c in clients]
                       for _ in range(600)])
    np.testing.assert_allclose(s_dev.mean(0), s_host.mean(0), atol=0.35)
    # batch indices in range
    n = np.asarray(eng.n)
    assert (np.asarray(idxs) < n[None, :, None, None]).all()
    assert (np.asarray(idxs) >= 0).all()


def test_engine_device_mode_trains():
    tr = make_trainer(make_clients(12, seed=2), engine="device",
                      chunk_size=8)
    hist = tr.run(30, eval_every=30)
    assert len(hist) == 30
    loss0 = hist[0].loss                  # evaluated at tau=0
    loss_end, _ = tr.evaluate()
    assert np.isfinite(loss0) and loss_end < 0.8 * loss0
    # all rounds carried realized participation counts
    assert all(h.n_active >= 1 for h in hist)


def test_engine_events_at_chunk_boundaries():
    """Arrivals/departures land on exact rounds even with large chunks."""
    tr = make_trainer(make_clients(with_events=True), engine="plan",
                      chunk_size=16)
    hist = tr.run(10, eval_every=10)
    assert any("arrival:7" in h.event for h in hist if h.tau == 3)
    assert any("departure" in h.event for h in hist if h.tau == 6)
    assert tr.lr_shift_tau == 6
    assert 7 in tr.objective and 2 not in tr.objective


def test_round_records_honest_nan_when_not_evaluated():
    """Satellite fix: rounds without an eval record NaN, never a stale
    copy of the previous eval."""
    for engine in ("host", "plan"):
        tr = make_trainer(make_clients(), engine=engine)
        hist = tr.run(6, eval_every=2)
        for h in hist:
            if h.tau % 2 == 0:
                assert np.isfinite(h.loss) and np.isfinite(h.acc)
            else:
                assert np.isnan(h.loss) and np.isnan(h.acc)


def test_accumulate_delta_accepts_plain_float():
    acc = {"w": jnp.zeros((3,), jnp.float32)}
    delta = {"w": jnp.ones((3,), jnp.bfloat16)}
    out = accumulate_delta(acc, delta, 0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5)
    out2 = accumulate_delta(acc, delta, jnp.float32(2.0))
    np.testing.assert_allclose(np.asarray(out2["w"]), 2.0)


def test_run_span_zero_rounds():
    """Regression: n_rounds == 0 used to crash on ms[0] of an empty
    metrics list; it must return params unchanged with empty metrics."""
    clients = make_clients(4)
    eng = RoundEngine(loss_fn=make_loss_fn(CFG), clients=clients,
                      local_epochs=5, batch_size=4)
    params = init_small(jax.random.PRNGKey(0), CFG)
    C = len(clients)
    for kw in (dict(key=jax.random.PRNGKey(1)),
               dict(plan=(np.zeros((0, C, 5), np.float32),
                          np.zeros((0, C, 5, 4), np.int64)))):
        out, m = eng.run_span(params, 3, 0, p=np.ones(C) / C,
                              active=np.ones(C), lr_shift_tau=0,
                              reboot_tau0=np.zeros(C, np.int32),
                              reboot_boost=np.ones(C, np.float32), **kw)
        assert_params_close(params, out, rtol=0, atol=0)
        assert m["s"].shape == (0, C)
        assert m["eta"].shape == (0,)
        assert m["delta_norm"].shape == (0,)


def test_sequential_mode_matches_parallel_same_seeds():
    """client_sequential streams deltas into one accumulator instead of
    vmapping the client axis; with the same device-mode key (identical
    sampling) the two modes implement the same Eq. (2) round — params
    agree to f32 reassociation tolerance over a multi-chunk span."""
    clients = make_clients(6)
    params = init_small(jax.random.PRNGKey(0), CFG)
    outs, mets = {}, {}
    for mode in ("client_parallel", "client_sequential"):
        eng = RoundEngine(loss_fn=make_loss_fn(CFG), clients=make_clients(6),
                          local_epochs=5, batch_size=10, scheme="C",
                          eta0=1.0, chunk_size=4, mode=mode,
                          with_metrics=True)
        cap = eng.capacity
        p = np.array([c.n for c in clients], np.float64)
        p = p / p.sum()
        outs[mode], mets[mode] = eng.run_span(
            params, 0, 10, p=p, active=np.ones(cap, np.float32),
            lr_shift_tau=0, reboot_tau0=np.zeros(cap, np.int32),
            reboot_boost=np.ones(cap, np.float32),
            key=jax.random.PRNGKey(7))
    # identical on-device sampling stream...
    np.testing.assert_array_equal(mets["client_parallel"]["s"],
                                  mets["client_sequential"]["s"])
    # ...and matching trajectories + delta norms
    assert_params_close(outs["client_parallel"], outs["client_sequential"],
                        rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(mets["client_parallel"]["delta_norm"],
                               mets["client_sequential"]["delta_norm"],
                               rtol=1e-4)


def test_engine_rejects_bad_mode_and_double_task():
    clients = make_clients(2)
    with pytest.raises(ValueError, match="client_parallel"):
        RoundEngine(loss_fn=make_loss_fn(CFG), clients=clients,
                    local_epochs=2, batch_size=5, mode="bogus")
    from repro.fed.task import ArrayTask
    task = ArrayTask(make_loss_fn(CFG), clients[0].x.shape[1:])
    with pytest.raises(ValueError, match="exactly one"):
        RoundEngine(loss_fn=make_loss_fn(CFG), task=task, clients=clients,
                    local_epochs=2, batch_size=5)


def test_admit_many_matches_single_admits():
    """One fused admit_many burst stages the same slot state as the
    equivalent sequence of single admits (including pow2 padding that
    repeats the last row)."""
    clients = make_clients(4)
    fresh = make_clients(3, seed=77)
    engs = []
    for _ in range(2):
        engs.append(RoundEngine(loss_fn=make_loss_fn(CFG),
                                clients=make_clients(4), local_epochs=5,
                                batch_size=10, capacity=8,
                                max_samples=max(c.n for c in fresh)))
    engs[0].admit_many([(4, fresh[0]), (5, fresh[1]), (6, fresh[2])])
    for slot, c in [(4, fresh[0]), (5, fresh[1]), (6, fresh[2])]:
        engs[1].admit(slot, c)
    for name in engs[0].data:
        np.testing.assert_array_equal(np.asarray(engs[0].data[name]),
                                      np.asarray(engs[1].data[name]))
    np.testing.assert_array_equal(np.asarray(engs[0].n),
                                  np.asarray(engs[1].n))
    np.testing.assert_array_equal(np.asarray(engs[0].s_cdf),
                                  np.asarray(engs[1].s_cdf))


def test_trainer_plumbs_engine_options():
    """Satellite: interpret/donate/with_metrics reach the RoundEngine the
    trainer constructs (they were silently dropped before)."""
    tr = make_trainer(make_clients(4), engine="plan", interpret=False,
                      donate=False, with_metrics=True)
    eng = tr.engine
    assert eng.interpret is False
    assert eng.donate is False
    assert eng.with_metrics is True
    # defaults still resolve (donate=None -> backend-dependent bool)
    tr2 = make_trainer(make_clients(4), engine="plan")
    assert isinstance(tr2.engine.donate, bool)
    assert tr2.engine.with_metrics is False


def test_pow2_chunking():
    assert _pow2_chunks(13, 8) == [8, 4, 1]
    assert _pow2_chunks(32, 32) == [32]
    assert _pow2_chunks(1, 16) == [1]
    assert _pow2_chunks(0, 16) == []


def test_trace_s_cdf_properties():
    clients = make_clients(8, seed=3)
    cdf = trace_s_cdf(clients, 5)
    assert cdf.shape == (8, 6)
    assert np.all(np.diff(cdf, axis=1) >= -1e-6)      # monotone
    np.testing.assert_allclose(cdf[:, -1], 1.0)
    for i, c in enumerate(clients):
        if c.trace.p_inactive == 0:
            assert cdf[i, 0] == 0.0                   # s >= 1 clamp
        else:
            assert cdf[i, 0] >= c.trace.p_inactive - 1e-6
